//! Minimal, offline shim for the `anyhow` API surface this workspace uses:
//! `Result`, `Error`, `Context` (on `Result` and `Option`), `anyhow!` and
//! `bail!`. Messages are stored as strings (no downcasting is used in the
//! workspace); `Display` shows the outermost context, `Debug` and the
//! alternate `{:#}` form show the full cause chain, matching how the real
//! crate is observed by our tests.

use std::error::Error as StdError;
use std::fmt;

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error with a context chain. `stack[0]` is the
/// outermost (most recently attached) message.
pub struct Error {
    stack: Vec<String>,
}

impl Error {
    /// Create an error from a plain message.
    pub fn msg(message: impl Into<String>) -> Error {
        Error {
            stack: vec![message.into()],
        }
    }

    /// Push a new outermost context message.
    pub fn context(mut self, ctx: impl fmt::Display) -> Error {
        self.stack.insert(0, ctx.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.stack.iter().map(|s| s.as_str())
    }

    /// The innermost message (the original error).
    pub fn root_cause(&self) -> &str {
        self.stack.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.stack.join(": "))
        } else {
            write!(f, "{}", self.stack.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.stack.first().map(String::as_str).unwrap_or(""))?;
        if self.stack.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.stack[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what allows the blanket `From` below to coexist with the reflexive
// `impl From<T> for T` (the same trick the real crate uses).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut stack = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            stack.push(s.to_string());
            src = s.source();
        }
        Error { stack }
    }
}

/// Context-attachment extension trait for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Err::<(), _>(io_err())
            .context("opening catalog")
            .unwrap_err();
        assert_eq!(e.to_string(), "opening catalog");
        assert_eq!(format!("{e:#}"), "opening catalog: missing file");
    }

    #[test]
    fn option_context() {
        let v: Result<i32> = None.context("no value");
        assert!(v.unwrap_err().to_string().contains("no value"));
    }

    #[test]
    fn macros_format() {
        fn fails() -> Result<()> {
            bail!("bad {}", 42)
        }
        assert_eq!(fails().unwrap_err().to_string(), "bad 42");
        assert_eq!(anyhow!("x={}", 1).to_string(), "x=1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i64> {
            Ok("12x".parse::<i64>()?)
        }
        assert!(parse().is_err());
    }
}
