"""Pytest bootstrap for the python/ tree.

Two offline-environment repairs, both no-ops when the real thing is
available:

* puts this directory on ``sys.path`` so ``from compile import ...``
  resolves regardless of pytest's rootdir;
* installs a minimal fallback implementation of the ``hypothesis`` API
  surface the tests use (``given``/``settings``/``strategies``) when the
  real package is not installed. The fallback runs each property over a
  deterministic seed sweep — weaker shrinking than hypothesis, but the
  same oracle coverage, mirroring ``forelem::util::forall_seeds`` on the
  Rust side.
"""

import os
import random
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)

try:
    import hypothesis  # noqa: F401
except ImportError:
    import functools
    import types

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def _integers(min_value=0, max_value=1 << 31):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value=0.0, max_value=1.0, width=64, **_kw):
        del width  # the fallback always draws doubles; tests cast anyway
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.example(rng) for s in strategies))

    def _lists(elements, min_size=0, max_size=16):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]

        return _Strategy(draw)

    def _sampled_from(options):
        return _Strategy(lambda rng: options[rng.randrange(len(options))])

    _DEFAULT_MAX_EXAMPLES = 20

    def _settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        del deadline  # the fallback enforces no deadlines

        def decorate(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return decorate

    def _given(**strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                # @settings is applied OUTSIDE @given, so the attribute
                # lands on this wrapper, not on fn.
                max_examples = getattr(
                    runner, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES
                )
                for seed in range(max_examples):
                    rng = random.Random(seed)
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"property failed at fallback seed {seed}: "
                            f"{drawn!r}"
                        ) from e

            # Hide the drawn parameters from pytest's fixture resolution:
            # without this, inspect.signature follows __wrapped__ and
            # pytest tries to supply e.g. `vw` as a fixture.
            del runner.__wrapped__
            return runner

        return decorate

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.tuples = _tuples
    _st.lists = _lists
    _st.sampled_from = _sampled_from

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    _hyp.__fallback__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
