"""AOT-lower every L2 entry point to HLO text for the Rust runtime.

Run once at build time (``make artifacts``); Python never runs on the
request path.  The interchange format is HLO *text*, not a serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and resources/aot_recipe.md).

Output layout (``--out DIR``):

* ``DIR/<entry>.hlo.txt``  — one HLO module per entry point;
* ``DIR/manifest.tsv``     — one line per entry:
  ``name<TAB>file<TAB>in0;in1;...<TAB>out`` where each spec is
  ``dtype:dim0xdim1x...`` (e.g. ``i32:65536``).  The Rust runtime
  (rust/src/runtime/artifacts.rs) parses exactly this format.

Shape configurations are chosen to cover the Figure-2 workloads (chunked
65536-key calls over a 131072-wide dictionary-encoded key space), the
Pallas demo sizes, and small sizes the test suites use.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, fn, [input ShapeDtypeStruct-s], output spec string)
I32 = jnp.int32
F32 = jnp.float32


def _spec(dtype, *dims):
    return jax.ShapeDtypeStruct(tuple(dims), dtype)


def _fmt(dtype, *dims):
    tag = {I32: "i32", F32: "f32"}[dtype]
    return f"{tag}:{'x'.join(str(d) for d in dims)}"


def entries():
    """The artifact table: every (chunk, key-space) configuration we ship."""
    out = []

    # Scatter (large-K production) histograms and segment-sums. The 1M
    # chunk exists to amortize the PJRT call overhead on multi-million-row
    # tables (EXPERIMENTS.md §Perf).
    for n, k in [(1048576, 131072), (65536, 131072), (8192, 1024), (1024, 256)]:
        out.append(
            (
                f"count_scatter_{n}x{k}",
                functools.partial(model.count_scatter, num_keys=k),
                [_spec(I32, n)],
                _fmt(F32, k),
                [_fmt(I32, n)],
            )
        )
        out.append(
            (
                f"segsum_scatter_{n}x{k}",
                functools.partial(model.segsum_scatter, num_keys=k),
                [_spec(I32, n), _spec(F32, n)],
                _fmt(F32, k),
                [_fmt(I32, n), _fmt(F32, n)],
            )
        )

    # Pallas one-hot (TPU-adapted) variants at MXU-friendly tile sizes.
    for n, k, block, k_tile in [(8192, 1024, 1024, 256), (1024, 256, 256, 128)]:
        out.append(
            (
                f"count_onehot_{n}x{k}",
                functools.partial(
                    model.count_onehot, num_keys=k, block=block, k_tile=k_tile
                ),
                [_spec(I32, n)],
                _fmt(F32, k),
                [_fmt(I32, n)],
            )
        )
        out.append(
            (
                f"segsum_onehot_{n}x{k}",
                functools.partial(
                    model.segsum_onehot, num_keys=k, block=block, k_tile=k_tile
                ),
                [_spec(I32, n), _spec(F32, n)],
                _fmt(F32, k),
                [_fmt(I32, n), _fmt(F32, n)],
            )
        )

    # §III-B weighted-average fold.
    for n in [65536, 8192, 1024]:
        out.append(
            (
                f"weighted_avg_{n}",
                model.weighted_average,
                [_spec(F32, n), _spec(F32, n)],
                _fmt(F32, 2),
                [_fmt(F32, n), _fmt(F32, n)],
            )
        )
    return out


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--only", default=None, help="substring filter on entry names (for tests)"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest_lines = []
    for name, fn, in_specs, out_fmt, in_fmts in entries():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest_lines.append(f"{name}\t{fname}\t{';'.join(in_fmts)}\t{out_fmt}")
        print(f"  {name}: {len(text)} chars")

    with open(os.path.join(args.out, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(manifest_lines)} artifacts + manifest.tsv to {args.out}")


if __name__ == "__main__":
    main()
