"""L1 Pallas kernel: blocked segment-sum (sum-by-key).

The generalisation the paper makes in §IV: replacing the counting loop's
``count[Table[i].field1]++`` with ``sum[Table[i].field1] += Table[i].field2``
(the MapReduce pair becomes ``(field1, field2)`` instead of ``(field1, 1)``).

Structure is identical to histogram.py — same grid, same BlockSpec
schedule, same output-revisiting accumulator — except the contraction
folds the *value* vector instead of ones: ``values @ onehot``.  See
histogram.py for the TPU-adaptation rationale and VMEM accounting (this
kernel adds one BLOCK-sized f32 value block per step: +4 KiB at defaults).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .histogram import BLOCK, K_TILE


def _segsum_kernel(k_tile: int, keys_ref, vals_ref, out_ref):
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]
    vals = vals_ref[...]
    base = pl.program_id(0) * k_tile
    lanes = base + jax.lax.iota(jnp.int32, k_tile)
    onehot = (keys[:, None] == lanes[None, :]).astype(jnp.float32)
    # vals @ onehot: per-lane sum of values for this key block (MXU form).
    out_ref[...] += jnp.dot(vals, onehot, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("num_keys", "block", "k_tile"))
def group_sum(keys, values, *, num_keys: int, block: int = BLOCK, k_tile: int = K_TILE):
    """Per-key sums of ``values`` as a Pallas kernel (padding keys drop)."""
    n = keys.shape[0]
    assert n % block == 0, f"n={n} not a multiple of block={block}"
    assert num_keys % k_tile == 0, f"num_keys={num_keys} not a multiple of k_tile={k_tile}"
    assert values.shape == keys.shape
    grid = (num_keys // k_tile, n // block)
    return pl.pallas_call(
        functools.partial(_segsum_kernel, k_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda j, i: (i,)),
            pl.BlockSpec((block,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((k_tile,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((num_keys,), jnp.float32),
        interpret=True,
    )(keys, values)
