"""Pure-jnp oracles for the L1 Pallas kernels.

These are the *reference semantics* the Pallas kernels (histogram.py,
segment_sum.py) are validated against in python/tests/.  They are also the
semantics the Rust execution engine implements natively for the string
variant of the Figure-2 workloads, so agreement here ties all three layers
to one definition of the aggregation.

Conventions shared by every kernel in this package:

* keys are ``int32``; a key of ``-1`` (or any out-of-range value) is a
  padding slot and must not contribute to any bucket;
* counts/sums are ``float32``.  Chunks are bounded (<= 2**16 elements) so
  per-chunk counts are exactly representable; cross-chunk accumulation is
  done in Rust in wider types.
"""

import jax.numpy as jnp


def _sanitize(keys, num_keys: int):
    """Map negative (padding) keys out of range so ``mode='drop'`` drops them.

    jax ``.at[]`` wraps negative indices numpy-style even under
    ``mode='drop'``; a -1 padding slot would silently count into bucket
    ``num_keys - 1``.  Remapping negatives to ``num_keys`` makes them
    genuinely out-of-bounds.
    """
    return jnp.where(keys < 0, num_keys, keys)


def group_count(keys, num_keys: int):
    """counts[k] = |{ i : keys[i] == k }| for k in [0, num_keys).

    Out-of-range keys (including the -1 padding convention) are dropped,
    mirroring the one-hot kernels where such keys match no lane.
    """
    zeros = jnp.zeros((num_keys,), jnp.float32)
    return zeros.at[_sanitize(keys, num_keys)].add(1.0, mode="drop")


def group_sum(keys, values, num_keys: int):
    """sums[k] = sum of values[i] where keys[i] == k (out-of-range dropped)."""
    zeros = jnp.zeros((num_keys,), jnp.float32)
    return zeros.at[_sanitize(keys, num_keys)].add(values, mode="drop")


def weighted_average(values, weights):
    """The paper's §III-B vertically-integrated grades example.

    Returns (sum(values * weights), sum(weights)) so the caller can both
    reproduce the paper's ``avg += grade*weight`` fold and a normalized
    average without a second pass.
    """
    return jnp.dot(values, weights), jnp.sum(weights)
