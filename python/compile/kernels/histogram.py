"""L1 Pallas kernel: blocked count-by-key (histogram).

This is the TPU re-think of the paper's per-partition counting loop
(``count[Table[i].field1]++`` in the forelem intermediate, §IV):

* the scalar increment loop becomes a **one-hot contraction**: a VMEM block
  of ``BLOCK`` keys is expanded against a ``K_TILE``-wide slice of the key
  space into a ``(BLOCK, K_TILE)`` one-hot matrix, and folded with a
  ``ones(BLOCK) @ onehot`` vector-matrix product — the MXU-friendly form of
  "count occurrences" (the paper's §III-C2 vectorization remark, mapped to
  a systolic array instead of SSE/Phi lanes);
* ``BlockSpec`` expresses the HBM->VMEM schedule the paper's generated
  OpenMP code got from chunking: the key stream is tiled over the inner
  grid dimension while the histogram tile stays resident in VMEM (output
  revisiting over the innermost dimension, initialised at step 0);
* grid = (num_keys/K_TILE, n/BLOCK) — the key-space tile is the *outer*
  dimension so each output tile sees all its revisits consecutively, which
  is the layout real Mosaic lowering requires.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs under the Rust runtime.  Real-TPU VMEM/MXU estimates for the
chosen block shapes live in DESIGN.md §Perf.

Complexity note: the one-hot form does O(n * num_keys) work — the right
trade on an MXU for modest key spaces, the wrong one for 1e5+ keys.  The
large-K production path is the scatter-based L2 graph in model.py; the
Rust runtime picks per key-space size.  Both are validated against the
same oracle (ref.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile shapes. BLOCK is the number of keys streamed into VMEM per
# grid step; K_TILE is the slice of the key space each output block covers.
# VMEM per step = BLOCK*4 (keys) + BLOCK*K_TILE*4 (one-hot) + K_TILE*4
# (accumulator) bytes; 1024x256 -> ~1.1 MiB, far under the 16 MiB budget.
BLOCK = 1024
K_TILE = 256


def _count_kernel(k_tile: int, keys_ref, out_ref):
    """One grid step: fold one key block into one histogram tile."""
    step = pl.program_id(1)  # inner dimension: position in the key stream

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]
    base = pl.program_id(0) * k_tile
    lanes = base + jax.lax.iota(jnp.int32, k_tile)
    # (BLOCK, K_TILE) one-hot; padding keys (-1 / out of range) match no lane.
    onehot = (keys[:, None] == lanes[None, :]).astype(jnp.float32)
    ones = jnp.ones((keys.shape[0],), jnp.float32)
    # ones @ onehot == per-lane occurrence count for this block: the MXU form.
    out_ref[...] += jnp.dot(ones, onehot, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("num_keys", "block", "k_tile"))
def group_count(keys, *, num_keys: int, block: int = BLOCK, k_tile: int = K_TILE):
    """Histogram of ``keys`` over ``[0, num_keys)`` as a Pallas kernel.

    ``keys.shape[0]`` must be a multiple of ``block`` and ``num_keys`` a
    multiple of ``k_tile`` (callers pad with -1, which drops out).
    """
    n = keys.shape[0]
    assert n % block == 0, f"n={n} not a multiple of block={block}"
    assert num_keys % k_tile == 0, f"num_keys={num_keys} not a multiple of k_tile={k_tile}"
    grid = (num_keys // k_tile, n // block)
    return pl.pallas_call(
        functools.partial(_count_kernel, k_tile),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda j, i: (i,))],
        out_specs=pl.BlockSpec((k_tile,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((num_keys,), jnp.float32),
        interpret=True,
    )(keys)
