"""L2: the JAX compute graphs that get AOT-lowered for the Rust runtime.

Each entry point here corresponds to the numeric hot loop of one of the
paper's evaluation kernels *after* the compiler's data-reformatting pass
has made the data integer-keyed (§III-C1 / §IV).  Two families:

* ``*_onehot`` — call the L1 Pallas kernels (histogram.py /
  segment_sum.py): the TPU-adapted one-hot contraction.  O(n*K) work;
  right for modest key spaces.
* ``*_scatter`` — plain-XLA scatter-add: O(n) work; the production path
  for large key spaces on the CPU PJRT backend.

Both families share the oracle semantics of kernels/ref.py (padding key
-1 drops out) so the Rust runtime can pick either per key-space size
without changing results.

Every entry point returns a SINGLE array (never a Python tuple) so the
Rust side can uniformly unwrap the 1-tuple that ``return_tuple=True``
lowering produces.
"""

import jax.numpy as jnp

from .kernels import histogram, ref, segment_sum


def count_scatter(keys, *, num_keys: int):
    """Histogram via XLA scatter-add (large-K production path)."""
    return ref.group_count(keys, num_keys)


def count_onehot(keys, *, num_keys: int, block: int, k_tile: int):
    """Histogram via the L1 Pallas one-hot kernel."""
    return histogram.group_count(keys, num_keys=num_keys, block=block, k_tile=k_tile)


def segsum_scatter(keys, values, *, num_keys: int):
    """Per-key sums via XLA scatter-add."""
    return ref.group_sum(keys, values, num_keys)


def segsum_onehot(keys, values, *, num_keys: int, block: int, k_tile: int):
    """Per-key sums via the L1 Pallas one-hot kernel."""
    return segment_sum.group_sum(
        keys, values, num_keys=num_keys, block=block, k_tile=k_tile
    )


def weighted_average(values, weights):
    """§III-B grades fold: returns [sum(v*w), sum(w)] as a length-2 array."""
    dot, wsum = ref.weighted_average(values, weights)
    return jnp.stack([dot, wsum])
