"""L1 histogram kernel vs the pure-jnp oracle (hypothesis sweeps)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import histogram, ref

SHAPES = [
    # (n, num_keys, block, k_tile)
    (256, 128, 256, 128),
    (512, 256, 256, 128),
    (1024, 256, 256, 256),
    (1024, 512, 512, 128),
    (2048, 256, 1024, 256),
]


def _run(keys, num_keys, block, k_tile):
    got = histogram.group_count(
        jnp.asarray(keys), num_keys=num_keys, block=block, k_tile=k_tile
    )
    want = ref.group_count(jnp.asarray(keys), num_keys)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)
    return np.asarray(got)


@pytest.mark.parametrize("n,num_keys,block,k_tile", SHAPES)
def test_random_keys(n, num_keys, block, k_tile):
    rng = np.random.default_rng(seed=n + num_keys)
    keys = rng.integers(0, num_keys, size=n).astype(np.int32)
    got = _run(keys, num_keys, block, k_tile)
    assert got.sum() == n  # nothing dropped when all keys in range


@pytest.mark.parametrize("n,num_keys,block,k_tile", SHAPES)
def test_padding_keys_drop(n, num_keys, block, k_tile):
    rng = np.random.default_rng(seed=7)
    keys = rng.integers(-1, num_keys, size=n).astype(np.int32)
    got = _run(keys, num_keys, block, k_tile)
    assert got.sum() == (keys >= 0).sum()


def test_all_same_key():
    keys = np.full(512, 3, dtype=np.int32)
    got = _run(keys, 128, 256, 128)
    assert got[3] == 512 and got.sum() == 512


def test_all_padding():
    keys = np.full(256, -1, dtype=np.int32)
    got = _run(keys, 128, 256, 128)
    assert got.sum() == 0


def test_extreme_out_of_range_values():
    # Values far outside [0, num_keys) in both directions must drop, not wrap.
    keys = np.array([0, 127, 128, 1 << 30, -(1 << 30), -2, 5, 5] + [-1] * 248, dtype=np.int32)
    got = _run(keys, 128, 256, 128)
    assert got.sum() == 4  # 0, 127, 5, 5
    assert got[5] == 2


def test_block_shape_invariance():
    """The same data must produce the same histogram under any tiling."""
    rng = np.random.default_rng(seed=42)
    keys = rng.integers(0, 512, size=2048).astype(np.int32)
    a = histogram.group_count(jnp.asarray(keys), num_keys=512, block=256, k_tile=128)
    b = histogram.group_count(jnp.asarray(keys), num_keys=512, block=1024, k_tile=512)
    c = histogram.group_count(jnp.asarray(keys), num_keys=512, block=2048, k_tile=256)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_shape_assertions():
    keys = jnp.zeros(100, jnp.int32)
    with pytest.raises(AssertionError):
        histogram.group_count(keys, num_keys=128, block=256, k_tile=128)
    with pytest.raises(AssertionError):
        histogram.group_count(
            jnp.zeros(256, jnp.int32), num_keys=100, block=256, k_tile=128
        )


@settings(max_examples=40, deadline=None)
@given(
    keys=st.lists(st.integers(min_value=-1, max_value=127), min_size=1, max_size=256),
)
def test_hypothesis_arbitrary_keys(keys):
    """Pad any key list to a block boundary; kernel must match the oracle."""
    n = len(keys)
    padded = np.full(256, -1, dtype=np.int32)
    padded[:n] = np.asarray(keys, dtype=np.int32)
    got = _run(padded, 128, 256, 128)
    # Cross-check against a plain numpy histogram of the in-range keys.
    want = np.zeros(128)
    for k in keys:
        if 0 <= k < 128:
            want[k] += 1
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_zipfian_keys(seed):
    """Skewed (zipf-like) key distributions — the Figure-2 regime."""
    rng = np.random.default_rng(seed)
    keys = np.minimum(rng.zipf(1.5, size=512) - 1, 255).astype(np.int32)
    _run(keys, 256, 256, 128)
