"""AOT pipeline: entry table consistency, HLO text emission, manifest format."""

import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp

from compile import aot


def test_entry_table_specs_consistent():
    """Declared manifest spec strings must match the actual lowering specs."""
    for name, _fn, in_specs, out_fmt, in_fmts in aot.entries():
        assert len(in_specs) == len(in_fmts), name
        for spec, fmt in zip(in_specs, in_fmts):
            tag, dims = fmt.split(":")
            want_dtype = {"i32": jnp.int32, "f32": jnp.float32}[tag]
            assert spec.dtype == want_dtype, name
            assert tuple(int(d) for d in dims.split("x")) == spec.shape, name
        assert ":" in out_fmt


def test_entry_names_unique():
    names = [e[0] for e in aot.entries()]
    assert len(names) == len(set(names))


def test_lower_small_entry_to_hlo_text():
    for name, fn, in_specs, _of, _if in aot.entries():
        if name == "count_scatter_1024x256":
            text = aot.to_hlo_text(jax.jit(fn).lower(*in_specs))
            assert "ENTRY" in text and "HloModule" in text
            assert "f32[256]" in text  # output key-space width
            return
    raise AssertionError("count_scatter_1024x256 missing from entry table")


def test_output_shape_of_lowered_matches_manifest():
    for name, fn, in_specs, out_fmt, _if in aot.entries():
        if "1024" not in name:
            continue  # keep the test fast: only small entries
        out = jax.eval_shape(fn, *in_specs)
        tag, dims = out_fmt.split(":")
        assert out.shape == tuple(int(d) for d in dims.split("x")), name
        assert out.dtype == {"i32": jnp.int32, "f32": jnp.float32}[tag], name


def test_cli_writes_manifest_and_files():
    with tempfile.TemporaryDirectory() as td:
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", td, "--only", "1024x256"],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        manifest = open(os.path.join(td, "manifest.tsv")).read().strip().splitlines()
        assert len(manifest) >= 2  # count + segsum at least
        for line in manifest:
            name, fname, ins, out = line.split("\t")
            assert "1024x256" in name
            path = os.path.join(td, fname)
            assert os.path.exists(path)
            assert "ENTRY" in open(path).read()
