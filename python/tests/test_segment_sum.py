"""L1 segment-sum kernel vs the pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, segment_sum

SHAPES = [
    (256, 128, 256, 128),
    (1024, 256, 256, 256),
    (2048, 512, 1024, 128),
]


def _run(keys, vals, num_keys, block, k_tile, atol=1e-3):
    got = segment_sum.group_sum(
        jnp.asarray(keys), jnp.asarray(vals), num_keys=num_keys, block=block, k_tile=k_tile
    )
    want = ref.group_sum(jnp.asarray(keys), jnp.asarray(vals), num_keys)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol)
    return np.asarray(got)


@pytest.mark.parametrize("n,num_keys,block,k_tile", SHAPES)
def test_random(n, num_keys, block, k_tile):
    rng = np.random.default_rng(seed=n)
    keys = rng.integers(-1, num_keys, size=n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    _run(keys, vals, num_keys, block, k_tile)


def test_sums_match_total():
    rng = np.random.default_rng(seed=3)
    keys = rng.integers(0, 128, size=512).astype(np.int32)
    vals = rng.random(512).astype(np.float32)
    got = _run(keys, vals, 128, 256, 128)
    np.testing.assert_allclose(got.sum(), vals.sum(), rtol=1e-4)


def test_padding_values_ignored():
    keys = np.full(256, -1, dtype=np.int32)
    keys[0] = 7
    vals = np.full(256, 100.0, dtype=np.float32)
    got = _run(keys, vals, 128, 256, 128)
    assert got[7] == 100.0 and got.sum() == 100.0


def test_negative_and_large_values():
    keys = np.array([1, 1, 2] + [-1] * 253, dtype=np.int32)
    vals = np.array([1e6, -1e6, -0.5] + [9.9] * 253, dtype=np.float32)
    got = _run(keys, vals, 128, 256, 128, atol=1.0)
    assert abs(got[1]) < 1.0 and got[2] == np.float32(-0.5)


def test_value_dtype_is_f32():
    out = segment_sum.group_sum(
        jnp.zeros(256, jnp.int32), jnp.zeros(256, jnp.float32),
        num_keys=128, block=256, k_tile=128,
    )
    assert out.dtype == jnp.float32


@settings(max_examples=30, deadline=None)
@given(
    data=st.lists(
        st.tuples(
            st.integers(min_value=-1, max_value=63),
            st.floats(min_value=-100, max_value=100, width=32),
        ),
        min_size=1,
        max_size=256,
    )
)
def test_hypothesis_pairs(data):
    n = len(data)
    keys = np.full(256, -1, dtype=np.int32)
    vals = np.zeros(256, dtype=np.float32)
    keys[:n] = [k for k, _ in data]
    vals[:n] = [v for _, v in data]
    got = _run(keys, vals, 64, 256, 64, atol=1e-2)
    want = np.zeros(64)
    for k, v in data:
        if k >= 0:
            want[k] += np.float32(v)
    np.testing.assert_allclose(got, want, atol=1e-2)
