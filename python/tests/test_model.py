"""L2 model entry points: scatter family vs oracle, weighted average."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def test_count_scatter_matches_numpy():
    rng = np.random.default_rng(0)
    keys = rng.integers(-1, 512, size=4096).astype(np.int32)
    got = np.asarray(model.count_scatter(jnp.asarray(keys), num_keys=512))
    want = np.zeros(512)
    for k in keys:
        if k >= 0:
            want[k] += 1
    np.testing.assert_array_equal(got, want)


def test_scatter_equals_onehot_family():
    """The two artifact families must be bit-identical on counts."""
    rng = np.random.default_rng(1)
    keys = rng.integers(-1, 256, size=1024).astype(np.int32)
    a = np.asarray(model.count_scatter(jnp.asarray(keys), num_keys=256))
    b = np.asarray(
        model.count_onehot(jnp.asarray(keys), num_keys=256, block=256, k_tile=128)
    )
    np.testing.assert_array_equal(a, b)


def test_segsum_scatter_matches_oracle():
    rng = np.random.default_rng(2)
    keys = rng.integers(-1, 128, size=2048).astype(np.int32)
    vals = rng.normal(size=2048).astype(np.float32)
    got = np.asarray(model.segsum_scatter(jnp.asarray(keys), jnp.asarray(vals), num_keys=128))
    want = np.asarray(ref.group_sum(jnp.asarray(keys), jnp.asarray(vals), 128))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_weighted_average_fold():
    vals = np.array([8.0, 6.0, 9.0], dtype=np.float32)
    wts = np.array([0.5, 0.25, 0.25], dtype=np.float32)
    out = np.asarray(model.weighted_average(jnp.asarray(vals), jnp.asarray(wts)))
    assert out.shape == (2,)
    np.testing.assert_allclose(out[0], 7.75, rtol=1e-6)  # sum(v*w)
    np.testing.assert_allclose(out[1], 1.0, rtol=1e-6)  # sum(w)


@settings(max_examples=25, deadline=None)
@given(
    vw=st.lists(
        st.tuples(
            st.floats(min_value=0, max_value=10, width=32),
            st.floats(min_value=0, max_value=1, width=32),
        ),
        min_size=1,
        max_size=128,
    )
)
def test_hypothesis_weighted_average(vw):
    vals = np.array([v for v, _ in vw], dtype=np.float32)
    wts = np.array([w for _, w in vw], dtype=np.float32)
    out = np.asarray(model.weighted_average(jnp.asarray(vals), jnp.asarray(wts)))
    np.testing.assert_allclose(out[0], np.dot(vals, wts), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out[1], wts.sum(), rtol=1e-4, atol=1e-4)
