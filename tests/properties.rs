//! Seed-driven property tests across the stack (proptest is unavailable
//! offline; `forelem::util::forall_seeds` reports the failing seed).

use forelem::compiler::{CompileOptions, Engine, ReformatMode};
use forelem::ir::{DataType, Multiset, Schema, Value};
use forelem::prelude::*;
use forelem::prop_assert;
use forelem::sched::{Chunk, Policy, Scheduler};
use forelem::storage::{read_rows, temp_path, write_rows, StorageCatalog};
use forelem::util::{forall_seeds, Rng};

/// Random multiset with mixed types.
fn random_multiset(rng: &mut Rng, max_rows: usize) -> Multiset {
    let schema = Schema::new(vec![
        ("k", DataType::Str),
        ("n", DataType::Int),
        ("x", DataType::Float),
        ("b", DataType::Bool),
    ]);
    let rows = 1 + rng.below(max_rows as u64) as usize;
    let keys = 1 + rng.below(32) as usize;
    let mut m = Multiset::new(schema);
    for _ in 0..rows {
        m.push(vec![
            Value::str(format!("key{}", rng.below(keys as u64))),
            Value::Int(rng.range(-1000, 1000)),
            Value::Float((rng.f64() - 0.5) * 100.0),
            Value::Bool(rng.below(2) == 1),
        ]);
    }
    m
}

#[test]
fn row_file_roundtrip_any_multiset() {
    forall_seeds(25, |rng| {
        let m = random_multiset(rng, 200);
        let path = temp_path("prop");
        write_rows(&path, &m).map_err(|e| e.to_string())?;
        let back = read_rows(&path).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        prop_assert!(m.bag_eq(&back), "roundtrip diverged ({} rows)", m.len());
        Ok(())
    });
}

#[test]
fn group_by_pipeline_agrees_across_all_configurations() {
    // For random data + random compile options, the optimized pipeline
    // must equal the plain reference interpreter.
    forall_seeds(20, |rng| {
        let m = random_multiset(rng, 400);
        let mut catalog = StorageCatalog::new();
        catalog.insert_multiset("t", &m).unwrap();
        let q = "SELECT k, COUNT(k) FROM t GROUP BY k";

        let reference = {
            let mut e = Engine::new(catalog.clone());
            let out = e.sql(q).map_err(|e| e.to_string())?;
            out.result().unwrap().clone()
        };

        let processors = 1 + rng.below(8) as usize;
        let reformat = match rng.below(3) {
            0 => ReformatMode::Off,
            1 => ReformatMode::Force,
            _ => ReformatMode::Auto { expected_runs: rng.below(100) },
        };
        let mut e = Engine::new(catalog).with_options(CompileOptions {
            processors,
            partition_field: if rng.below(2) == 1 { Some("k".into()) } else { None },
            reformat,
            optimize: rng.below(2) == 1,
        });
        let compiled = e.compile(q).map_err(|e| e.to_string())?;
        let out = forelem::exec::run(&compiled.program, &e.catalog).map_err(|e| e.to_string())?;
        prop_assert!(
            out.result().unwrap().bag_eq(&reference),
            "processors={processors} reformat={reformat:?}"
        );
        Ok(())
    });
}

#[test]
fn vectorized_interpreter_and_idiom_tiers_agree_on_random_programs() {
    // For random data, the three executor tiers must agree bag-for-bag:
    // the reference interpreter (`exec::run`), the dispatching
    // `run_compiled` (idiom kernels where recognized), and the vectorized
    // batch executor (`run_vectorized`). Shapes the vectorized tier must
    // handle (group/filter/guard/join) are asserted to actually fire.
    forall_seeds(20, |rng| {
        let m = random_multiset(rng, 300);
        let m2 = random_multiset(rng, 80);
        let mut catalog = StorageCatalog::new();
        catalog.insert_multiset("t", &m).unwrap();
        catalog.insert_multiset("u", &m2).unwrap();
        let queries = [
            ("SELECT k, COUNT(k) FROM t GROUP BY k", true),
            ("SELECT k, SUM(x) FROM t GROUP BY k", true),
            ("SELECT k, n FROM t WHERE k = 'key0'", true),
            ("SELECT k FROM t WHERE n > 0", true),
            ("SELECT k, COUNT(k) FROM t WHERE n > 0 GROUP BY k", true),
            // Joins route through the vectorized hash-join kernel now.
            ("SELECT t.k, u.k FROM t JOIN u ON t.n = u.n", true),
        ];
        for (q, expect_vectorized) in queries {
            let p = forelem::sql::compile_sql(q, &catalog.schemas())
                .map_err(|e| e.to_string())?;
            let reference = forelem::exec::run(&p, &catalog).map_err(|e| e.to_string())?;
            let compiled =
                forelem::exec::run_compiled(&p, &catalog, None).map_err(|e| e.to_string())?;
            prop_assert!(
                compiled
                    .result()
                    .unwrap()
                    .bag_eq(reference.result().unwrap()),
                "run_compiled diverged from interpreter for `{q}`"
            );
            match forelem::exec::run_vectorized(&p, &catalog).map_err(|e| e.to_string())? {
                Some(out) => {
                    prop_assert!(
                        out.result().unwrap().bag_eq(reference.result().unwrap()),
                        "vectorized diverged from interpreter for `{q}`"
                    );
                    prop_assert!(
                        out.stats.idioms.contains(&"vectorized".to_string()),
                        "vectorized output missing tier tag for `{q}`"
                    );
                }
                None => {
                    prop_assert!(
                        !expect_vectorized,
                        "vectorized tier unexpectedly skipped `{q}`"
                    );
                }
            }
        }
        Ok(())
    });
}

/// Random pair of joinable tables: `A(b_id, g, w)` probes `B(id, tag, v)`
/// on `b_id = id`, with key ranges narrow enough that matches (including
/// multiplicities > 1) are common.
fn random_join_tables(rng: &mut Rng) -> (Multiset, Multiset) {
    let arows = 1 + rng.below(300) as usize;
    let brows = 1 + rng.below(120) as usize;
    let keys = 1 + rng.below(40) as i64;
    let mut a = Multiset::new(Schema::new(vec![
        ("b_id", DataType::Int),
        ("g", DataType::Str),
        ("w", DataType::Float),
    ]));
    for _ in 0..arows {
        a.push(vec![
            Value::Int(rng.range(0, keys)),
            Value::str(format!("g{}", rng.below(8))),
            Value::Float((rng.f64() - 0.5) * 10.0),
        ]);
    }
    let mut b = Multiset::new(Schema::new(vec![
        ("id", DataType::Int),
        ("tag", DataType::Str),
        ("v", DataType::Float),
    ]));
    for _ in 0..brows {
        b.push(vec![
            Value::Int(rng.range(0, keys)),
            Value::str(format!("t{}", rng.below(6))),
            Value::Float((rng.f64() - 0.5) * 10.0),
        ]);
    }
    (a, b)
}

#[test]
fn hash_join_three_tiers_agree_on_random_joins() {
    // For random joinable tables, plain joins and join + GROUP BY
    // aggregates must agree bag-for-bag across the reference interpreter,
    // the dispatching `run_compiled`, and the vectorized tier — and the
    // vectorized tier must actually fire its hash-join kernel.
    forall_seeds(15, |rng| {
        let (a, b) = random_join_tables(rng);
        let mut catalog = StorageCatalog::new();
        catalog.insert_multiset("A", &a).unwrap();
        catalog.insert_multiset("B", &b).unwrap();
        let queries = [
            "SELECT A.g, B.tag FROM A JOIN B ON A.b_id = B.id",
            "SELECT A.g, B.v FROM A JOIN B ON A.b_id = B.id WHERE B.v > 0.0",
            "SELECT g, COUNT(g) FROM A JOIN B ON A.b_id = B.id GROUP BY g",
            "SELECT tag, COUNT(tag) FROM A JOIN B ON A.b_id = B.id GROUP BY tag",
            "SELECT g, SUM(v) FROM A JOIN B ON A.b_id = B.id GROUP BY g",
            "SELECT g, SUM(w) FROM A JOIN B ON A.b_id = B.id GROUP BY g",
        ];
        for q in queries {
            let p = forelem::sql::compile_sql(q, &catalog.schemas())
                .map_err(|e| e.to_string())?;
            let reference = forelem::exec::run(&p, &catalog).map_err(|e| e.to_string())?;
            let compiled =
                forelem::exec::run_compiled(&p, &catalog, None).map_err(|e| e.to_string())?;
            prop_assert!(
                compiled
                    .result()
                    .unwrap()
                    .bag_eq(reference.result().unwrap()),
                "run_compiled diverged from interpreter for `{q}`"
            );
            let out = forelem::exec::run_vectorized(&p, &catalog)
                .map_err(|e| e.to_string())?
                .ok_or_else(|| format!("vectorized tier skipped join `{q}`"))?;
            prop_assert!(
                out.result().unwrap().bag_eq(reference.result().unwrap()),
                "vectorized diverged from interpreter for `{q}`"
            );
            prop_assert!(
                out.stats.idioms.contains(&"vec.hash_join".to_string()),
                "`{q}` missing vec.hash_join tag: {:?}",
                out.stats.idioms
            );
        }
        // The COUNT aggregate must also survive the parallel driver.
        let p = forelem::sql::compile_sql(
            "SELECT g, COUNT(g) FROM A JOIN B ON A.b_id = B.id GROUP BY g",
            &catalog.schemas(),
        )
        .map_err(|e| e.to_string())?;
        let reference = forelem::exec::run(&p, &catalog).map_err(|e| e.to_string())?;
        let threads = 1 + rng.below(8) as usize;
        let par = forelem::exec::run_parallel(&p, &catalog, threads)
            .map_err(|e| e.to_string())?;
        prop_assert!(
            par.result().unwrap().bag_eq(reference.result().unwrap()),
            "run_parallel diverged on the join aggregate (threads={threads})"
        );
        Ok(())
    });
}

/// Random star/snowflake fixtures for the N-way chain property: a fact
/// `F(d_id, e_id, n)` with two star arms `D(id, g_id, tag)` and
/// `E(id, name)`, plus a snowflake hop `G(id, label)` off `D`. Key
/// ranges are narrow so matches (with multiplicities) are common, and
/// dangling fact keys exist too.
fn random_star_tables(rng: &mut Rng) -> [(&'static str, Multiset); 4] {
    let frows = 1200 + rng.below(1200) as usize;
    let dkeys = 1 + rng.below(48) as i64;
    let ekeys = 1 + rng.below(24) as i64;
    let gkeys = 1 + rng.below(12) as i64;
    let mut f = Multiset::new(Schema::new(vec![
        ("d_id", DataType::Int),
        ("e_id", DataType::Int),
        ("n", DataType::Int),
    ]));
    for _ in 0..frows {
        f.push(vec![
            Value::Int(rng.range(0, dkeys * 2)),
            Value::Int(rng.range(0, ekeys * 2)),
            Value::Int(rng.range(-20, 20)),
        ]);
    }
    let mut d = Multiset::new(Schema::new(vec![
        ("id", DataType::Int),
        ("g_id", DataType::Int),
        ("tag", DataType::Str),
    ]));
    for _ in 0..1 + rng.below(60) {
        d.push(vec![
            Value::Int(rng.range(0, dkeys)),
            Value::Int(rng.range(0, gkeys)),
            Value::str(format!("t{}", rng.below(6))),
        ]);
    }
    let mut e = Multiset::new(Schema::new(vec![
        ("id", DataType::Int),
        ("name", DataType::Str),
    ]));
    for _ in 0..1 + rng.below(30) {
        e.push(vec![
            Value::Int(rng.range(0, ekeys)),
            Value::str(format!("e{}", rng.below(5))),
        ]);
    }
    let mut g = Multiset::new(Schema::new(vec![
        ("id", DataType::Int),
        ("label", DataType::Str),
    ]));
    for _ in 0..1 + rng.below(15) {
        g.push(vec![
            Value::Int(rng.range(0, gkeys)),
            Value::str(format!("g{}", rng.below(4))),
        ]);
    }
    [("F", f), ("D", d), ("E", e), ("G", g)]
}

#[test]
fn n_way_join_chains_agree_across_tiers_orders_and_policies() {
    // Star and snowflake chains of 3-4 tables: the reference interpreter,
    // the tier dispatch, and the vectorized multi-level hash join must
    // agree bag-for-bag — before AND after the Selinger join-order DP —
    // and the optimized plan must carry both the `vec.hash_join` kernel
    // tag and the `opt.join_order` decision. Aggregates stick to COUNT /
    // integer SUM (a reorder reassociates float folds by design), and the
    // morsel driver is held to the same bags for every scheduling policy.
    forall_seeds(8, |rng| {
        let mut catalog = StorageCatalog::new();
        for (name, m) in random_star_tables(rng) {
            catalog.insert_multiset(name, &m).unwrap();
        }
        let queries = [
            // Star, fact-first: projection and aggregates.
            "SELECT D.tag, E.name FROM F JOIN D ON F.d_id = D.id JOIN E ON F.e_id = E.id",
            "SELECT tag, COUNT(tag) FROM F JOIN D ON F.d_id = D.id \
             JOIN E ON F.e_id = E.id GROUP BY tag",
            // Snowflake: G keys on D's cursor, not the fact.
            "SELECT label, COUNT(label) FROM F JOIN D ON F.d_id = D.id \
             JOIN G ON D.g_id = G.id GROUP BY label",
            // Four tables, star + snowflake arms combined.
            "SELECT tag, SUM(n) FROM F JOIN D ON F.d_id = D.id \
             JOIN E ON F.e_id = E.id JOIN G ON D.g_id = G.id GROUP BY tag",
            // Dimension-first: the written order hashes the fact, the DP
            // usually flips it — results must not move either way.
            "SELECT tag, COUNT(tag) FROM D JOIN F ON D.id = F.d_id \
             JOIN E ON F.e_id = E.id GROUP BY tag",
        ];
        for q in queries {
            let p0 = forelem::sql::compile_sql(q, &catalog.schemas())
                .map_err(|e| e.to_string())?;
            let reference = forelem::exec::run(&p0, &catalog).map_err(|e| e.to_string())?;
            let off = forelem::exec::run_compiled(&p0, &catalog, None)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                off.result().unwrap().bag_eq(reference.result().unwrap()),
                "run_compiled diverged from interpreter for `{q}`"
            );
            let vec_out = forelem::exec::run_vectorized(&p0, &catalog)
                .map_err(|e| e.to_string())?
                .ok_or_else(|| format!("vectorized tier skipped chain `{q}`"))?;
            prop_assert!(
                vec_out.result().unwrap().bag_eq(reference.result().unwrap()),
                "vectorized diverged from interpreter for `{q}`"
            );
            prop_assert!(
                vec_out.stats.idioms.contains(&"vec.hash_join".to_string()),
                "`{q}` missing vec.hash_join: {:?}",
                vec_out.stats.idioms
            );

            // Optimized: the DP always records its decision on a chain
            // (as written or reordered), and semantics must not move.
            let mut p1 = p0.clone();
            let report =
                forelem::opt::optimize(&mut p1, &catalog).map_err(|e| e.to_string())?;
            prop_assert!(
                report.has("opt.join_order"),
                "`{q}` should decide a join order: {report:?}"
            );
            let interp_opt = forelem::exec::run(&p1, &catalog).map_err(|e| e.to_string())?;
            prop_assert!(
                interp_opt.result().unwrap().bag_eq(reference.result().unwrap()),
                "`{q}`: interpreter(optimized) diverged"
            );
            let on = forelem::exec::run_compiled(&p1, &catalog, None)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                on.result().unwrap().bag_eq(reference.result().unwrap()),
                "`{q}`: run_compiled(optimized) diverged"
            );
            for tag in ["vec.hash_join", "opt.join_order"] {
                prop_assert!(
                    on.stats.idioms.contains(&tag.to_string()),
                    "`{q}` missing `{tag}` on the optimized plan: {:?}",
                    on.stats.idioms
                );
            }

            // Morsel driver: every policy, random threads, both orders.
            for policy in Policy::ALL {
                let threads = 2 + rng.below(7) as usize;
                for p in [&p0, &p1] {
                    let par = forelem::exec::run_parallel_with_policy(
                        p, &catalog, threads, policy,
                    )
                    .map_err(|e| e.to_string())?;
                    prop_assert!(
                        par.result().unwrap().bag_eq(reference.result().unwrap()),
                        "`{q}` diverged under {policy:?} (threads={threads})"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn optimizer_on_off_and_interpreter_agree_on_random_programs() {
    // For random data, the cost-based optimizer must be invisible in the
    // results: optimizer-on vs optimizer-off vs the reference interpreter
    // are bag_eq-identical across scan / filter / join / group-by shapes
    // — including the swapped-build-side join path (the small table is
    // always written FIRST here, so `opt.join_build_side` must swap the
    // nest). Join aggregates stick to COUNT / integer SUM: the swap
    // reassociates float folds by design.
    forall_seeds(12, |rng| {
        let srows = 1 + rng.below(60) as usize;
        let brows = 600 + rng.below(900) as usize;
        let keys = 1 + rng.below(80) as i64;
        let mut small = Multiset::new(Schema::new(vec![
            ("id", DataType::Int),
            ("g", DataType::Str),
            ("w", DataType::Float),
        ]));
        for _ in 0..srows {
            small.push(vec![
                Value::Int(rng.range(0, keys)),
                Value::str(format!("g{}", rng.below(9))),
                Value::Float((rng.f64() - 0.5) * 10.0),
            ]);
        }
        let mut big = Multiset::new(Schema::new(vec![
            ("a_id", DataType::Int),
            ("n", DataType::Int),
        ]));
        for _ in 0..brows {
            big.push(vec![
                Value::Int(rng.range(0, keys)),
                Value::Int(rng.range(-20, 20)),
            ]);
        }
        let scan = random_multiset(rng, 300);
        let mut catalog = StorageCatalog::new();
        catalog.insert_multiset("small", &small).unwrap();
        catalog.insert_multiset("big", &big).unwrap();
        catalog.insert_multiset("t", &scan).unwrap();

        let queries = [
            // Scan / filter / group-by shapes (exercise strategy and
            // filter-reorder decisions).
            ("SELECT k, COUNT(k) FROM t GROUP BY k", false),
            ("SELECT k FROM t WHERE n > 0 AND x < 10.0", false),
            ("SELECT k, COUNT(k) FROM t WHERE n > 0 AND x < 10.0 GROUP BY k", false),
            // Join shapes: small written first → the optimizer must swap.
            ("SELECT small.g, big.n FROM small JOIN big ON small.id = big.a_id", true),
            ("SELECT g, COUNT(g) FROM small JOIN big ON small.id = big.a_id GROUP BY g", true),
            ("SELECT g, SUM(n) FROM small JOIN big ON small.id = big.a_id GROUP BY g", true),
        ];
        for (q, is_join) in queries {
            let p0 = forelem::sql::compile_sql(q, &catalog.schemas())
                .map_err(|e| e.to_string())?;
            let reference = forelem::exec::run(&p0, &catalog).map_err(|e| e.to_string())?;
            let mut p1 = p0.clone();
            let report =
                forelem::opt::optimize(&mut p1, &catalog).map_err(|e| e.to_string())?;
            if is_join {
                prop_assert!(
                    report.has("opt.join_build_side"),
                    "`{q}` should decide a build side: {report:?}"
                );
            }
            // Interpreter on the optimized program.
            let interp_opt = forelem::exec::run(&p1, &catalog).map_err(|e| e.to_string())?;
            prop_assert!(
                interp_opt.result().unwrap().bag_eq(reference.result().unwrap()),
                "`{q}`: interpreter(optimized) diverged"
            );
            // Tier dispatch on optimized and unoptimized programs.
            let on = forelem::exec::run_compiled(&p1, &catalog, None)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                on.result().unwrap().bag_eq(reference.result().unwrap()),
                "`{q}`: run_compiled(optimized) diverged"
            );
            let off = forelem::exec::run_compiled(&p0, &catalog, None)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                off.result().unwrap().bag_eq(reference.result().unwrap()),
                "`{q}`: run_compiled(unoptimized) diverged"
            );
            if is_join {
                prop_assert!(
                    on.stats.idioms.contains(&"vec.hash_join".to_string()),
                    "`{q}`: swapped join must stay on the hash-join kernel: {:?}",
                    on.stats.idioms
                );
                prop_assert!(
                    on.stats.idioms.contains(&"opt.join_build_side".to_string()),
                    "`{q}`: decision tag must surface in ExecStats: {:?}",
                    on.stats.idioms
                );
            }
        }
        Ok(())
    });
}

#[test]
fn morsel_parallel_scans_match_interpreter_across_policies() {
    // Scan/filter/group-by programs must produce interpreter-identical
    // bags under the morsel-driven parallel driver for every scheduling
    // policy and random thread counts. Aggregates stick to integer
    // accumulation so the bags are exact under any worker merge order
    // (float folds may reorder across workers by design).
    forall_seeds(6, |rng| {
        // More rows than the spin-up gate (PARALLEL_SPINUP_ROWS = 4096)
        // so the morsel driver engages.
        let rows = 4200 + rng.below(1800) as usize;
        let keys = 1 + rng.below(24);
        let mut m = Multiset::new(Schema::new(vec![
            ("k", DataType::Str),
            ("n", DataType::Int),
        ]));
        for _ in 0..rows {
            m.push(vec![
                Value::str(format!("key{}", rng.below(keys))),
                Value::Int(rng.range(-50, 50)),
            ]);
        }
        let mut catalog = StorageCatalog::new();
        catalog.insert_multiset("t", &m).unwrap();
        let queries = [
            "SELECT k, COUNT(k) FROM t GROUP BY k",
            "SELECT k, SUM(n) FROM t GROUP BY k",
            "SELECT k, n FROM t WHERE k = 'key0'",
            "SELECT k FROM t WHERE n > 0",
            "SELECT k, COUNT(k) FROM t WHERE n > 0 GROUP BY k",
        ];
        let policies = [
            Policy::StaticBlock,
            Policy::FixedChunk(1 + rng.below(512) as usize),
            Policy::Gss,
            Policy::Trapezoid,
            Policy::Factoring,
            Policy::FeedbackGuided,
            Policy::Hybrid {
                super_chunks_per_worker: 1 + rng.below(4) as usize,
            },
        ];
        for q in queries {
            let p = forelem::sql::compile_sql(q, &catalog.schemas())
                .map_err(|e| e.to_string())?;
            let reference = forelem::exec::run(&p, &catalog).map_err(|e| e.to_string())?;
            for policy in policies {
                let threads = 2 + rng.below(7) as usize;
                let par =
                    forelem::exec::run_parallel_with_policy(&p, &catalog, threads, policy)
                        .map_err(|e| e.to_string())?;
                prop_assert!(
                    par.result().unwrap().bag_eq(reference.result().unwrap()),
                    "`{q}` diverged under {policy:?} (threads={threads})"
                );
                prop_assert!(
                    par.stats.idioms.contains(&"vec.morsel".to_string()),
                    "`{q}` did not take the morsel path under {policy:?}: {:?}",
                    par.stats.idioms
                );
                let tag = format!("sched.{}", policy.name());
                prop_assert!(
                    par.stats.idioms.contains(&tag),
                    "`{q}` missing `{tag}` under {policy:?}: {:?}",
                    par.stats.idioms
                );
            }
        }
        Ok(())
    });
}

#[test]
fn simd_kernels_agree_across_remainders_policies_and_affinity() {
    // The SIMD-shaped kernels (branchless selection building, striped
    // integer accumulators) must be invisible in the results: bag_eq
    // with the interpreter at every final-batch remainder length
    // (n mod LANES ∈ {0, 1, LANES−1} — BATCH is a LANES multiple, so
    // whole extra batches keep the remainder intact), under every
    // scheduling policy, with chunk-affinity on and off. Float sums are
    // checked ROW-identical sequentially: the sequential tier never
    // stripes floats, so its fold order — and every last bit — matches
    // the interpreter.
    let lanes = forelem::exec::LANES;
    let batch = forelem::exec::BATCH;
    assert_eq!(batch % lanes, 0, "BATCH must stay a LANES multiple");
    forall_seeds(3, |rng| {
        for rem in [0, 1, lanes - 1] {
            // > PARALLEL_SPINUP_ROWS so the morsel driver engages.
            let rows = (5 + rng.below(3) as usize) * batch + rem;
            let keys = 1 + rng.below(40);
            let mut m = Multiset::new(Schema::new(vec![
                ("k", DataType::Str),
                ("n", DataType::Int),
                ("x", DataType::Float),
            ]));
            for _ in 0..rows {
                m.push(vec![
                    Value::str(format!("key{}", rng.below(keys))),
                    Value::Int(rng.range(-50, 50)),
                    Value::Float((rng.f64() - 0.5) * 10.0),
                ]);
            }
            let mut t = forelem::storage::Table::from_multiset(&m).map_err(|e| e.to_string())?;
            t.dict_encode_field(0).map_err(|e| e.to_string())?;
            let mut catalog = StorageCatalog::new();
            catalog.insert("t", t);

            // Integer-exact kernels: striped count/sum and the branchless
            // dict-code equality filter, sequential then parallel.
            let queries = [
                "SELECT k, COUNT(k) FROM t GROUP BY k",
                "SELECT k, SUM(n) FROM t GROUP BY k",
                "SELECT k, n FROM t WHERE k = 'key0'",
            ];
            for q in queries {
                let p = forelem::sql::compile_sql(q, &catalog.schemas())
                    .map_err(|e| e.to_string())?;
                let reference = forelem::exec::run(&p, &catalog).map_err(|e| e.to_string())?;
                let out = forelem::exec::run_vectorized(&p, &catalog)
                    .map_err(|e| e.to_string())?
                    .ok_or_else(|| format!("vectorized tier skipped `{q}`"))?;
                prop_assert!(
                    out.result().unwrap().bag_eq(reference.result().unwrap()),
                    "`{q}` diverged sequentially (rows={rows}, rem={rem})"
                );
                prop_assert!(
                    out.stats.idioms.contains(&"vec.simd".to_string()),
                    "`{q}` missing `vec.simd` (rows={rows}): {:?}",
                    out.stats.idioms
                );
                for policy in Policy::ALL {
                    for affinity in [false, true] {
                        let threads = 2 + rng.below(7) as usize;
                        let par = forelem::exec::run_parallel_with_opts(
                            &p, &catalog, threads, policy, affinity,
                        )
                        .map_err(|e| e.to_string())?;
                        prop_assert!(
                            par.result().unwrap().bag_eq(reference.result().unwrap()),
                            "`{q}` diverged under {policy:?} (threads={threads}, \
                             affinity={affinity}, rows={rows}, rem={rem})"
                        );
                        prop_assert!(
                            par.stats.idioms.contains(&"vec.simd".to_string()),
                            "`{q}` lost `vec.simd` under {policy:?} (affinity={affinity}): {:?}",
                            par.stats.idioms
                        );
                    }
                }
            }

            // Float sums: never striped, so the sequential vectorized tier
            // must reproduce the interpreter's fold order bit-for-bit.
            let pf = forelem::sql::compile_sql(
                "SELECT k, SUM(x) FROM t GROUP BY k",
                &catalog.schemas(),
            )
            .map_err(|e| e.to_string())?;
            let reference = forelem::exec::run(&pf, &catalog).map_err(|e| e.to_string())?;
            let out = forelem::exec::run_vectorized(&pf, &catalog)
                .map_err(|e| e.to_string())?
                .ok_or_else(|| "vectorized tier skipped the float sum".to_string())?;
            let float_rows = |o: &forelem::exec::Output| {
                let mut v: Vec<(String, u64)> = o
                    .result()
                    .unwrap()
                    .rows()
                    .iter()
                    .map(|r| (r[0].to_string(), r[1].as_float().unwrap().to_bits()))
                    .collect();
                v.sort();
                v
            };
            prop_assert!(
                float_rows(&out) == float_rows(&reference),
                "float sums must be row-identical sequentially (rows={rows}, rem={rem})"
            );
        }
        Ok(())
    });
}

#[test]
fn ineligible_bodies_stay_on_the_sequential_driver() {
    // Prints and scalar writes are order-dependent effects the worker
    // merge cannot reproduce: such bodies must run sequentially (exact
    // print order and scalar values) and never tag the morsel path.
    use forelem::ir::{Expr, IndexSet, Loop, Program, Stmt};
    let mut m = Multiset::new(Schema::new(vec![
        ("k", DataType::Str),
        ("n", DataType::Int),
    ]));
    let mut rng = Rng::new(77);
    for _ in 0..2_000 {
        m.push(vec![
            Value::str(format!("key{}", rng.below(8))),
            Value::Int(rng.range(-50, 50)),
        ]);
    }
    let mut catalog = StorageCatalog::new();
    catalog.insert_multiset("t", &m).unwrap();

    let mut printer = Program::new("printer")
        .with_relation("t", catalog.schemas()["t"].clone());
    printer.body = vec![Stmt::Loop(Loop::forelem(
        "i",
        IndexSet::all("t"),
        vec![Stmt::Print {
            format: "{}".into(),
            args: vec![Expr::field("i", "k")],
        }],
    ))];
    let reference = forelem::exec::run(&printer, &catalog).unwrap();
    let par = forelem::exec::run_parallel(&printer, &catalog, 8).unwrap();
    assert_eq!(par.prints, reference.prints, "print order must be sequential");
    assert!(
        !par.stats.idioms.contains(&"vec.morsel".to_string()),
        "print body must not fan out: {:?}",
        par.stats.idioms
    );

    let mut assigner = Program::new("assigner")
        .with_relation("t", catalog.schemas()["t"].clone())
        .with_scalar("last", Value::Int(0));
    assigner.body = vec![Stmt::Loop(Loop::forelem(
        "i",
        IndexSet::all("t"),
        vec![Stmt::assign("last", Expr::field("i", "n"))],
    ))];
    let reference = forelem::exec::run(&assigner, &catalog).unwrap();
    let par = forelem::exec::run_parallel(&assigner, &catalog, 8).unwrap();
    assert_eq!(par.scalars, reference.scalars, "scalar writes must be sequential");
    assert!(
        !par.stats.idioms.contains(&"vec.morsel".to_string()),
        "scalar-writing body must not fan out: {:?}",
        par.stats.idioms
    );
}

#[test]
fn top_k_emission_agrees_across_tiers_policies_and_threads() {
    // ORDER BY count LIMIT k lowered into the IR: the vectorized
    // `vec.topk` bounded-heap kernel, the tier dispatch, and the morsel
    // driver's per-worker-heap + k-way merge must all equal the reference
    // interpreter's full-sort prefix — row-identical here (tie-breaking
    // is pinned to emission order in every tier), and additionally
    // checked against a sort-the-full-aggregate oracle with ties handled
    // as a set.
    forall_seeds(8, |rng| {
        let keys = 1 + rng.below(48) as u64;
        let rows = 200 + rng.below(3000) as usize;
        let mut m = Multiset::new(Schema::new(vec![("k", DataType::Str)]));
        for _ in 0..rows {
            m.push(vec![Value::str(format!("key{}", rng.below(keys)))]);
        }
        let mut catalog = StorageCatalog::new();
        catalog.insert_multiset("t", &m).unwrap();
        let k = rng.below(12) as usize;
        let desc = rng.below(2) == 1;
        let dir = if desc { "DESC" } else { "ASC" };
        let q = format!("SELECT k, COUNT(k) AS c FROM t GROUP BY k ORDER BY c {dir} LIMIT {k}");
        let p = forelem::sql::compile_sql(&q, &catalog.schemas()).map_err(|e| e.to_string())?;
        let reference = forelem::exec::run(&p, &catalog).map_err(|e| e.to_string())?;
        let ref_rows = reference.result().unwrap().rows();

        // Oracle: full aggregate, sorted by count, truncated; the count
        // sequence must match exactly and each emitted key must carry
        // its true count (ties as a set: any tied key is acceptable).
        let full_q = "SELECT k, COUNT(k) AS c FROM t GROUP BY k";
        let full = forelem::exec::run(
            &forelem::sql::compile_sql(full_q, &catalog.schemas()).unwrap(),
            &catalog,
        )
        .map_err(|e| e.to_string())?;
        let mut counts: Vec<i64> = full
            .result()
            .unwrap()
            .rows()
            .iter()
            .map(|r| r[1].as_int().unwrap())
            .collect();
        counts.sort_unstable();
        if desc {
            counts.reverse();
        }
        counts.truncate(k);
        let got_counts: Vec<i64> = ref_rows.iter().map(|r| r[1].as_int().unwrap()).collect();
        prop_assert!(
            got_counts == counts,
            "`{q}`: prefix counts {got_counts:?} != oracle {counts:?}"
        );
        let true_count: std::collections::HashMap<String, i64> = full
            .result()
            .unwrap()
            .rows()
            .iter()
            .map(|r| (r[0].to_string(), r[1].as_int().unwrap()))
            .collect();
        for r in ref_rows {
            prop_assert!(
                true_count[&r[0].to_string()] == r[1].as_int().unwrap(),
                "`{q}`: emitted key carries a wrong count"
            );
        }

        // Vectorized tier: row-identical, and the topk kernel fires.
        let vec_out = forelem::exec::run_vectorized(&p, &catalog)
            .map_err(|e| e.to_string())?
            .ok_or_else(|| format!("vectorized tier skipped `{q}`"))?;
        prop_assert!(
            vec_out.result().unwrap().rows() == ref_rows,
            "`{q}`: vectorized emission diverged"
        );
        prop_assert!(
            vec_out.stats.idioms.contains(&"vec.topk".to_string()),
            "`{q}`: missing vec.topk tag: {:?}",
            vec_out.stats.idioms
        );

        // Tier dispatch (must skip the unordered idiom kernels).
        let dispatched =
            forelem::exec::run_compiled(&p, &catalog, None).map_err(|e| e.to_string())?;
        prop_assert!(
            dispatched.result().unwrap().rows() == ref_rows,
            "`{q}`: run_compiled diverged"
        );

        // Optimizer on: the topk strategy decision surfaces in the tags.
        let mut opt_p = p.clone();
        forelem::opt::optimize(&mut opt_p, &catalog).map_err(|e| e.to_string())?;
        let opt_out =
            forelem::exec::run_compiled(&opt_p, &catalog, None).map_err(|e| e.to_string())?;
        prop_assert!(
            opt_out.result().unwrap().rows() == ref_rows,
            "`{q}`: optimized plan diverged"
        );
        prop_assert!(
            opt_out.stats.idioms.iter().any(|t| t.starts_with("opt.topk_")),
            "`{q}`: missing opt.topk_* tag: {:?}",
            opt_out.stats.idioms
        );

        // Morsel driver: every policy × random threads, row-identical.
        for policy in Policy::ALL {
            let threads = 2 + rng.below(7) as usize;
            let par = forelem::exec::run_parallel_with_policy(&p, &catalog, threads, policy)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                par.result().unwrap().rows() == ref_rows,
                "`{q}` diverged under {policy:?} (threads={threads})"
            );
        }
        Ok(())
    });
}

#[test]
fn sum_aggregate_matches_scalar_fold() {
    forall_seeds(15, |rng| {
        let m = random_multiset(rng, 300);
        let mut catalog = StorageCatalog::new();
        catalog.insert_multiset("t", &m).unwrap();
        let mut e = Engine::new(catalog);
        let out = e
            .sql("SELECT k, SUM(x) FROM t GROUP BY k")
            .map_err(|e| e.to_string())?;
        // Oracle: plain fold over the multiset.
        let mut want: std::collections::HashMap<String, f64> = Default::default();
        for r in m.rows() {
            *want.entry(r[0].to_string()).or_default() += r[2].as_float().unwrap();
        }
        let result = out.result().unwrap();
        prop_assert!(result.len() == want.len(), "group count mismatch");
        for r in result.rows() {
            let k = r[0].to_string();
            let got = r[1].as_float().unwrap();
            prop_assert!(
                (want[&k] - got).abs() < 1e-6,
                "key {k}: {got} vs {}",
                want[&k]
            );
        }
        Ok(())
    });
}

#[test]
fn schedulers_cover_exactly_once_under_random_failure_patterns() {
    forall_seeds(40, |rng| {
        let n = 1 + rng.below(5000) as usize;
        let workers = 1 + rng.below(12) as usize;
        let policies = [
            Policy::FixedChunk(1 + rng.below(512) as usize),
            Policy::Gss,
            Policy::Trapezoid,
            Policy::Factoring,
            Policy::FeedbackGuided,
            Policy::Hybrid {
                super_chunks_per_worker: 1 + rng.below(6) as usize,
            },
        ];
        let policy = policies[rng.below(policies.len() as u64) as usize];
        let mut s = Scheduler::new(policy, n, workers);
        let mut seen = vec![false; n];
        let mut held: Vec<Chunk> = Vec::new();
        let mut w = 0usize;
        loop {
            // Occasionally "fail": requeue a held chunk instead of
            // completing it.
            if !held.is_empty() && rng.below(4) == 0 {
                let c = held.swap_remove(rng.below(held.len() as u64) as usize);
                s.requeue(c);
                continue;
            }
            match s.next_chunk(w % workers) {
                Some(c) => {
                    if rng.below(5) == 0 {
                        held.push(c); // in flight, may be failed later
                    } else {
                        for i in c.lo..c.hi {
                            prop_assert!(!seen[i], "{policy:?}: iteration {i} twice");
                            seen[i] = true;
                        }
                    }
                    w += 1;
                }
                None => {
                    if held.is_empty() {
                        break;
                    }
                    // Complete remaining held chunks.
                    for c in held.drain(..) {
                        for i in c.lo..c.hi {
                            prop_assert!(!seen[i], "{policy:?}: iteration {i} twice");
                            seen[i] = true;
                        }
                    }
                }
            }
        }
        prop_assert!(
            seen.iter().all(|&b| b),
            "{policy:?}: not all iterations issued (n={n}, workers={workers})"
        );
        Ok(())
    });
}

#[test]
fn dict_encoding_is_lossless_for_any_string_column() {
    forall_seeds(20, |rng| {
        let m = random_multiset(rng, 300);
        let mut t = forelem::storage::Table::from_multiset(&m).unwrap();
        t.dict_encode_field(0).map_err(|e| e.to_string())?;
        for row in 0..t.len() {
            prop_assert!(
                t.value(row, 0) == *m.get(row, 0),
                "row {row} changed after encoding"
            );
        }
        Ok(())
    });
}

#[test]
fn transform_pipeline_never_invalidates_programs() {
    use forelem::transform::{run_to_fixpoint, Pass, PassCtx};
    forall_seeds(15, |rng| {
        let m = random_multiset(rng, 100);
        let mut catalog = StorageCatalog::new();
        catalog.insert_multiset("t", &m).unwrap();
        let queries = [
            "SELECT k, COUNT(k) FROM t GROUP BY k",
            "SELECT k FROM t WHERE n > 0",
            "SELECT k, n FROM t WHERE k = 'key0' AND n < 100",
            "SELECT k, SUM(x) AS s, AVG(n) FROM t GROUP BY k",
        ];
        let q = queries[rng.below(queries.len() as u64) as usize];
        let mut p =
            forelem::sql::compile_sql(q, &catalog.schemas()).map_err(|e| e.to_string())?;
        let reference = forelem::exec::run(&p, &catalog).map_err(|e| e.to_string())?;

        let passes = forelem::transform::standard_pipeline();
        let refs: Vec<&dyn Pass> = passes.iter().map(|b| b.as_ref()).collect();
        let ctx = PassCtx::new()
            .with_catalog(&catalog)
            .with_processors(1 + rng.below(4) as usize);
        run_to_fixpoint(&mut p, &refs, &ctx, 4).map_err(|e| e.to_string())?;
        validate(&p).map_err(|e| format!("invalid after pipeline: {e}"))?;
        let out = forelem::exec::run(&p, &catalog).map_err(|e| e.to_string())?;
        prop_assert!(
            out.result().unwrap().bag_eq(reference.result().unwrap()),
            "pipeline changed semantics for `{q}`"
        );
        Ok(())
    });
}

#[test]
fn compressed_and_raw_storage_agree_across_tiers_and_policies() {
    // Build the same logical table twice — raw columns vs compressed
    // storage (dict-encoded strings + RLE integers) — and require every
    // execution tier and every scheduling policy to reproduce the raw
    // interpreter's bags exactly, with the compressed-domain kernels
    // actually firing and the optimizer recording the code-domain choice.
    forall_seeds(6, |rng| {
        let rows = 1200 + rng.below(2400) as usize;
        // Runs of >= 8 rows keep the RLE layout profitable for any size.
        let run = 8 + rng.below(200) as usize;
        let keys = 1 + rng.below(12) as u64;
        let mut m = Multiset::new(Schema::new(vec![
            ("k", DataType::Str),
            ("code", DataType::Int),
            ("n", DataType::Int),
        ]));
        for i in 0..rows {
            m.push(vec![
                Value::str(format!("key{}", rng.below(keys))),
                Value::Int((i / run) as i64 % 7),
                Value::Int(rng.range(-50, 50)),
            ]);
        }
        let mut raw = StorageCatalog::new();
        raw.insert_multiset("t", &m).unwrap();
        let mut t = forelem::storage::Table::from_multiset(&m).unwrap();
        t.dict_encode_field(0).map_err(|e| e.to_string())?;
        let packed_code = t.compress_int_field(1).map_err(|e| e.to_string())?;
        prop_assert!(packed_code, "runny code column should compress (rows={rows}, run={run})");
        let mut packed = StorageCatalog::new();
        packed.insert("t", t);

        let queries = [
            ("SELECT k, n FROM t WHERE k = 'key0'", "vec.dict_filter"),
            ("SELECT n FROM t WHERE code = 3", "vec.rle_filter"),
            ("SELECT code, COUNT(code) FROM t GROUP BY code", "vec.rle_agg"),
            ("SELECT code, SUM(n) FROM t GROUP BY code", "vec.rle_agg"),
        ];
        for (q, tag) in queries {
            // Schemas are storage-transparent: one program serves both.
            let p = forelem::sql::compile_sql(q, &raw.schemas()).map_err(|e| e.to_string())?;
            let reference = forelem::exec::run(&p, &raw).map_err(|e| e.to_string())?;

            let interp = forelem::exec::run(&p, &packed).map_err(|e| e.to_string())?;
            prop_assert!(
                interp.result().unwrap().bag_eq(reference.result().unwrap()),
                "`{q}`: interpreter diverged on compressed storage"
            );
            let dispatched = forelem::exec::run_compiled(&p, &packed, None)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                dispatched.result().unwrap().bag_eq(reference.result().unwrap()),
                "`{q}`: run_compiled diverged on compressed storage"
            );
            let out = forelem::exec::run_vectorized(&p, &packed)
                .map_err(|e| e.to_string())?
                .ok_or_else(|| format!("vectorized tier skipped `{q}`"))?;
            prop_assert!(
                out.result().unwrap().bag_eq(reference.result().unwrap()),
                "`{q}`: vectorized diverged on compressed storage"
            );
            prop_assert!(
                out.stats.idioms.contains(&tag.to_string()),
                "`{q}` missing `{tag}` on compressed storage: {:?}",
                out.stats.idioms
            );

            // Every scheduling policy over the morsel driver.
            for policy in Policy::ALL {
                let threads = 2 + rng.below(7) as usize;
                let par =
                    forelem::exec::run_parallel_with_policy(&p, &packed, threads, policy)
                        .map_err(|e| e.to_string())?;
                prop_assert!(
                    par.result().unwrap().bag_eq(reference.result().unwrap()),
                    "`{q}` diverged under {policy:?} (threads={threads}) on compressed storage"
                );
            }

            // The optimizer records the code-domain choice — only where
            // the storage is actually compressed.
            let mut p1 = p.clone();
            let report =
                forelem::opt::optimize(&mut p1, &packed).map_err(|e| e.to_string())?;
            prop_assert!(
                report.has("opt.compressed_scan"),
                "`{q}`: expected opt.compressed_scan on compressed storage: {report:?}"
            );
            let opt_out = forelem::exec::run_compiled(&p1, &packed, None)
                .map_err(|e| e.to_string())?;
            prop_assert!(
                opt_out.result().unwrap().bag_eq(reference.result().unwrap()),
                "`{q}`: optimized plan diverged on compressed storage"
            );
            prop_assert!(
                opt_out.stats.idioms.contains(&"opt.compressed_scan".to_string()),
                "`{q}`: decision tag must surface in ExecStats: {:?}",
                opt_out.stats.idioms
            );
            let mut p2 = p.clone();
            let raw_report =
                forelem::opt::optimize(&mut p2, &raw).map_err(|e| e.to_string())?;
            prop_assert!(
                !raw_report.has("opt.compressed_scan"),
                "`{q}`: raw storage must not claim the code domain: {raw_report:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn concurrent_prepared_serving_matches_sequential_engine() {
    // N concurrent clients executing one prepared statement with random
    // bindings on the shared serving pool must each produce exactly the
    // bag a sequential `Engine::sql` of the literal-substituted query
    // produces — while the statement compiles exactly once (the plan
    // cache serves every later prepare) and the serving tags surface.
    use forelem::serve::Server;
    use forelem::workload::{access_log_wide, AccessLogSpec};
    forall_seeds(4, |rng| {
        let m = access_log_wide(&AccessLogSpec {
            // Above the parallel spin-up gate so executions actually run
            // as morsel phases on the shared pool.
            rows: 6_000 + rng.below(6_000) as usize,
            urls: 10 + rng.below(30) as usize,
            skew: 1.1,
            seed: rng.below(1 << 30),
        });
        let mut catalog = StorageCatalog::new();
        catalog.insert_multiset("access", &m).unwrap();
        let srv = Server::new(Engine::new(catalog.clone()), 4, 3);
        let q = "SELECT url, COUNT(*) FROM access WHERE bytes > ? GROUP BY url";
        let prepared = srv.prepare(q).map_err(|e| e.to_string())?;

        // Bindings from the middle of the uniform [200, 100000) byte
        // range: selectivities stay within REBIND_RATIO of each other, so
        // every execution must reuse the one compiled plan.
        let n = 6 + rng.below(5) as usize;
        let bindings: Vec<i64> = (0..n).map(|_| rng.range(30_000, 70_000)).collect();
        let outs: Vec<Result<forelem::exec::Output, String>> = std::thread::scope(|scope| {
            let handles: Vec<_> = bindings
                .iter()
                .map(|&b| {
                    let (srv, prepared) = (&srv, &prepared);
                    scope.spawn(move || {
                        srv.execute(prepared, &[Value::Int(b)])
                            .map_err(|e| e.to_string())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        let mut reference = Engine::new(catalog);
        for (&b, out) in bindings.iter().zip(&outs) {
            let out = out.as_ref().map_err(|e| e.clone())?;
            let want = reference
                .sql(&format!(
                    "SELECT url, COUNT(*) FROM access WHERE bytes > {b} GROUP BY url"
                ))
                .map_err(|e| e.to_string())?;
            prop_assert!(
                out.result().unwrap().bag_eq(want.result().unwrap()),
                "binding {b} diverged from the sequential engine"
            );
            for tag in ["serve.admit", "sched.multi", "vec.morsel"] {
                prop_assert!(
                    out.stats.idioms.iter().any(|t| t == tag),
                    "binding {b} missing `{tag}`: {:?}",
                    out.stats.idioms
                );
            }
            prop_assert!(
                !out.stats.idioms.iter().any(|t| t == "opt.rebind"),
                "binding {b} must not re-plan (ordinary drift): {:?}",
                out.stats.idioms
            );
        }

        // The plan cache must have served every prepare after the first:
        // re-preparing is a hit, and no execution re-entered the compiler.
        let again = srv.prepare(q).map_err(|e| e.to_string())?;
        prop_assert!(again.cache_hit(), "second prepare missed the plan cache");
        let hit_out = srv
            .execute(&again, &[Value::Int(bindings[0])])
            .map_err(|e| e.to_string())?;
        prop_assert!(
            hit_out.stats.idioms.iter().any(|t| t == "serve.cache_hit"),
            "cache-served plan missing `serve.cache_hit`: {:?}",
            hit_out.stats.idioms
        );
        let (hits, misses, invalidations) = srv.plan_cache_stats();
        prop_assert!(
            (hits, misses, invalidations) == (1, 1, 0),
            "statement must compile exactly once: hits={hits} misses={misses} \
             invalidations={invalidations}"
        );
        Ok(())
    });
}

#[test]
fn hadoop_sim_equals_interpreter_for_random_tables() {
    forall_seeds(10, |rng| {
        let m = random_multiset(rng, 300);
        let mut catalog = StorageCatalog::new();
        catalog.insert_multiset("t", &m).unwrap();
        let p = forelem::sql::compile_sql(
            "SELECT k, COUNT(k) FROM t GROUP BY k",
            &catalog.schemas(),
        )
        .unwrap();
        let reference = forelem::exec::run(&p, &catalog).unwrap();
        let (mr, _) = forelem::mapreduce::derive(&p).map_err(|e| e.to_string())?;
        let maps = 1 + rng.below(8) as usize;
        let reducers = 1 + rng.below(4) as usize;
        let h = forelem::mapreduce::run_hadoop(
            &forelem::mapreduce::HadoopConfig::instant(maps, reducers),
            &mr,
            catalog.get("t").unwrap(),
        )
        .map_err(|e| e.to_string())?;
        let mut want: Vec<(String, f64)> = reference
            .result()
            .unwrap()
            .rows()
            .iter()
            .map(|r| (r[0].to_string(), r[1].as_int().unwrap() as f64))
            .collect();
        let mut got: Vec<(String, f64)> = h
            .pairs
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect();
        want.sort_by(|a, b| a.0.cmp(&b.0));
        got.sort_by(|a, b| a.0.cmp(&b.0));
        prop_assert!(want == got, "maps={maps} reducers={reducers}");
        Ok(())
    });
}

/// Fault-tag consistency: the `dist.*` tag set is derived from the
/// counters, and the counters never exceed what the plan injected.
fn fault_tags_match_counters(
    m: &forelem::coordinator::Metrics,
    plan: &forelem::distrib::FaultPlan,
) -> Result<(), String> {
    let has = |t: &str| m.tags.iter().any(|x| x == t);
    prop_assert!(
        has("dist.retry") == (m.failures_recovered > 0 || m.chunks_retried > 0),
        "dist.retry out of sync: {m:?}"
    );
    prop_assert!(
        has("dist.speculative") == (m.stragglers_detected > 0),
        "dist.speculative out of sync: {m:?}"
    );
    prop_assert!(
        has("dist.lost_result") == (m.lost_flushes > 0),
        "dist.lost_result out of sync: {m:?}"
    );
    prop_assert!(
        has("dist.restart") == (m.restarts > 0),
        "dist.restart out of sync: {m:?}"
    );
    prop_assert!(
        m.failures_recovered <= plan.crashes.len(),
        "more failures recovered than crashes injected: {m:?} vs {plan:?}"
    );
    prop_assert!(
        m.lost_flushes <= plan.lost_flushes.len(),
        "more flushes lost than injected: {m:?} vs {plan:?}"
    );
    prop_assert!(
        m.stragglers_detected <= plan.slow.len(),
        "more stragglers detected than slowed workers: {m:?} vs {plan:?}"
    );
    if plan.is_empty() {
        prop_assert!(
            !has("dist.retry")
                && !has("dist.speculative")
                && !has("dist.lost_result")
                && !has("dist.restart"),
            "clean run carries fault tags: {:?}",
            m.tags
        );
    }
    Ok(())
}

#[test]
fn distributed_retail_matches_local_under_random_skew_and_faults() {
    use forelem::coordinator::ClusterConfig;
    use forelem::distrib::FaultPlan;
    use forelem::workload::retail::{self, RetailSpec};

    const JOIN_Q: &str = "SELECT store_id, COUNT(store_id) FROM sales \
                          JOIN products ON sales.product_id = products.id \
                          GROUP BY store_id";
    const FLAT_Q: &str = "SELECT store_id, COUNT(store_id) FROM sales GROUP BY store_id";

    forall_seeds(8, |rng| {
        let skewed = rng.below(2) == 1;
        let sales = 2_000 + rng.below(4_000) as usize;
        // Build-side size picks the shipping strategy deterministically:
        // a 40-row dimension broadcasts, a sales/4-row one shuffles.
        let shuffle_sides = rng.below(2) == 1;
        let products = if shuffle_sides { (sales / 4).max(64) } else { 40 };
        let spec = RetailSpec {
            sales,
            customers: 50,
            products,
            stores: 12,
            categories: 8,
            product_domain_factor: 1,
            skew: if skewed { 2.0 } else { 0.0 },
            seed: rng.below(1 << 30),
        };
        let mut catalog = StorageCatalog::new();
        retail::register_retail(&mut catalog, &spec).map_err(|e| e.to_string())?;
        let mut e = Engine::new(catalog);

        let workers = 2 + rng.below(5) as usize;
        let plan = FaultPlan::random(rng, workers);
        let cfg = ClusterConfig::new(workers, Policy::FixedChunk(128))
            .with_flush_every(2 + rng.below(6) as usize)
            .with_faults(plan.clone());

        for q in [FLAT_Q, JOIN_Q] {
            let reference = e.sql(q).map_err(|e| e.to_string())?;
            let want = reference.result().ok_or("no sequential result")?.clone();
            let (r, got) = e.sql_distributed(q, &cfg).map_err(|e| e.to_string())?;
            prop_assert!(
                got.bag_eq(&want),
                "diverged: sales={sales} products={products} workers={workers} \
                 skew={} plan={plan:?} q={q}: {}",
                spec.skew,
                r.metrics.render()
            );
            fault_tags_match_counters(&r.metrics, &plan)?;
            if q == JOIN_Q {
                let has = |t: &str| r.metrics.tags.iter().any(|x| x == t);
                let opt = e.compile(q).map_err(|e| e.to_string())?;
                let opt = opt.opt.ok_or("optimizer report missing")?;
                if shuffle_sides {
                    prop_assert!(
                        opt.has("opt.dist_shuffle") && !opt.has("opt.dist_broadcast"),
                        "sales={sales} products={products}: expected shuffle decision"
                    );
                    prop_assert!(
                        has("dist.shuffle") && !has("dist.broadcast"),
                        "decision did not route to the shuffle executor: {:?}",
                        r.metrics.tags
                    );
                } else {
                    prop_assert!(
                        opt.has("opt.dist_broadcast") && !opt.has("opt.dist_shuffle"),
                        "sales={sales} products={products}: expected broadcast decision"
                    );
                    prop_assert!(
                        has("dist.broadcast") && !has("dist.shuffle"),
                        "decision did not route to the broadcast executor: {:?}",
                        r.metrics.tags
                    );
                }
                if shuffle_sides && skewed {
                    // Zipf(2.0) concentrates >40% of the fact on the top
                    // product — far past the rows/(2*nodes) hot threshold.
                    prop_assert!(
                        has("dist.repartition_skew"),
                        "skewed shuffle without salting: {:?}",
                        r.metrics.tags
                    );
                }
                if !skewed {
                    prop_assert!(
                        !has("dist.repartition_skew"),
                        "uniform keys flagged as skewed: {:?}",
                        r.metrics.tags
                    );
                }
            }
        }
        Ok(())
    });
}
