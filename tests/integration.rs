//! Cross-module integration tests: the full stack composed end-to-end.

use std::sync::Arc;

use forelem::compiler::{CompileOptions, Engine, ReformatMode};
use forelem::coordinator::{run_job, AggJob, ClusterConfig, Failure};
use forelem::ir::{pretty, Multiset, Value};
use forelem::mapreduce::{self, HadoopConfig};
use forelem::sched::Policy;
use forelem::storage::{StorageCatalog, Table};
use forelem::workload::{access_log, grades, link_graph, AccessLogSpec, LinkGraphSpec};

const URL_Q: &str = "SELECT url, COUNT(url) FROM access GROUP BY url";

fn access_catalog(rows: usize) -> StorageCatalog {
    let m = access_log(&AccessLogSpec {
        rows,
        urls: (rows / 10).max(10),
        skew: 1.1,
        seed: 123,
    });
    let mut c = StorageCatalog::new();
    c.insert_multiset("access", &m).unwrap();
    c
}

/// Normalize a (key, value) result for comparison across engines.
fn pairs_of(m: &Multiset) -> Vec<(String, i64)> {
    let mut v: Vec<(String, i64)> = m
        .rows()
        .iter()
        .map(|r| (r[0].to_string(), r[1].as_int().unwrap()))
        .collect();
    v.sort();
    v
}

#[test]
fn five_engines_agree_on_url_count() {
    // 1. reference interpreter, 2. compiled plan, 3. parallelized IR,
    // 4. distributed coordinator, 5. hadoop-sim — all the same counts.
    let catalog = access_catalog(20_000);
    let mut engine = Engine::new(catalog.clone());
    let compiled = engine.compile(URL_Q).unwrap();

    let interp = forelem::exec::run(&compiled.program, &catalog).unwrap();
    let reference = pairs_of(interp.result().unwrap());

    let plan = engine.sql(URL_Q).unwrap();
    assert_eq!(pairs_of(plan.result().unwrap()), reference);

    let mut par = Engine::new(catalog.clone()).with_options(CompileOptions {
        processors: 6,
        partition_field: None,
        reformat: ReformatMode::Off,
        ..Default::default()
    });
    let c2 = par.compile(URL_Q).unwrap();
    let par_out = forelem::exec::run(&c2.program, &catalog).unwrap();
    assert_eq!(pairs_of(par_out.result().unwrap()), reference);

    let (_, dist) = Engine::new(catalog.clone())
        .sql_distributed(URL_Q, &ClusterConfig::new(5, Policy::Trapezoid))
        .unwrap();
    assert_eq!(pairs_of(&dist), reference);

    let (mr, info) = mapreduce::derive(&compiled.program).unwrap();
    let h = mapreduce::run_hadoop(
        &HadoopConfig::instant(6, 3),
        &mr,
        catalog.get(&info.table).unwrap(),
    )
    .unwrap();
    let mut hpairs: Vec<(String, i64)> = h
        .pairs
        .iter()
        .map(|(k, v)| (k.to_string(), *v as i64))
        .collect();
    hpairs.sort();
    assert_eq!(hpairs, reference);
}

#[test]
fn reformat_plus_parallel_plus_failure_still_exact() {
    let mut engine = Engine::new(access_catalog(30_000)).with_options(CompileOptions {
        processors: 4,
        partition_field: None,
        reformat: ReformatMode::Force,
        ..Default::default()
    });
    let reference = {
        let mut plain = Engine::new(access_catalog(30_000));
        pairs_of(plain.sql(URL_Q).unwrap().result().unwrap())
    };
    let cluster = ClusterConfig::new(6, Policy::Gss).with_failure(Failure {
        worker: 1,
        after_chunks: 1,
    });
    let (r, m) = engine.sql_distributed(URL_Q, &cluster).unwrap();
    assert_eq!(pairs_of(&m), reference);
    assert!(r.metrics.failures_recovered >= 1 || r.metrics.restarts >= 1);
}

#[test]
fn weblink_graph_through_indirect_partitioning() {
    let m = link_graph(&LinkGraphSpec {
        edges: 20_000,
        pages: 1_000,
        skew: 1.05,
        seed: 9,
    });
    let mut catalog = StorageCatalog::new();
    catalog.insert_multiset("links", &m).unwrap();
    let q = "SELECT target, COUNT(target) FROM links GROUP BY target";

    let mut seq = Engine::new(catalog.clone());
    let reference = pairs_of(seq.sql(q).unwrap().result().unwrap());

    let mut par = Engine::new(catalog.clone()).with_options(CompileOptions {
        processors: 4,
        partition_field: Some("target".into()),
        reformat: ReformatMode::Off,
        ..Default::default()
    });
    let compiled = par.compile(q).unwrap();
    let text = pretty::program(&compiled.program);
    assert!(text.contains("X = links.target"), "{text}");
    let out = forelem::exec::run(&compiled.program, &catalog).unwrap();
    assert_eq!(pairs_of(out.result().unwrap()), reference);
}

#[test]
fn grades_sum_aggregate_distributed() {
    let m = grades(500, 6, 77);
    let mut catalog = StorageCatalog::new();
    catalog.insert_multiset("Grades", &m).unwrap();
    let q = "SELECT studentID, SUM(grade) FROM Grades GROUP BY studentID";

    let mut engine = Engine::new(catalog.clone());
    let reference = engine.sql(q).unwrap();
    let want: std::collections::HashMap<Value, f64> = reference
        .result()
        .unwrap()
        .rows()
        .iter()
        .map(|r| (r[0].clone(), r[1].as_float().unwrap()))
        .collect();

    let (r, _) = engine
        .sql_distributed(q, &ClusterConfig::new(4, Policy::Factoring))
        .unwrap();
    assert_eq!(r.pairs.len(), want.len());
    for (k, v) in &r.pairs {
        assert!((want[k] - v).abs() < 1e-6, "key {k}");
    }
}

#[test]
fn csv_import_pipeline_with_generated_load_code() {
    // gen-data style CSV → import with a reformat plan → query.
    use forelem::storage::{import_csv_with_plan, ImportPlan};
    let m = access_log(&AccessLogSpec {
        rows: 5_000,
        urls: 100,
        skew: 1.1,
        seed: 55,
    });
    let mut csv = String::new();
    for r in m.rows() {
        csv.push_str(r[0].as_str().unwrap());
        csv.push('\n');
    }
    let schema = m.schema.clone();
    let plan = ImportPlan {
        dict_encode: vec![0],
        keep: None,
    };
    let table = import_csv_with_plan(std::io::Cursor::new(csv), &schema, &plan).unwrap();
    assert!(table.column(0).dictionary().is_some());

    let job = AggJob::count(Arc::new(table), 0);
    let r = run_job(&ClusterConfig::new(4, Policy::Gss), &job).unwrap();
    assert_eq!(r.pairs.iter().map(|(_, n)| *n).sum::<f64>() as usize, 5_000);
}

#[test]
fn xla_kernels_integrate_when_artifacts_exist() {
    if forelem::runtime::Kernels::load_default().is_err() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let kernels = forelem::runtime::Kernels::load_default().unwrap();
    let mut engine = Engine::new(access_catalog(10_000))
        .with_options(CompileOptions {
            processors: 1,
            partition_field: None,
            reformat: ReformatMode::Force,
            ..Default::default()
        })
        .with_kernels(kernels);
    let reference = {
        let mut plain = Engine::new(access_catalog(10_000));
        pairs_of(plain.sql(URL_Q).unwrap().result().unwrap())
    };
    let out = engine.sql(URL_Q).unwrap();
    assert!(out.stats.kernel_calls > 0, "kernel path not taken");
    assert_eq!(pairs_of(out.result().unwrap()), reference);
}

#[test]
fn hadoop_and_coordinator_agree_on_sum_jobs() {
    let m = grades(200, 5, 3);
    let t = Table::from_multiset(&m).unwrap();
    let mr = mapreduce::MapReduceProgram {
        map: mapreduce::MapFn::EmitKeyValue {
            key_field: 0,
            val_field: 1,
        },
        reduce: mapreduce::ReduceFn::SumValues,
    };
    let h = mapreduce::run_hadoop(&HadoopConfig::instant(4, 2), &mr, &t).unwrap();
    let r = run_job(
        &ClusterConfig::new(3, Policy::Gss),
        &AggJob::sum(Arc::new(t), 0, 1),
    )
    .unwrap();
    let hs: std::collections::HashMap<String, f64> = h
        .pairs
        .iter()
        .map(|(k, v)| (k.to_string(), *v))
        .collect();
    assert_eq!(hs.len(), r.pairs.len());
    for (k, v) in &r.pairs {
        let hv = hs[&k.to_string()];
        assert!((hv - v).abs() < 1e-6, "key {k}: {hv} vs {v}");
    }
}

#[test]
fn optimizer_chooses_the_build_side_end_to_end() {
    // The acceptance shape: a skewed equi-join whose small table is
    // written where the lowered nest would NOT hash it. Through the full
    // `Engine::sql` pipeline the optimizer must pick the small build
    // side (`opt.join_build_side` tagged), route through `vec.hash_join`,
    // and produce interpreter-identical output.
    use forelem::ir::{DataType, Schema};
    use forelem::util::Rng;

    let mut dim = Multiset::new(Schema::new(vec![
        ("id", DataType::Int),
        ("g", DataType::Str),
    ]));
    for i in 0..200i64 {
        dim.push(vec![Value::Int(i), Value::str(format!("g{}", i % 11))]);
    }
    let mut fact = Multiset::new(Schema::new(vec![
        ("a_id", DataType::Int),
        ("w", DataType::Int),
    ]));
    let mut rng = Rng::new(31);
    for _ in 0..30_000 {
        fact.push(vec![
            Value::Int(rng.range(0, 800)),
            Value::Int(rng.range(0, 50)),
        ]);
    }
    let mut catalog = StorageCatalog::new();
    catalog.insert_multiset("dim", &dim).unwrap();
    catalog.insert_multiset("fact", &fact).unwrap();
    let q = "SELECT g, COUNT(g) FROM dim JOIN fact ON dim.id = fact.a_id GROUP BY g";

    let mut on = Engine::new(catalog.clone());
    let optimized = on.sql(q).unwrap();
    assert!(
        optimized.stats.idioms.contains(&"vec.hash_join".to_string()),
        "{:?}",
        optimized.stats.idioms
    );
    assert!(
        optimized
            .stats
            .idioms
            .contains(&"opt.join_build_side".to_string()),
        "{:?}",
        optimized.stats.idioms
    );

    let mut off = Engine::new(catalog.clone()).with_options(CompileOptions {
        optimize: false,
        ..Default::default()
    });
    let unoptimized = off.sql(q).unwrap();
    assert_eq!(
        pairs_of(optimized.result().unwrap()),
        pairs_of(unoptimized.result().unwrap())
    );

    // And against the raw interpreter on the optimized program.
    let compiled = on.compile(q).unwrap();
    let interp = forelem::exec::run(&compiled.program, &on.catalog).unwrap();
    assert_eq!(
        pairs_of(optimized.result().unwrap()),
        pairs_of(interp.result().unwrap())
    );
}
