//! Deterministic fault/skew injection matrix (§III-A3: loop scheduling
//! as the fault-tolerance mechanism, extended to speculation and lost
//! results).
//!
//! Every scenario fixes the *entire* failure schedule up front as a
//! [`FaultPlan`], so each run exercises exactly the planned recovery
//! path: the distributed result must stay bag-identical to the
//! sequential `Engine::sql`, and the retry/speculation counters must
//! equal what the injected schedule implies — not merely "some recovery
//! happened".

use std::sync::Arc;

use forelem::compiler::Engine;
use forelem::coordinator::{run_job, AggJob, ClusterConfig};
use forelem::distrib::FaultPlan;
use forelem::ir::Value;
use forelem::sched::Policy;
use forelem::storage::{StorageCatalog, Table};
use forelem::workload::{access_log, AccessLogSpec};

const Q: &str = "SELECT url, COUNT(url) FROM access GROUP BY url";

fn workload(rows: usize) -> forelem::ir::Multiset {
    access_log(&AccessLogSpec {
        rows,
        urls: 300,
        skew: 1.1,
        seed: 17,
    })
}

fn engine(rows: usize) -> Engine {
    let mut c = StorageCatalog::new();
    c.insert_multiset("access", &workload(rows)).unwrap();
    let mut e = Engine::new(c);
    e.options.reformat = forelem::compiler::ReformatMode::Force;
    e
}

fn table(rows: usize) -> Arc<Table> {
    let mut t = Table::from_multiset(&workload(rows)).unwrap();
    t.dict_encode_field(0).unwrap();
    Arc::new(t)
}

fn check_exact(t: &Arc<Table>, pairs: &[(Value, f64)]) {
    let mut want: std::collections::HashMap<Value, f64> = Default::default();
    for r in 0..t.len() {
        *want.entry(t.value(r, 0)).or_insert(0.0) += 1.0;
    }
    assert_eq!(pairs.len(), want.len());
    for (k, x) in pairs {
        assert_eq!(want[k], *x, "key {k}");
    }
}

/// The four seeded scenarios of the matrix. Each returns (name, plan).
fn matrix() -> Vec<(&'static str, FaultPlan)> {
    vec![
        ("crash-only", FaultPlan::none().crash(2, 5)),
        ("straggler-only", FaultPlan::none().slow(3, 8.0)),
        (
            "crash+straggler",
            FaultPlan::none().crash(1, 5).slow(3, 8.0),
        ),
        ("lost-result", FaultPlan::none().lose_flush(1, 0)),
    ]
}

/// Every matrix entry leaves `sql_distributed` bag-identical to the
/// sequential engine, and the derived `dist.*` tags route correctly.
#[test]
fn every_seeded_fault_plan_is_bag_identical_to_sql() {
    let mut e = engine(60_000);
    let reference = e.sql(Q).unwrap();
    for (name, plan) in matrix() {
        let cfg = ClusterConfig::new(4, Policy::FixedChunk(512))
            .with_flush_every(4)
            .with_faults(plan.clone());
        let (r, m) = e.sql_distributed(Q, &cfg).unwrap();
        assert!(
            m.bag_eq(reference.result().unwrap()),
            "{name}: distributed result diverged: {}",
            r.metrics.render()
        );
        let has = |t: &str| r.metrics.tags.iter().any(|x| x == t);
        match name {
            "crash-only" => assert!(has("dist.retry"), "{name}: {:?}", r.metrics.tags),
            "straggler-only" => {
                assert!(has("dist.speculative"), "{name}: {:?}", r.metrics.tags)
            }
            "crash+straggler" => assert!(
                has("dist.retry") && has("dist.speculative"),
                "{name}: {:?}",
                r.metrics.tags
            ),
            "lost-result" => assert!(
                has("dist.lost_result") && has("dist.retry"),
                "{name}: {:?}",
                r.metrics.tags
            ),
            _ => unreachable!(),
        }
        assert_eq!(r.metrics.restarts, 0, "{name}: dynamic policy never restarts");
    }
}

/// Crash after 5 completed chunks with flush_every=4: the first flush
/// committed 4 chunks; the 5th (unflushed) and the in-flight 6th die
/// with the node — exactly 2 re-queued chunks, 1 recovered failure, and
/// the dead worker's committed count frozen at 4.
#[test]
fn crash_retry_counters_equal_the_injected_schedule() {
    let t = table(60_000);
    let cfg = ClusterConfig::new(4, Policy::FixedChunk(512))
        .with_flush_every(4)
        .with_faults(FaultPlan::none().crash(2, 5));
    let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
    check_exact(&t, &r.pairs);
    assert_eq!(r.metrics.failures_recovered, 1);
    assert_eq!(r.metrics.chunks_retried, 2);
    assert_eq!(r.metrics.chunks_per_worker.get(&2), Some(&4));
    assert_eq!(r.metrics.restarts, 0);
    assert!(r.metrics.tags.iter().any(|t| t == "dist.retry"));
}

/// An 8× straggler against the 4× detection threshold: exactly one
/// straggler detected (virtual cost units make the ratio exact, not
/// wall-clock-noisy), with speculative duplicates launched for its
/// remaining chunks.
#[test]
fn straggler_detection_is_deterministic() {
    let t = table(60_000);
    let cfg = ClusterConfig::new(4, Policy::FixedChunk(1024))
        .with_faults(FaultPlan::none().slow(3, 8.0));
    let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
    check_exact(&t, &r.pairs);
    assert_eq!(r.metrics.stragglers_detected, 1);
    assert!(r.metrics.speculative_launched >= 1);
    assert!(r.metrics.speculative_won <= r.metrics.speculative_launched);
    assert!(r.metrics.tags.iter().any(|t| t == "dist.speculative"));
    assert_eq!(r.metrics.restarts, 0);

    // Speculation off: the same plan still completes exactly, with the
    // straggler detected but never duplicated.
    let cfg_off = ClusterConfig::new(4, Policy::FixedChunk(1024))
        .with_faults(FaultPlan::none().slow(3, 8.0))
        .with_speculation(false);
    let r2 = run_job(&cfg_off, &AggJob::count(t.clone(), 0)).unwrap();
    check_exact(&t, &r2.pairs);
    assert_eq!(r2.metrics.speculative_launched, 0);
    assert_eq!(r2.metrics.speculative_won, 0);
}

/// Losing worker 1's first flush (flush_every=4) drops exactly one
/// partial covering 4 chunks: the leader detects the gap via the flush
/// ordinal and re-queues those 4 chunks.
#[test]
fn lost_result_requeues_exactly_the_dropped_batch() {
    let t = table(60_000);
    let cfg = ClusterConfig::new(4, Policy::FixedChunk(512))
        .with_flush_every(4)
        .with_faults(FaultPlan::none().lose_flush(1, 0));
    let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
    check_exact(&t, &r.pairs);
    assert_eq!(r.metrics.lost_flushes, 1);
    assert_eq!(r.metrics.chunks_retried, 4);
    assert_eq!(r.metrics.failures_recovered, 0);
    assert!(r.metrics.tags.iter().any(|t| t == "dist.lost_result"));
}

/// Crash and straggler in one schedule: both recovery paths fire in the
/// same run and the counters stay independent.
#[test]
fn combined_crash_and_straggler_recover_in_one_run() {
    let t = table(60_000);
    let cfg = ClusterConfig::new(4, Policy::FixedChunk(512))
        .with_flush_every(4)
        .with_faults(FaultPlan::none().crash(1, 5).slow(3, 8.0));
    let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
    check_exact(&t, &r.pairs);
    assert_eq!(r.metrics.failures_recovered, 1);
    assert_eq!(r.metrics.stragglers_detected, 1);
    assert!(r.metrics.chunks_retried >= 2);
    let tags = &r.metrics.tags;
    assert!(tags.iter().any(|t| t == "dist.retry"), "{tags:?}");
    assert!(tags.iter().any(|t| t == "dist.speculative"), "{tags:?}");
}

/// The promoted example's policy sweep: a node dies immediately, and
/// every scheduling discipline still counts every row — they differ
/// only in recovery cost (restart for static, chunk re-queue for
/// dynamic, super-chunk for hybrid).
#[test]
fn every_policy_survives_an_immediate_node_death() {
    let t = table(60_000);
    for policy in [
        Policy::StaticBlock,
        Policy::Gss,
        Policy::Trapezoid,
        Policy::Hybrid {
            super_chunks_per_worker: 8,
        },
    ] {
        let cfg = ClusterConfig::new(4, policy).with_faults(FaultPlan::none().crash(3, 0));
        let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
        check_exact(&t, &r.pairs);
        let total: f64 = r.pairs.iter().map(|(_, n)| *n).sum();
        assert_eq!(total as usize, 60_000);
        if matches!(policy, Policy::StaticBlock) {
            assert_eq!(r.metrics.restarts, 1, "static schedules must restart");
            assert!(r.metrics.tags.iter().any(|t| t == "dist.restart"));
        } else {
            assert_eq!(r.metrics.restarts, 0, "{policy:?} must recover in place");
            assert_eq!(r.metrics.failures_recovered, 1);
        }
    }
}

/// Fault-free runs carry no fault tags: the tag set is a faithful
/// record, not a constant.
#[test]
fn clean_runs_carry_no_fault_tags() {
    let mut e = engine(20_000);
    let reference = e.sql(Q).unwrap();
    let cfg = ClusterConfig::new(4, Policy::Gss);
    let (r, m) = e.sql_distributed(Q, &cfg).unwrap();
    assert!(m.bag_eq(reference.result().unwrap()));
    assert!(
        !r.metrics.tags.iter().any(|t| t.starts_with("dist.")
            && t != "dist.shuffle"
            && t != "dist.broadcast"),
        "{:?}",
        r.metrics.tags
    );
    assert_eq!(r.metrics.failures_recovered, 0);
    assert_eq!(r.metrics.chunks_retried, 0);
    assert_eq!(r.metrics.lost_flushes, 0);
    assert_eq!(r.metrics.stragglers_detected, 0);
}
