//! BigBench-style retail star-schema workload: N-way equi-joins (star and
//! snowflake) over `workload::retail`, proven two ways per query — the
//! *result* against a hand-computed oracle (and the optimizer-off
//! reference), and the *plan* against golden `Engine::explain` text: the
//! Selinger `opt.join_order` decision (as-written or reordered), the
//! executing tier, and the kernels that fired.
//!
//! The fixtures guarantee referential integrity (every dimension id
//! appears in the fact), so grouped join results match plain SQL and the
//! suite needs no special zero-group handling.

use std::collections::BTreeMap;

use forelem::compiler::{CompileOptions, Engine};
use forelem::exec::Output;
use forelem::ir::Multiset;
use forelem::sched::Policy;
use forelem::storage::StorageCatalog;
use forelem::workload::retail::{self, RetailSpec};

fn catalog() -> StorageCatalog {
    let mut c = StorageCatalog::new();
    retail::register_retail(&mut c, &RetailSpec::default()).unwrap();
    c
}

fn engine() -> Engine {
    Engine::new(catalog())
}

fn engine_optimizer_off() -> Engine {
    Engine::new(catalog()).with_options(CompileOptions {
        optimize: false,
        ..CompileOptions::default()
    })
}

/// Dense-pk lookup: `dim.rows()[id]` IS the row with `id` (asserted by
/// the generator's own tests).
fn dim_field(dim: &Multiset, id: i64, field: usize) -> String {
    dim.rows()[id as usize][field].as_str().unwrap().to_string()
}

/// Hand-computed grouped aggregate over the generated fact: every sale
/// matches exactly one row per dimension (referential integrity), so the
/// star join's group totals are a single pass over `sales`.
/// `key(customer_id, product_id, store_id)` names the group;
/// `val(quantity, revenue)` is the per-row contribution (1 for COUNT).
fn fact_oracle(
    key: impl Fn(i64, i64, i64) -> String,
    val: impl Fn(i64, i64) -> i64,
) -> BTreeMap<String, i64> {
    let spec = RetailSpec::default();
    let sales = retail::sales(&spec);
    let mut want: BTreeMap<String, i64> = BTreeMap::new();
    for r in sales.rows() {
        let (c, p, s) = (
            r[0].as_int().unwrap(),
            r[1].as_int().unwrap(),
            r[2].as_int().unwrap(),
        );
        *want.entry(key(c, p, s)).or_default() += val(r[3].as_int().unwrap(), r[4].as_int().unwrap());
    }
    want
}

fn grouped(out: &Output) -> BTreeMap<String, i64> {
    out.result()
        .unwrap()
        .rows()
        .iter()
        .map(|r| (r[0].as_str().unwrap().to_string(), r[1].as_int().unwrap()))
        .collect()
}

fn assert_tags(out: &Output, tags: &[&str]) {
    for t in tags {
        assert!(
            out.stats.idioms.contains(&t.to_string()),
            "missing `{t}`: {:?}",
            out.stats.idioms
        );
    }
}

/// Q1 — three-way star, fact written first: the DP must conclude the
/// written order is already optimal and say so in the plan.
#[test]
fn q1_count_by_segment_star_as_written() {
    let q = "SELECT segment, COUNT(segment) FROM sales \
             JOIN customers ON sales.customer_id = customers.id \
             JOIN stores ON sales.store_id = stores.id \
             GROUP BY segment";
    let spec = RetailSpec::default();
    let customers = retail::customers(&spec);
    let want = fact_oracle(|c, _, _| dim_field(&customers, c, 1), |_, _| 1);

    let out = engine().sql(q).unwrap();
    assert_eq!(grouped(&out), want);
    assert_eq!(want.len(), 3, "three customer segments");
    assert_tags(&out, &["vectorized", "vec.hash_join", "opt.join_order"]);

    let text = engine().explain(q).unwrap();
    assert!(
        text.contains("[opt.join_order] sales ⋈ customers ⋈ stores — as written"),
        "{text}"
    );
    assert!(text.contains("-- tier: vectorized"), "{text}");
    assert!(text.contains("vec.hash_join"), "{text}");
}

/// Q2 — the same star written dimension-first: the DP must move the fact
/// to the front and record the rewrite, without changing the result.
#[test]
fn q2_dimension_first_star_is_reordered() {
    let q = "SELECT segment, COUNT(segment) FROM customers \
             JOIN sales ON customers.id = sales.customer_id \
             JOIN stores ON sales.store_id = stores.id \
             GROUP BY segment";
    let spec = RetailSpec::default();
    let customers = retail::customers(&spec);
    let want = fact_oracle(|c, _, _| dim_field(&customers, c, 1), |_, _| 1);

    let out = engine().sql(q).unwrap();
    assert_eq!(grouped(&out), want);
    assert_tags(&out, &["vectorized", "vec.hash_join", "opt.join_order"]);

    let text = engine().explain(q).unwrap();
    assert!(
        text.contains(
            "[opt.join_order] sales ⋈ customers ⋈ stores — reordered from \
             customers ⋈ sales ⋈ stores"
        ),
        "{text}"
    );

    // Optimizer off: same bag, no opt.* tags, and no plan section.
    let off = engine_optimizer_off().sql(q).unwrap();
    assert_eq!(grouped(&off), want);
    assert!(
        !off.stats.idioms.iter().any(|t| t.starts_with("opt.")),
        "{:?}",
        off.stats.idioms
    );
    let off_text = engine_optimizer_off().explain(q).unwrap();
    assert!(!off_text.contains("[opt.join_order]"), "{off_text}");
}

/// Q3 — non-aggregate three-way projection: one output row per sale
/// (referential integrity), bag-identical with the optimizer off.
#[test]
fn q3_projection_emits_one_row_per_sale() {
    let q = "SELECT customers.segment, products.price, sales.quantity FROM sales \
             JOIN customers ON sales.customer_id = customers.id \
             JOIN products ON sales.product_id = products.id";
    let out = engine().sql(q).unwrap();
    let rows = out.result().unwrap();
    assert_eq!(rows.len(), RetailSpec::default().sales);
    assert_tags(&out, &["vectorized", "vec.hash_join", "opt.join_order"]);

    let off = engine_optimizer_off().sql(q).unwrap();
    assert!(rows.bag_eq(off.result().unwrap()));

    let text = engine().explain(q).unwrap();
    assert!(
        text.contains("[opt.join_order] sales ⋈ customers ⋈ products — as written"),
        "{text}"
    );
}

/// Q4 — snowflake: `categories` hangs off `products`, not the fact. The
/// chain (fact → products → categories) is already the cheapest order.
#[test]
fn q4_snowflake_count_by_category() {
    let q = "SELECT name, COUNT(name) FROM sales \
             JOIN products ON sales.product_id = products.id \
             JOIN categories ON products.cat_id = categories.id \
             GROUP BY name";
    let spec = RetailSpec::default();
    let products = retail::products(&spec);
    let want = fact_oracle(
        |_, p, _| {
            let cat = products.rows()[p as usize][1].as_int().unwrap();
            format!("cat{cat}")
        },
        |_, _| 1,
    );

    let out = engine().sql(q).unwrap();
    assert_eq!(grouped(&out), want);
    assert_eq!(want.len(), spec.categories);
    assert_tags(&out, &["vectorized", "vec.hash_join", "opt.join_order"]);

    let text = engine().explain(q).unwrap();
    assert!(
        text.contains("[opt.join_order] sales ⋈ products ⋈ categories — as written"),
        "{text}"
    );
}

/// Q5 — four-way star over every dimension at once.
#[test]
fn q5_four_table_star_count_by_state() {
    let q = "SELECT state, COUNT(state) FROM sales \
             JOIN customers ON sales.customer_id = customers.id \
             JOIN products ON sales.product_id = products.id \
             JOIN stores ON sales.store_id = stores.id \
             GROUP BY state";
    let spec = RetailSpec::default();
    let stores = retail::stores(&spec);
    let want = fact_oracle(|_, _, s| dim_field(&stores, s, 2), |_, _| 1);

    let out = engine().sql(q).unwrap();
    assert_eq!(grouped(&out), want);
    assert_eq!(want.len(), 5, "five US states in the stores dimension");
    assert_tags(&out, &["vectorized", "vec.hash_join", "opt.join_order"]);

    let text = engine().explain(q).unwrap();
    assert!(
        text.contains(
            "[opt.join_order] sales ⋈ customers ⋈ products ⋈ stores — as written"
        ),
        "{text}"
    );
}

/// Q6 — a WHERE equality on the fact is lifted into the outer index-set
/// filter, which pins the nest: no `opt.join_order` decision may fire,
/// but the chain still executes as a vectorized hash join.
#[test]
fn q6_fact_filter_pins_the_join_order() {
    let q = "SELECT segment, COUNT(segment) FROM sales \
             JOIN customers ON sales.customer_id = customers.id \
             JOIN stores ON sales.store_id = stores.id \
             WHERE sales.store_id = 3 \
             GROUP BY segment";
    let spec = RetailSpec::default();
    let customers = retail::customers(&spec);
    // The emit loop walks ALL distinct segments of `customers`; segments
    // with no store-3 sales would surface as 0 (none do at this size).
    let mut want: BTreeMap<String, i64> = customers
        .rows()
        .iter()
        .map(|r| (r[1].as_str().unwrap().to_string(), 0))
        .collect();
    let matches = fact_oracle(
        |c, _, s| {
            if s == 3 {
                dim_field(&customers, c, 1)
            } else {
                String::new()
            }
        },
        |_, _| 1,
    );
    for (k, v) in matches {
        if !k.is_empty() {
            want.insert(k, v);
        }
    }

    let out = engine().sql(q).unwrap();
    assert_eq!(grouped(&out), want);
    assert_tags(&out, &["vectorized", "vec.hash_join"]);
    assert!(
        !out.stats.idioms.contains(&"opt.join_order".to_string()),
        "pinned nest must not be reordered: {:?}",
        out.stats.idioms
    );

    let text = engine().explain(q).unwrap();
    assert!(!text.contains("[opt.join_order]"), "{text}");
    assert!(text.contains("vec.hash_join"), "{text}");
}

/// Q7 — star join + ORDER BY/LIMIT: the join-order DP and the top-k heap
/// decision compose, and the bounded-heap kernel runs the emission.
#[test]
fn q7_top_segments_by_sales() {
    let q = "SELECT segment, COUNT(segment) AS n FROM sales \
             JOIN customers ON sales.customer_id = customers.id \
             JOIN stores ON sales.store_id = stores.id \
             GROUP BY segment ORDER BY n DESC LIMIT 2";
    let spec = RetailSpec::default();
    let customers = retail::customers(&spec);
    let want = fact_oracle(|c, _, _| dim_field(&customers, c, 1), |_, _| 1);
    let mut counts: Vec<i64> = want.values().copied().collect();
    counts.sort_unstable_by(|a, b| b.cmp(a));
    counts.truncate(2);

    let out = engine().sql(q).unwrap();
    let rows = out.result().unwrap();
    assert_eq!(rows.len(), 2);
    let got: Vec<i64> = rows.rows().iter().map(|r| r[1].as_int().unwrap()).collect();
    assert_eq!(got, counts, "top-2 counts must match the sorted oracle");
    for r in rows.rows() {
        let seg = r[0].as_str().unwrap();
        assert_eq!(r[1].as_int().unwrap(), want[seg], "`{seg}` carries its true count");
    }
    assert_tags(
        &out,
        &["vectorized", "vec.hash_join", "vec.topk", "opt.join_order", "opt.topk_heap"],
    );

    let text = engine().explain(q).unwrap();
    assert!(
        text.contains("[opt.join_order] sales ⋈ customers ⋈ stores — as written"),
        "{text}"
    );
    assert!(text.contains("[opt.topk_heap]"), "{text}");
    assert!(text.contains("vec.topk"), "{text}");
}

/// Q8 — integer SUM over a reordered star: exact under reordering, the
/// morsel-parallel driver, and every scheduling policy.
#[test]
fn q8_revenue_by_region_is_exact_everywhere() {
    let q = "SELECT region, SUM(revenue) FROM customers \
             JOIN sales ON customers.id = sales.customer_id \
             JOIN products ON sales.product_id = products.id \
             GROUP BY region";
    let spec = RetailSpec::default();
    let customers = retail::customers(&spec);
    let want = fact_oracle(|c, _, _| dim_field(&customers, c, 2), |_, rev| rev);

    let out = engine().sql(q).unwrap();
    assert_eq!(grouped(&out), want);
    assert_eq!(want.len(), 7, "seven customer regions");
    assert_tags(&out, &["vectorized", "vec.hash_join", "opt.join_order"]);

    let text = engine().explain(q).unwrap();
    assert!(
        text.contains(
            "[opt.join_order] sales ⋈ customers ⋈ products — reordered from \
             customers ⋈ sales ⋈ products"
        ),
        "{text}"
    );

    // The reordered program under the parallel driver: every policy,
    // several thread counts, bag-identical to the oracle.
    let c = catalog();
    let mut p = forelem::sql::compile_sql(q, &c.schemas()).unwrap();
    forelem::opt::optimize(&mut p, &c).unwrap();
    for policy in Policy::ALL {
        for threads in [2, 5, 8] {
            let par = forelem::exec::run_parallel_with_policy(&p, &c, threads, policy).unwrap();
            assert_eq!(
                grouped(&par),
                want,
                "diverged under {policy:?} (threads={threads})"
            );
        }
    }
}

/// The interpreter is the semantic oracle for the whole suite: for every
/// workload query, optimizer-on and optimizer-off programs must both
/// reproduce the reference interpreter's bags on all tiers.
#[test]
fn all_queries_agree_with_the_interpreter() {
    let queries = [
        "SELECT segment, COUNT(segment) FROM sales \
         JOIN customers ON sales.customer_id = customers.id \
         JOIN stores ON sales.store_id = stores.id GROUP BY segment",
        "SELECT segment, COUNT(segment) FROM customers \
         JOIN sales ON customers.id = sales.customer_id \
         JOIN stores ON sales.store_id = stores.id GROUP BY segment",
        "SELECT customers.segment, products.price, sales.quantity FROM sales \
         JOIN customers ON sales.customer_id = customers.id \
         JOIN products ON sales.product_id = products.id",
        "SELECT name, COUNT(name) FROM sales \
         JOIN products ON sales.product_id = products.id \
         JOIN categories ON products.cat_id = categories.id GROUP BY name",
        "SELECT state, COUNT(state) FROM sales \
         JOIN customers ON sales.customer_id = customers.id \
         JOIN products ON sales.product_id = products.id \
         JOIN stores ON sales.store_id = stores.id GROUP BY state",
        "SELECT segment, COUNT(segment) FROM sales \
         JOIN customers ON sales.customer_id = customers.id \
         JOIN stores ON sales.store_id = stores.id \
         WHERE sales.store_id = 3 GROUP BY segment",
        "SELECT region, SUM(revenue) FROM customers \
         JOIN sales ON customers.id = sales.customer_id \
         JOIN products ON sales.product_id = products.id GROUP BY region",
    ];
    let c = catalog();
    for q in queries {
        let p0 = forelem::sql::compile_sql(q, &c.schemas()).unwrap();
        let reference = forelem::exec::run(&p0, &c).unwrap();
        let off = forelem::exec::run_compiled(&p0, &c, None).unwrap();
        assert!(
            off.result().unwrap().bag_eq(reference.result().unwrap()),
            "`{q}`: run_compiled(unoptimized) diverged"
        );
        let mut p1 = p0.clone();
        forelem::opt::optimize(&mut p1, &c).unwrap();
        let interp_opt = forelem::exec::run(&p1, &c).unwrap();
        assert!(
            interp_opt.result().unwrap().bag_eq(reference.result().unwrap()),
            "`{q}`: interpreter(optimized) diverged"
        );
        let on = forelem::exec::run_compiled(&p1, &c, None).unwrap();
        assert!(
            on.result().unwrap().bag_eq(reference.result().unwrap()),
            "`{q}`: run_compiled(optimized) diverged"
        );
    }
}
