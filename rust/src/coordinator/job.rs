//! Job specifications and partial results for the coordinator.
//!
//! Besides the single-table count/sum jobs, a job can carry a
//! [`JoinProbe`]: the hash table over the build side is constructed once
//! and shared read-only by every worker (`Arc`), while chunks of the
//! probe side stream through [`process_chunk`] — the distributed analogue
//! of `exec::parallel`'s shared-build, partitioned-probe compiled join.
//!
//! Chunk distribution and chunk processing are both shared with the
//! in-process driver: the leader hands out chunks through the same
//! `sched::Scheduler` policies `exec::parallel`'s `SharedScheduler`
//! wraps, and [`process_chunk`] walks its range at the same
//! `exec::vector::morsel_ranges` granularity, driving the same batch
//! kernels.


use crate::util::FxHashMap;
use std::sync::Arc;

use crate::exec::vector::JoinHashTable;
use crate::ir::Value;
use crate::storage::{Column, Table};

/// The aggregation performed by a job (the paper's two evaluation kernels
/// generalize to these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggOp {
    /// `count[key]++` (URL access count, reverse web-link graph).
    Count,
    /// `sum[key] += val` (the §IV variant with a value field).
    Sum,
}

/// A hash-join probe attached to a distributed aggregation job. The
/// job's `table` becomes the probe (outer) side; every matched
/// (probe row, build row) pair contributes one unit (count) or one value
/// (sum) to the aggregate. Only the hash table is retained — the
/// build-side table itself is not needed after construction.
#[derive(Clone)]
pub struct JoinProbe {
    /// Probe-side field compared against the build key.
    pub probe_field: usize,
    /// The hash table, built once and shared read-only by all workers.
    pub table: Arc<JoinHashTable>,
}

impl JoinProbe {
    /// Build the shared hash table over `build.column(build_key_field)`.
    pub fn new(build: &Table, build_key_field: usize, probe_field: usize) -> Self {
        JoinProbe {
            probe_field,
            table: Arc::new(JoinHashTable::build(build, build_key_field)),
        }
    }
}

/// A distributed aggregation job over a table.
#[derive(Clone)]
pub struct AggJob {
    pub op: AggOp,
    pub table: Arc<Table>,
    pub key_field: usize,
    /// Required for `Sum`.
    pub val_field: Option<usize>,
    /// Dense key-space width if the key column is integer-keyed
    /// (dictionary-encoded); None → associative (string) accumulation.
    pub num_keys: Option<usize>,
    /// When set, each probe-side row is weighted by its number of
    /// build-side matches (the Figure-1 join feeding a GROUP BY).
    pub join: Option<JoinProbe>,
}

impl AggJob {
    pub fn count(table: Arc<Table>, key_field: usize) -> Self {
        let num_keys = dense_width(&table, key_field);
        AggJob {
            op: AggOp::Count,
            table,
            key_field,
            val_field: None,
            num_keys,
            join: None,
        }
    }

    pub fn sum(table: Arc<Table>, key_field: usize, val_field: usize) -> Self {
        let num_keys = dense_width(&table, key_field);
        AggJob {
            op: AggOp::Sum,
            table,
            key_field,
            val_field: Some(val_field),
            num_keys,
            join: None,
        }
    }

    /// `COUNT` of matched pairs per probe-side key — the join + GROUP BY
    /// COUNT shape, distributed.
    pub fn count_join(table: Arc<Table>, key_field: usize, probe: JoinProbe) -> Self {
        let mut job = AggJob::count(table, key_field);
        job.join = Some(probe);
        job
    }

    /// `SUM(val_field)` (a probe-side column) weighted by build-side
    /// match count per probe-side key.
    pub fn sum_join(
        table: Arc<Table>,
        key_field: usize,
        val_field: usize,
        probe: JoinProbe,
    ) -> Self {
        let mut job = AggJob::sum(table, key_field, val_field);
        job.join = Some(probe);
        job
    }

    pub fn rows(&self) -> usize {
        self.table.len()
    }
}

/// Dense key-space width when the key column is integer-keyed.
fn dense_width(table: &Table, key_field: usize) -> Option<usize> {
    match table.column(key_field) {
        Column::DictStrs { dict, .. } => Some(dict.len()),
        Column::Ints(v) => {
            let max = v.iter().copied().max().unwrap_or(0);
            let min = v.iter().copied().min().unwrap_or(0);
            if min >= 0 && (max as usize) < v.len().max(1024) * 4 {
                Some(max as usize + 1)
            } else {
                None
            }
        }
        Column::CompressedInts(c) => {
            // Same density test as plain integers, but min/max come from
            // the run values (or the range endpoints) — never a decode.
            let (min, max) = match c.runs() {
                Some(runs) if runs.is_empty() => (0, 0),
                Some(runs) => runs
                    .iter()
                    .fold((i64::MAX, i64::MIN), |(lo, hi), &(v, _)| {
                        (lo.min(v), hi.max(v))
                    }),
                None if c.is_empty() => (0, 0),
                None => {
                    let (a, b) = (c.get(0), c.get(c.len() - 1));
                    (a.min(b), a.max(b))
                }
            };
            if min >= 0 && (max as usize) < c.len().max(1024) * 4 {
                Some(max as usize + 1)
            } else {
                None
            }
        }
        _ => None,
    }
}

/// A partial aggregate computed by one worker over one chunk.
#[derive(Debug, Clone)]
pub enum Partial {
    /// Dense f64 accumulator over `[0, num_keys)`.
    Dense(Vec<f64>),
    /// Sparse (value, accum) pairs — the string path.
    Assoc(Vec<(Value, f64)>),
}

impl Partial {
    /// Approximate wire size for comm accounting.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Partial::Dense(v) => v.len() * 8,
            Partial::Assoc(pairs) => pairs
                .iter()
                .map(|(v, _)| crate::distrib::tuple_bytes(std::slice::from_ref(v)) + 8)
                .sum(),
        }
    }
}

/// The leader-side merged accumulator.
#[derive(Debug)]
pub enum Acc {
    Dense(Vec<f64>),
    Assoc(FxHashMap<Value, f64>),
}

impl Acc {
    pub fn for_job(job: &AggJob) -> Acc {
        match job.num_keys {
            Some(k) => Acc::Dense(vec![0.0; k]),
            None => Acc::Assoc(FxHashMap::default()),
        }
    }

    pub fn merge(&mut self, p: Partial) {
        match (self, p) {
            (Acc::Dense(acc), Partial::Dense(part)) => {
                for (a, b) in acc.iter_mut().zip(part) {
                    *a += b;
                }
            }
            (Acc::Assoc(acc), Partial::Assoc(pairs)) => {
                for (v, x) in pairs {
                    *acc.entry(v).or_insert(0.0) += x;
                }
            }
            (Acc::Assoc(acc), Partial::Dense(part)) => {
                for (k, x) in part.into_iter().enumerate() {
                    if x != 0.0 {
                        *acc.entry(Value::Int(k as i64)).or_insert(0.0) += x;
                    }
                }
            }
            (Acc::Dense(_), Partial::Assoc(_)) => {
                panic!("dense accumulator fed a sparse partial — job misconfigured")
            }
        }
    }

    /// Convert a (worker-local) accumulator into a flushable partial.
    pub fn into_partial(self) -> Partial {
        match self {
            Acc::Dense(v) => Partial::Dense(v),
            Acc::Assoc(m) => Partial::Assoc(m.into_iter().collect()),
        }
    }

    /// Nonzero entries as (key-value, total) pairs, decoding dictionary
    /// keys back to strings via the job's table.
    pub fn into_pairs(self, job: &AggJob) -> Vec<(Value, f64)> {
        match self {
            Acc::Dense(acc) => {
                let dict = job.table.column(job.key_field).dictionary().cloned();
                acc.into_iter()
                    .enumerate()
                    .filter(|(_, x)| *x != 0.0)
                    .map(|(k, x)| {
                        let key = match &dict {
                            Some(d) => Value::Str(d.decode(k as u32).expect("key").clone()),
                            None => Value::Int(k as i64),
                        };
                        (key, x)
                    })
                    .collect()
            }
            Acc::Assoc(acc) => acc.into_iter().collect(),
        }
    }
}

/// Compute the partial aggregate for chunk `[lo, hi)` of the job's table.
/// This is the worker inner loop — the generated-code analogue. The dense
/// integer-keyed loops are the shared batch kernels in `exec::vector`,
/// driven per `morsel_ranges` window — the same primitives, at the same
/// morsel granularity, as the vectorized executor's fused aggregations,
/// `exec::parallel`'s morsel workers and `exec::plan`'s native idiom
/// fallbacks — one code path for all three tiers.
pub fn process_chunk(job: &AggJob, lo: usize, hi: usize) -> Partial {
    use crate::exec::vector::{
        count_batch_i64_f64, count_batch_strs, count_batch_u32_f64, morsel_ranges, sum_batch_i64,
        sum_batch_u32,
    };
    if let Some(probe) = &job.join {
        return process_join_chunk(job, probe, lo, hi);
    }
    let t = &job.table;
    match job.num_keys {
        Some(num_keys) => {
            let mut acc = vec![0.0f64; num_keys];
            match (job.op, t.column(job.key_field)) {
                (AggOp::Count, Column::DictStrs { keys, .. }) => {
                    for (mlo, mhi) in morsel_ranges(lo, hi) {
                        count_batch_u32_f64(&keys[mlo..mhi], &mut acc);
                    }
                }
                (AggOp::Count, Column::Ints(keys)) => {
                    for (mlo, mhi) in morsel_ranges(lo, hi) {
                        count_batch_i64_f64(&keys[mlo..mhi], &mut acc);
                    }
                }
                (AggOp::Count, Column::CompressedInts(c)) => {
                    // Run-domain count: one accumulator add per run,
                    // weighted by run length — rows are never iterated.
                    for (k, rlo, rhi) in c.run_windows(lo, hi) {
                        acc[k as usize] += (rhi - rlo) as f64;
                    }
                }
                (AggOp::Sum, kcol) => {
                    let vf = job.val_field.expect("sum job needs val_field");
                    // Aligned [lo, hi) window of values: borrowed when the
                    // column is already a float slice, materialized
                    // otherwise.
                    let owned: Vec<f64>;
                    let window: &[f64] = match t.column(vf).float_slice() {
                        Some(s) => &s[lo..hi],
                        None => {
                            owned = (lo..hi)
                                .map(|r| t.value(r, vf).as_float().unwrap_or(0.0))
                                .collect();
                            &owned
                        }
                    };
                    match kcol {
                        Column::DictStrs { keys, .. } => {
                            for (mlo, mhi) in morsel_ranges(lo, hi) {
                                let w = &window[mlo - lo..mhi - lo];
                                sum_batch_u32(&keys[mlo..mhi], w, &mut acc);
                            }
                        }
                        Column::Ints(keys) => {
                            for (mlo, mhi) in morsel_ranges(lo, hi) {
                                let w = &window[mlo - lo..mhi - lo];
                                sum_batch_i64(&keys[mlo..mhi], w, &mut acc);
                            }
                        }
                        Column::CompressedInts(c) => {
                            // One accumulator-slot resolution per run of
                            // the key column; value adds stay per-row.
                            for (k, rlo, rhi) in c.run_windows(lo, hi) {
                                let a = &mut acc[k as usize];
                                for &v in &window[rlo - lo..rhi - lo] {
                                    *a += v;
                                }
                            }
                        }
                        _ => {
                            for (i, r) in (lo..hi).enumerate() {
                                let k = t.value(r, job.key_field).as_int().unwrap() as usize;
                                acc[k] += window[i];
                            }
                        }
                    }
                }
                (AggOp::Count, _) => {
                    for r in lo..hi {
                        let k = t.value(r, job.key_field).as_int().unwrap() as usize;
                        acc[k] += 1.0;
                    }
                }
            }
            Partial::Dense(acc)
        }
        None => {
            // Associative (string) path. Fast lane for plain string
            // columns: hash the Arc<str> contents without constructing a
            // Value per row (a Value clone + enum hash per tuple is the
            // dominant cost otherwise — see EXPERIMENTS.md §Perf).
            if job.op == AggOp::Count {
                if let Column::Strs(vals) = t.column(job.key_field) {
                    let mut map: FxHashMap<std::sync::Arc<str>, f64> = FxHashMap::default();
                    for (mlo, mhi) in morsel_ranges(lo, hi) {
                        count_batch_strs(&vals[mlo..mhi], &mut map);
                    }
                    return Partial::Assoc(
                        map.into_iter().map(|(s, n)| (Value::Str(s), n)).collect(),
                    );
                }
            }
            let mut map: FxHashMap<Value, f64> = FxHashMap::default();
            for r in lo..hi {
                let k = t.value(r, job.key_field);
                let x = match job.op {
                    AggOp::Count => 1.0,
                    AggOp::Sum => t
                        .value(r, job.val_field.expect("sum job needs val_field"))
                        .as_float()
                        .unwrap_or(0.0),
                };
                *map.entry(k).or_insert(0.0) += x;
            }
            Partial::Assoc(map.into_iter().collect())
        }
    }
}

/// Join-probe worker loop: rows `[lo, hi)` of the probe table, each
/// weighted by its number of build-side matches from the shared hash
/// table. Counts stay exact in the coordinator's f64 wire format; sums
/// use multiply-by-multiplicity (the coordinator's aggregates are f64
/// approximations by design, see [`Partial`]).
fn process_join_chunk(job: &AggJob, probe: &JoinProbe, lo: usize, hi: usize) -> Partial {
    let t = &job.table;
    let pcol = t.column(probe.probe_field);
    let weight = |r: usize, n: f64| -> f64 {
        match job.op {
            AggOp::Count => n,
            AggOp::Sum => {
                let vf = job.val_field.expect("sum job needs val_field");
                t.value(r, vf).as_float().unwrap_or(0.0) * n
            }
        }
    };
    match job.num_keys {
        Some(num_keys) => {
            let kcol = t.column(job.key_field);
            let mut acc = vec![0.0f64; num_keys];
            for r in lo..hi {
                let n = probe.table.probe(&pcol.value(r)).len() as f64;
                if n == 0.0 {
                    continue;
                }
                let k = match kcol {
                    Column::DictStrs { keys, .. } => keys[r] as usize,
                    Column::Ints(keys) => keys[r] as usize,
                    // O(log runs) via the prefix-sum index.
                    Column::CompressedInts(c) => c.get(r) as usize,
                    _ => t.value(r, job.key_field).as_int().unwrap_or(0) as usize,
                };
                acc[k] += weight(r, n);
            }
            Partial::Dense(acc)
        }
        None => {
            let mut map: FxHashMap<Value, f64> = FxHashMap::default();
            for r in lo..hi {
                let n = probe.table.probe(&pcol.value(r)).len() as f64;
                if n == 0.0 {
                    continue;
                }
                *map.entry(t.value(r, job.key_field)).or_insert(0.0) += weight(r, n);
            }
            Partial::Assoc(map.into_iter().collect())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Multiset, Schema};

    fn string_table() -> Arc<Table> {
        let schema = Schema::new(vec![("url", DataType::Str)]);
        let mut m = Multiset::new(schema);
        for u in ["/a", "/b", "/a", "/c", "/a"] {
            m.push(vec![Value::str(u)]);
        }
        Arc::new(Table::from_multiset(&m).unwrap())
    }

    fn dict_table() -> Arc<Table> {
        let mut t = (*string_table()).clone();
        t.dict_encode_field(0).unwrap();
        Arc::new(t)
    }

    #[test]
    fn count_job_detects_density() {
        assert!(AggJob::count(string_table(), 0).num_keys.is_none());
        assert_eq!(AggJob::count(dict_table(), 0).num_keys, Some(3));
    }

    #[test]
    fn chunked_processing_equals_whole() {
        for table in [string_table(), dict_table()] {
            let job = AggJob::count(table, 0);
            let whole = process_chunk(&job, 0, 5);
            let mut acc1 = Acc::for_job(&job);
            acc1.merge(whole);
            let mut acc2 = Acc::for_job(&job);
            acc2.merge(process_chunk(&job, 0, 2));
            acc2.merge(process_chunk(&job, 2, 4));
            acc2.merge(process_chunk(&job, 4, 5));
            let mut a: Vec<(Value, f64)> = acc1.into_pairs(&job);
            let mut b: Vec<(Value, f64)> = acc2.into_pairs(&job);
            a.sort_by(|x, y| x.0.cmp(&y.0));
            b.sort_by(|x, y| x.0.cmp(&y.0));
            assert_eq!(a, b);
            assert_eq!(a.iter().map(|(_, n)| *n).sum::<f64>(), 5.0);
        }
    }

    #[test]
    fn dense_pairs_decode_dictionary() {
        let job = AggJob::count(dict_table(), 0);
        let mut acc = Acc::for_job(&job);
        acc.merge(process_chunk(&job, 0, 5));
        let mut pairs = acc.into_pairs(&job);
        pairs.sort_by(|x, y| x.0.cmp(&y.0));
        assert_eq!(pairs[0], (Value::str("/a"), 3.0));
        assert_eq!(pairs[1], (Value::str("/b"), 1.0));
    }

    #[test]
    fn join_count_chunks_match_nested_loop_oracle() {
        // Probe table A(b_id, g) against build table B(id); count matched
        // pairs per g — chunked processing must equal the whole table and
        // the brute-force nested loop.
        let a = {
            let schema = Schema::new(vec![("b_id", DataType::Int), ("g", DataType::Str)]);
            let mut m = Multiset::new(schema);
            for (id, g) in [(1, "x"), (2, "y"), (1, "x"), (9, "z"), (2, "x")] {
                m.push(vec![Value::Int(id), Value::str(g)]);
            }
            Arc::new(Table::from_multiset(&m).unwrap())
        };
        let b = {
            let schema = Schema::new(vec![("id", DataType::Int)]);
            let mut m = Multiset::new(schema);
            for id in [1, 1, 2] {
                m.push(vec![Value::Int(id)]);
            }
            Arc::new(Table::from_multiset(&m).unwrap())
        };
        // Oracle: nested loops.
        let mut want: std::collections::BTreeMap<String, f64> = Default::default();
        for ar in 0..a.len() {
            for br in 0..b.len() {
                if a.value(ar, 0) == b.value(br, 0) {
                    *want.entry(a.value(ar, 1).to_string()).or_default() += 1.0;
                }
            }
        }
        let probe = JoinProbe::new(&b, 0, 0);
        let job = AggJob::count_join(a, 1, probe);
        let mut whole = Acc::for_job(&job);
        whole.merge(process_chunk(&job, 0, 5));
        let mut chunked = Acc::for_job(&job);
        chunked.merge(process_chunk(&job, 0, 2));
        chunked.merge(process_chunk(&job, 2, 5));
        for acc in [whole, chunked] {
            let pairs = acc.into_pairs(&job);
            assert_eq!(pairs.len(), want.len());
            for (k, x) in pairs {
                assert_eq!(want[&k.to_string()], x, "key {k}");
            }
        }
    }

    #[test]
    fn join_sum_weights_by_multiplicity() {
        let a = {
            let schema = Schema::new(vec![("b_id", DataType::Int), ("v", DataType::Float)]);
            let mut m = Multiset::new(schema);
            for (id, v) in [(0, 1.5), (1, 2.0), (0, 0.5)] {
                m.push(vec![Value::Int(id), Value::Float(v)]);
            }
            Arc::new(Table::from_multiset(&m).unwrap())
        };
        let b = {
            let schema = Schema::new(vec![("id", DataType::Int)]);
            let mut m = Multiset::new(schema);
            for id in [0, 0, 1] {
                m.push(vec![Value::Int(id)]);
            }
            Arc::new(Table::from_multiset(&m).unwrap())
        };
        let probe = JoinProbe::new(&b, 0, 0);
        let job = AggJob::sum_join(a, 0, 1, probe);
        let mut acc = Acc::for_job(&job);
        acc.merge(process_chunk(&job, 0, 3));
        let mut pairs = acc.into_pairs(&job);
        pairs.sort_by(|x, y| x.0.cmp(&y.0));
        // key 0: (1.5 + 0.5) * 2 matches; key 1: 2.0 * 1 match.
        assert_eq!(pairs, vec![(Value::Int(0), 4.0), (Value::Int(1), 2.0)]);
    }

    #[test]
    fn compressed_key_chunks_run_in_run_domain() {
        use crate::storage::CompressedInts;
        // 40 runs of 5 rows: key = run index, val = row index. Chunk
        // boundaries are deliberately not run-aligned so the run-window
        // clipping is exercised.
        let keys: Vec<i64> = (0..200).map(|i| (i / 5) as i64).collect();
        let c = CompressedInts::compress(&keys).expect("run-length data compresses");
        assert!(matches!(c, CompressedInts::Rle { .. }));
        let schema = Schema::new(vec![("k", DataType::Int), ("v", DataType::Float)]);
        let t = Arc::new(
            Table::new(
                schema,
                vec![
                    Column::CompressedInts(c),
                    Column::Floats((0..200).map(|i| i as f64).collect()),
                ],
            )
            .unwrap(),
        );
        for job in [AggJob::count(t.clone(), 0), AggJob::sum(t.clone(), 0, 1)] {
            assert!(job.num_keys.is_some(), "compressed int keys are dense");
            let mut whole = Acc::for_job(&job);
            whole.merge(process_chunk(&job, 0, 200));
            let mut chunked = Acc::for_job(&job);
            chunked.merge(process_chunk(&job, 0, 7));
            chunked.merge(process_chunk(&job, 7, 123));
            chunked.merge(process_chunk(&job, 123, 200));
            let mut a = whole.into_pairs(&job);
            let mut b = chunked.into_pairs(&job);
            a.sort_by(|x, y| x.0.cmp(&y.0));
            b.sort_by(|x, y| x.0.cmp(&y.0));
            assert_eq!(a, b);
            assert_eq!(a.len(), 40);
            for (key, x) in &a {
                let k = key.as_int().unwrap();
                let want = match job.op {
                    AggOp::Count => 5.0,
                    AggOp::Sum => (5 * k..5 * k + 5).map(|i| i as f64).sum(),
                };
                assert_eq!(*x, want, "key {k}");
            }
        }
    }

    #[test]
    fn sum_job() {
        let schema = Schema::new(vec![("k", DataType::Int), ("v", DataType::Float)]);
        let mut m = Multiset::new(schema);
        for (k, v) in [(0, 1.5), (1, 2.0), (0, 0.5)] {
            m.push(vec![Value::Int(k), Value::Float(v)]);
        }
        let t = Arc::new(Table::from_multiset(&m).unwrap());
        let job = AggJob::sum(t, 0, 1);
        let mut acc = Acc::for_job(&job);
        acc.merge(process_chunk(&job, 0, 3));
        let mut pairs = acc.into_pairs(&job);
        pairs.sort_by(|x, y| x.0.cmp(&y.0));
        assert_eq!(pairs, vec![(Value::Int(0), 2.0), (Value::Int(1), 2.0)]);
    }
}
