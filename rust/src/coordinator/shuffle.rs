//! The shuffle-join executor: partition-pinned distributed joins with
//! skew-resistant repartitioning.
//!
//! [`run_job`](super::run_job) ships work morsel-by-morsel from one
//! global iteration space — perfect load balance, but every worker needs
//! the whole probe relation. When the optimizer decides a join is too
//! big to broadcast (`opt.dist_shuffle`), both sides are hash-shuffled
//! on the join key instead and each worker owns exactly its shard
//! (`dist.shuffle`): worker `k` probes shard `k` against the build rows
//! whose keys hash to `k`. Ownership is what makes key skew hurt — a
//! heavy-hitter key piles its entire partition onto one node — and what
//! [`detect_heavy_hitters`] + salting fix (`dist.repartition_skew`):
//! hot-key probe rows are dealt round-robin into per-node sub-shards and
//! the matching build rows are replicated, so the coordinator's final
//! merge reassembles the hot groups exactly.
//!
//! Faults follow the same [`FaultPlan`](crate::distrib::FaultPlan)
//! semantics as the morsel path: a dead worker's remaining chunks are
//! re-queued to survivors (who fetch the shard — charged), a dropped
//! flush re-executes the chunks it covered. There is no speculation
//! here: shards are pinned, so a straggler is a *skew* problem and the
//! salting pass is the mitigation.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::distrib::{
    channel, detect_heavy_hitters, hash_value, redistribute, redistribute_skew, split_direct,
    tuple_bytes, CommStats, Partitioning, SkewPlan,
};
use crate::ir::Value;
use crate::storage::{ColumnStats, Table};

use super::{ClusterConfig, JobResult, Metrics};

/// Target chunk count for a perfectly balanced cluster: every worker's
/// shard splits into ~this many chunks of uniform row width. The width
/// is global, so a skew-bloated shard shows up directly as more chunks
/// on its pinned worker — and as proportionally more re-queued work when
/// that worker dies.
const CHUNKS_PER_WORKER: usize = 16;

/// A distributed group-aggregate over an equi-join, executed by
/// shuffling both sides on the join key. Group key and the optional
/// summed field live on the probe side (the `AggJob::count_join` shape).
#[derive(Clone)]
pub struct ShuffleJoinSpec {
    pub probe: Table,
    pub probe_key: String,
    pub build: Table,
    pub build_key: String,
    /// Probe-side field the aggregate groups by.
    pub group_by: String,
    /// Detect heavy hitters and salt them across nodes; off = plain hash
    /// partitioning (the skew-suffering baseline the bench measures).
    pub repartition: bool,
}

/// One unit of probe work: rows `[lo, hi)` of probe shard `shard`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct ChunkRef {
    shard: usize,
    lo: usize,
    hi: usize,
}

impl ChunkRef {
    fn len(&self) -> usize {
        self.hi - self.lo
    }
}

enum WorkerMsg {
    Request { worker: usize },
    Done {
        worker: usize,
        chunks: Vec<ChunkRef>,
        partial: HashMap<Value, f64>,
    },
    Failed { worker: usize },
}

enum Task {
    Chunk(ChunkRef),
    /// Flush the local batch, then ask again.
    Drain,
}

fn partial_bytes(p: &HashMap<Value, f64>) -> usize {
    p.iter().map(|(k, _)| tuple_bytes(&[k.clone()]) + 8).sum()
}

/// Run the shuffle join. Results are exact under any fault plan a
/// dynamic-schedule cluster survives; metrics carry the `dist.shuffle` /
/// `dist.repartition_skew` tags plus the usual recovery counters.
pub fn run_shuffle_join(cfg: &ClusterConfig, spec: &ShuffleJoinSpec) -> Result<JobResult> {
    let t0 = Instant::now();
    let n = cfg.workers.max(1);
    let pk = field(&spec.probe, &spec.probe_key)?;
    let bk = field(&spec.build, &spec.build_key)?;
    let gb = field(&spec.probe, &spec.group_by)?;

    let comm = CommStats::new();

    // Shuffle the probe side: resident direct blocks → hash (or salted)
    // key partitioning, moved tuples charged.
    let plan = if spec.repartition {
        let stats = ColumnStats::collect(&spec.probe, pk);
        detect_heavy_hitters(&spec.probe, &spec.probe_key, &stats, n)?
    } else {
        SkewPlan::default()
    };
    let resident = split_direct(&spec.probe, n);
    let probe_shards = if plan.is_empty() {
        redistribute(
            &resident,
            &Partitioning::HashKey(spec.probe_key.clone()),
            &comm,
        )?
    } else {
        redistribute_skew(&resident, &spec.probe_key, &plan, &comm)?
    };

    // Build side: per-shard key→multiplicity maps. Cold keys go to the
    // shard their hash owns; hot keys are replicated everywhere (their
    // probe rows are spread). Each shipped copy is charged.
    let mut mult: Vec<HashMap<Value, f64>> = vec![HashMap::new(); n];
    let mut build_moved = 0usize;
    for row in 0..spec.build.len() {
        let k = spec.build.value(row, bk);
        let bytes = tuple_bytes(&spec.build.tuple(row));
        if plan.is_hot(&k) {
            build_moved += bytes * (n - 1);
            for m in mult.iter_mut() {
                *m.entry(k.clone()).or_insert(0.0) += 1.0;
            }
        } else {
            let dst = (hash_value(&k) % n as u64) as usize;
            build_moved += bytes;
            *mult[dst].entry(k).or_insert(0.0) += 1.0;
        }
    }
    comm.record(build_moved);

    let total_rows: usize = probe_shards.iter().map(|t| t.len()).sum();
    let shards = Arc::new(probe_shards);
    let mult = Arc::new(mult);

    // Per-shard chunk queues of globally uniform row width; worker k
    // owns queue k (pinned).
    let per = total_rows.div_ceil(n * CHUNKS_PER_WORKER).max(1);
    let mut queues: Vec<VecDeque<ChunkRef>> = (0..n)
        .map(|s| {
            let len = shards[s].len();
            let mut q = VecDeque::new();
            let mut lo = 0;
            while lo < len {
                let hi = (lo + per).min(len);
                q.push_back(ChunkRef { shard: s, lo, hi });
                lo = hi;
            }
            q
        })
        .collect();

    let (msg_tx, msg_rx) = channel::<WorkerMsg>(cfg.queue_capacity, comm.clone(), cfg.link);

    let mut metrics = Metrics::default();
    metrics.note_tag("dist.shuffle");
    if !plan.is_empty() {
        metrics.note_tag("dist.repartition_skew");
    }

    let result = std::thread::scope(|scope| -> Result<HashMap<Value, f64>> {
        let mut chunk_txs: Vec<Option<Sender<Option<Task>>>> = Vec::new();
        let mut handles = Vec::new();
        for w in 0..n {
            let (ctx, crx) = std::sync::mpsc::channel::<Option<Task>>();
            chunk_txs.push(Some(ctx));
            let msg_tx = msg_tx.clone();
            let shards = shards.clone();
            let mult = mult.clone();
            let multiplier = cfg.slowdown_of(w);
            let crash = cfg.crash_of(w);
            let flush_every = cfg.flush_every.max(1);
            let row_cost = cfg.row_cost;
            handles.push(scope.spawn(move || {
                shuffle_worker(
                    w, &shards, &mult, pk, gb, crx, msg_tx, multiplier,
                    crash.map(|c| c.after_chunks), flush_every, row_cost,
                );
            }));
        }
        drop(msg_tx);

        // Chunks orphaned by a death or a dropped flush: any survivor may
        // take them (it fetches the rows — charged on requeue).
        let mut reassign: VecDeque<ChunkRef> = VecDeque::new();
        let mut outstanding: Vec<Option<ChunkRef>> = vec![None; n];
        let mut unflushed: Vec<Vec<ChunkRef>> = vec![Vec::new(); n];
        let mut parked: Vec<usize> = Vec::new();
        let mut flushes_seen = vec![0usize; n];
        let mut alive = vec![true; n];
        let mut completed = 0usize;
        let mut acc: HashMap<Value, f64> = HashMap::new();

        let requeue = |chunks: Vec<ChunkRef>,
                       reassign: &mut VecDeque<ChunkRef>,
                       metrics: &mut Metrics,
                       charge_fetch: bool| {
            metrics.chunks_retried += chunks.len();
            if charge_fetch {
                // The new owner pulls the rows from distributed storage.
                let bytes: usize = chunks
                    .iter()
                    .map(|c| {
                        (c.lo..c.hi)
                            .map(|r| tuple_bytes(&shards[c.shard].tuple(r)))
                            .sum::<usize>()
                    })
                    .sum();
                comm.record(bytes);
            }
            reassign.extend(chunks);
        };

        fn assign(
            w: usize,
            queues: &mut [VecDeque<ChunkRef>],
            reassign: &mut VecDeque<ChunkRef>,
        ) -> Option<ChunkRef> {
            queues[w].pop_front().or_else(|| reassign.pop_front())
        }

        while completed < total_rows {
            let Ok(msg) = msg_rx.recv() else {
                bail!("all workers failed before the shuffle join completed");
            };
            match msg {
                WorkerMsg::Request { worker } => {
                    if let Some(done) = outstanding[worker].take() {
                        unflushed[worker].push(done);
                    }
                    if let Some(c) = assign(worker, &mut queues, &mut reassign) {
                        outstanding[worker] = Some(c);
                        send(&mut chunk_txs, worker, Some(Task::Chunk(c)));
                    } else if completed < total_rows {
                        if unflushed[worker].is_empty() {
                            parked.push(worker);
                        } else {
                            send(&mut chunk_txs, worker, Some(Task::Drain));
                        }
                    } else {
                        send(&mut chunk_txs, worker, None);
                    }
                }
                WorkerMsg::Done {
                    worker,
                    chunks,
                    partial,
                } => {
                    let nth = flushes_seen[worker];
                    flushes_seen[worker] += 1;
                    unflushed[worker].retain(|c| !chunks.contains(c));
                    if let Some(c) = outstanding[worker] {
                        if chunks.contains(&c) {
                            outstanding[worker] = None;
                        }
                    }
                    if cfg.faults.loses_flush(worker, nth) {
                        metrics.lost_flushes += 1;
                        requeue(chunks, &mut reassign, &mut metrics, false);
                    } else {
                        completed += chunks.iter().map(ChunkRef::len).sum::<usize>();
                        metrics.chunks += chunks.len();
                        *metrics.chunks_per_worker.entry(worker).or_default() += chunks.len();
                        for (k, v) in partial {
                            *acc.entry(k).or_insert(0.0) += v;
                        }
                    }
                }
                WorkerMsg::Failed { worker } => {
                    alive[worker] = false;
                    chunk_txs[worker] = None;
                    let mut lost: Vec<ChunkRef> = unflushed[worker].drain(..).collect();
                    lost.extend(outstanding[worker].take());
                    lost.extend(std::mem::take(&mut queues[worker]));
                    if alive.iter().filter(|&&a| a).count() == 0 {
                        bail!("all workers failed before the shuffle join completed");
                    }
                    if !lost.is_empty() {
                        metrics.failures_recovered += 1;
                        requeue(lost, &mut reassign, &mut metrics, true);
                    }
                }
            }
            // New work may have arrived for parked workers.
            let waiting = std::mem::take(&mut parked);
            for w in waiting {
                if let Some(c) = assign(w, &mut queues, &mut reassign) {
                    outstanding[w] = Some(c);
                    send(&mut chunk_txs, w, Some(Task::Chunk(c)));
                } else {
                    parked.push(w);
                }
            }
        }

        for w in 0..n {
            send(&mut chunk_txs, w, None);
        }
        chunk_txs.clear();
        while msg_rx.try_recv().is_ok() {}
        for h in handles {
            let _ = h.join();
        }
        Ok(acc)
    })?;

    metrics.comm_bytes = comm.total_bytes();
    metrics.comm_messages = comm.total_messages();
    metrics.elapsed = t0.elapsed();
    metrics.finalize_fault_tags();
    let mut pairs: Vec<(Value, f64)> = result.into_iter().collect();
    pairs.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(JobResult { pairs, metrics })
}

fn field(t: &Table, name: &str) -> Result<usize> {
    t.schema
        .field_id(name)
        .ok_or_else(|| anyhow::anyhow!("no field `{name}`"))
}

fn send(txs: &mut [Option<Sender<Option<Task>>>], w: usize, task: Option<Task>) {
    if let Some(tx) = &txs[w] {
        if tx.send(task).is_err() {
            txs[w] = None;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn shuffle_worker(
    w: usize,
    shards: &[Table],
    mult: &[HashMap<Value, f64>],
    pk: usize,
    gb: usize,
    chunk_rx: std::sync::mpsc::Receiver<Option<Task>>,
    msg_tx: crate::distrib::Tx<WorkerMsg>,
    multiplier: f64,
    crash_after: Option<usize>,
    flush_every: usize,
    row_cost: Duration,
) {
    let mut processed = 0usize;
    let mut local: HashMap<Value, f64> = HashMap::new();
    let mut covered: Vec<ChunkRef> = Vec::new();

    let flush = |local: &mut HashMap<Value, f64>, covered: &mut Vec<ChunkRef>| -> bool {
        if covered.is_empty() {
            return true;
        }
        let partial = std::mem::take(local);
        let bytes = partial_bytes(&partial);
        msg_tx.send(
            WorkerMsg::Done {
                worker: w,
                chunks: std::mem::take(covered),
                partial,
            },
            bytes,
        )
    };

    loop {
        if !msg_tx.send(WorkerMsg::Request { worker: w }, 16) {
            return;
        }
        let chunk = match chunk_rx.recv() {
            Ok(Some(Task::Chunk(c))) => c,
            Ok(Some(Task::Drain)) => {
                if !flush(&mut local, &mut covered) {
                    return;
                }
                continue;
            }
            _ => {
                let _ = flush(&mut local, &mut covered);
                return;
            }
        };
        if let Some(after) = crash_after {
            if processed >= after {
                let _ = msg_tx.send(WorkerMsg::Failed { worker: w }, 16);
                return;
            }
        }
        let t0 = Instant::now();
        let shard = &shards[chunk.shard];
        let table = &mult[chunk.shard];
        for row in chunk.lo..chunk.hi {
            let Some(&m) = table.get(&shard.value(row, pk)) else {
                continue;
            };
            *local.entry(shard.value(row, gb)).or_insert(0.0) += m;
        }
        let real = t0.elapsed();
        let sim = row_cost.mul_f64(chunk.len() as f64 * multiplier);
        let extra = real.mul_f64(multiplier - 1.0) + sim;
        if extra > Duration::ZERO {
            std::thread::sleep(extra);
        }
        processed += 1;
        covered.push(chunk);
        if covered.len() >= flush_every && !flush(&mut local, &mut covered) {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distrib::FaultPlan;
    use crate::ir::{DataType, Multiset, Schema};
    use crate::sched::Policy;

    /// A skewed fact (60% of rows on key 0) joined to a small dim.
    fn spec(rows: usize, skew: bool, repartition: bool) -> ShuffleJoinSpec {
        let fact_schema = Schema::new(vec![("k", DataType::Int), ("g", DataType::Int)]);
        let mut fact = Multiset::new(fact_schema);
        let hot = if skew { (rows as f64 * 0.6) as usize } else { 0 };
        for i in 0..rows {
            let k = if i < hot { 0 } else { (i % 40) as i64 };
            fact.push(vec![Value::Int(k), Value::Int((i % 7) as i64)]);
        }
        let dim_schema = Schema::new(vec![("id", DataType::Int)]);
        let mut dim = Multiset::new(dim_schema);
        for k in 0..40i64 {
            dim.push(vec![Value::Int(k)]);
        }
        ShuffleJoinSpec {
            probe: Table::from_multiset(&fact).unwrap(),
            probe_key: "k".into(),
            build: Table::from_multiset(&dim).unwrap(),
            build_key: "id".into(),
            group_by: "g".into(),
            repartition,
        }
    }

    /// Sequential oracle: group counts of the joined rows.
    fn oracle(s: &ShuffleJoinSpec) -> Vec<(Value, f64)> {
        let pk = s.probe.schema.field_id(&s.probe_key).unwrap();
        let bk = s.build.schema.field_id(&s.build_key).unwrap();
        let gb = s.probe.schema.field_id(&s.group_by).unwrap();
        let mut mult: HashMap<Value, f64> = HashMap::new();
        for r in 0..s.build.len() {
            *mult.entry(s.build.value(r, bk)).or_insert(0.0) += 1.0;
        }
        let mut acc: HashMap<Value, f64> = HashMap::new();
        for r in 0..s.probe.len() {
            if let Some(&m) = mult.get(&s.probe.value(r, pk)) {
                *acc.entry(s.probe.value(r, gb)).or_insert(0.0) += m;
            }
        }
        let mut v: Vec<_> = acc.into_iter().collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    #[test]
    fn shuffle_join_matches_oracle_with_and_without_salting() {
        let cfg = ClusterConfig::new(4, Policy::FixedChunk(64));
        for repartition in [false, true] {
            let s = spec(4000, true, repartition);
            let r = run_shuffle_join(&cfg, &s).unwrap();
            assert_eq!(r.pairs, oracle(&s));
            assert!(r.metrics.tags.iter().any(|t| t == "dist.shuffle"));
            assert_eq!(
                r.metrics.tags.iter().any(|t| t == "dist.repartition_skew"),
                repartition,
                "salting tag must track the decision: {:?}",
                r.metrics.tags
            );
        }
    }

    #[test]
    fn uniform_keys_never_trigger_the_salting_tag() {
        let cfg = ClusterConfig::new(4, Policy::FixedChunk(64));
        let s = spec(4000, false, true);
        let r = run_shuffle_join(&cfg, &s).unwrap();
        assert_eq!(r.pairs, oracle(&s));
        assert!(!r.metrics.tags.iter().any(|t| t == "dist.repartition_skew"));
    }

    #[test]
    fn salting_rebalances_the_hot_shard() {
        let cfg = ClusterConfig::new(4, Policy::FixedChunk(64));
        let skewed = run_shuffle_join(&cfg, &spec(4000, true, false)).unwrap();
        let salted = run_shuffle_join(&cfg, &spec(4000, true, true)).unwrap();
        let max_of = |m: &Metrics| *m.chunks_per_worker.values().max().unwrap();
        assert!(
            max_of(&salted.metrics) < max_of(&skewed.metrics),
            "salting must shrink the hottest worker's share: {:?} vs {:?}",
            salted.metrics.chunks_per_worker,
            skewed.metrics.chunks_per_worker
        );
    }

    #[test]
    fn crash_and_lost_flush_recover_exactly() {
        let s = spec(4000, true, true);
        let want = oracle(&s);
        let cfg = ClusterConfig::new(4, Policy::FixedChunk(64))
            .with_flush_every(2)
            .with_faults(FaultPlan::none().crash(1, 2).lose_flush(0, 0));
        let r = run_shuffle_join(&cfg, &s).unwrap();
        assert_eq!(r.pairs, want);
        assert_eq!(r.metrics.lost_flushes, 1);
        assert!(r.metrics.failures_recovered >= 1);
        assert!(r.metrics.chunks_retried >= 2);
        assert!(r.metrics.tags.iter().any(|t| t == "dist.retry"));
        assert!(r.metrics.tags.iter().any(|t| t == "dist.lost_result"));
    }
}
