//! The L3 coordinator: leader/worker execution of parallelized loops on
//! the simulated cluster.
//!
//! The leader owns the loop scheduler (§III-A2) and hands chunks to
//! worker nodes over cost-accounted channels; workers run the generated
//! inner loop (`job::process_chunk`) and stream partial aggregates back
//! (bounded queue = backpressure). Node failures (§III-A3) are injected
//! by configuration: a failing worker abandons its in-flight chunk, and
//! the leader re-queues exactly that chunk under any dynamic policy — or
//! reports that a restart is required under a static schedule, matching
//! the paper's analysis.

pub mod job;

use std::collections::BTreeMap;
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::distrib::{channel, CommStats, LinkModel, Tx};
use crate::ir::{Multiset, Schema, Value};
use crate::sched::{Chunk, Policy, Scheduler};

pub use job::{process_chunk, Acc, AggJob, AggOp, JoinProbe, Partial};

/// Failure injection: `worker` dies after completing `after_chunks`.
#[derive(Debug, Clone, Copy)]
pub struct Failure {
    pub worker: usize,
    pub after_chunks: usize,
}

/// Cluster configuration (the DAS-4 stand-in).
#[derive(Clone)]
pub struct ClusterConfig {
    pub workers: usize,
    pub policy: Policy,
    pub link: LinkModel,
    /// Per-worker slowdown multiplier (1.0 = full speed). Shorter than
    /// `workers` → remaining workers run at 1.0.
    pub slowdown: Vec<f64>,
    pub failure: Option<Failure>,
    /// Result-queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Workers merge this many chunks locally before flushing a partial
    /// to the leader. 1 = per-chunk flush (finest failure granularity);
    /// larger values amortize merge + comm cost, at the price of
    /// re-queueing up to `flush_every` chunks when a node dies — the
    /// static-inside-dynamic trade of the paper's hybrid scheme, applied
    /// to result flushing (see EXPERIMENTS.md §Perf).
    pub flush_every: usize,
}

impl ClusterConfig {
    pub fn new(workers: usize, policy: Policy) -> Self {
        ClusterConfig {
            workers,
            policy,
            link: LinkModel::instant(),
            slowdown: vec![],
            failure: None,
            queue_capacity: 64,
            flush_every: 8,
        }
    }

    pub fn with_flush_every(mut self, n: usize) -> Self {
        self.flush_every = n.max(1);
        self
    }

    pub fn with_link(mut self, link: LinkModel) -> Self {
        self.link = link;
        self
    }

    pub fn with_slowdown(mut self, s: Vec<f64>) -> Self {
        self.slowdown = s;
        self
    }

    pub fn with_failure(mut self, f: Failure) -> Self {
        self.failure = Some(f);
        self
    }

    fn slowdown_of(&self, w: usize) -> f64 {
        self.slowdown.get(w).copied().unwrap_or(1.0).max(1.0)
    }
}

/// Execution metrics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub elapsed: Duration,
    pub chunks: usize,
    pub comm_bytes: u64,
    pub comm_messages: u64,
    pub failures_recovered: usize,
    pub restarts: usize,
    pub chunks_per_worker: BTreeMap<usize, usize>,
}

/// A completed job.
#[derive(Debug)]
pub struct JobResult {
    pub pairs: Vec<(Value, f64)>,
    pub metrics: Metrics,
}

impl JobResult {
    /// Render as a (key, count) multiset for oracle comparison.
    pub fn to_multiset(&self, schema: Schema) -> Multiset {
        let int_out = matches!(schema.dtype(1), crate::ir::DataType::Int);
        let mut m = Multiset::new(schema);
        for (k, x) in &self.pairs {
            let v = if int_out {
                Value::Int(*x as i64)
            } else {
                Value::Float(*x)
            };
            m.push(vec![k.clone(), v]);
        }
        m
    }
}

enum WorkerMsg {
    Request { worker: usize },
    /// A flushed batch: the chunks covered + their merged partial.
    Done {
        worker: usize,
        chunks: Vec<Chunk>,
        partial: Partial,
        elapsed: Duration,
    },
    Failed { worker: usize },
}

/// Run a distributed aggregation job, retrying whole-job restarts when a
/// static schedule loses work (§III-A3: "the computation has to be
/// restarted").
pub fn run_job(cfg: &ClusterConfig, job: &AggJob) -> Result<JobResult> {
    let t0 = Instant::now();
    let mut restarts = 0;
    loop {
        match run_once(cfg, job, restarts) {
            Ok(mut r) => {
                r.metrics.restarts = restarts;
                r.metrics.elapsed = t0.elapsed();
                return Ok(r);
            }
            Err(e) if e.to_string().contains("restart required") => {
                restarts += 1;
                if restarts > 3 {
                    bail!("job failed after {restarts} restarts: {e}");
                }
                // On restart the failed node is excluded (the cluster
                // manager reprovisions): run with one fewer worker and no
                // further injected failure.
                let mut cfg2 = cfg.clone();
                cfg2.failure = None;
                cfg2.workers = (cfg.workers - 1).max(1);
                let mut r = run_once(&cfg2, job, restarts)?;
                r.metrics.restarts = restarts;
                r.metrics.elapsed = t0.elapsed();
                return Ok(r);
            }
            Err(e) => return Err(e),
        }
    }
}

fn run_once(cfg: &ClusterConfig, job: &AggJob, attempt: usize) -> Result<JobResult> {
    let n = job.rows();
    let stats = CommStats::new();
    let mut scheduler = Scheduler::new(cfg.policy, n, cfg.workers);
    let supports_requeue = scheduler.supports_requeue();

    // Accounted, bounded worker→leader channel (backpressure).
    let (msg_tx, msg_rx) = channel::<WorkerMsg>(cfg.queue_capacity, stats.clone(), cfg.link);
    let job = job.clone();
    let job_arc = Arc::new(job);

    std::thread::scope(|scope| -> Result<JobResult> {
        // Leader→worker chunk channels (plain; replies are tiny).
        let mut chunk_txs: Vec<Option<Sender<Option<Chunk>>>> = Vec::new();
        let mut handles = Vec::new();
        for w in 0..cfg.workers {
            let (ctx, crx) = std::sync::mpsc::channel::<Option<Chunk>>();
            chunk_txs.push(Some(ctx));
            let msg_tx = msg_tx.clone();
            let job = job_arc.clone();
            let slowdown = cfg.slowdown_of(w);
            // Failure only fires on the first attempt.
            let failure = cfg.failure.filter(|f| f.worker == w && attempt == 0);
            let flush_every = cfg.flush_every.max(1);
            handles.push(scope.spawn(move || {
                worker_loop(w, &job, crx, msg_tx, slowdown, failure, flush_every);
            }));
        }
        drop(msg_tx); // leader keeps only the rx side

        let mut acc = Acc::for_job(&job_arc);
        let mut metrics = Metrics::default();
        let mut completed = 0usize;
        let mut outstanding: Vec<Option<Chunk>> = vec![None; cfg.workers];
        // Chunks a worker finished but has not flushed yet: lost with the
        // node's memory if it dies (re-queued on failure).
        let mut unflushed: Vec<Vec<Chunk>> = vec![Vec::new(); cfg.workers];
        let mut lost_work = false;

        while completed < n {
            let Ok(msg) = msg_rx.recv() else {
                // All workers gone before completion.
                if lost_work || completed < n {
                    bail!("workers exited early; restart required");
                }
                break;
            };
            match msg {
                WorkerMsg::Request { worker } => {
                    // The previously assigned chunk is now processed (the
                    // worker asks again only after finishing) but unflushed.
                    if let Some(done) = outstanding[worker].take() {
                        unflushed[worker].push(done);
                    }
                    let chunk = scheduler.next_chunk(worker);
                    outstanding[worker] = chunk;
                    if let Some(tx) = &chunk_txs[worker] {
                        let _ = tx.send(chunk);
                    }
                }
                WorkerMsg::Done {
                    worker,
                    chunks,
                    partial,
                    elapsed,
                } => {
                    let batch: usize = chunks.iter().map(|c| c.len()).sum();
                    for chunk in &chunks {
                        scheduler.report(
                            worker,
                            *chunk,
                            elapsed.mul_f64(chunk.len() as f64 / batch.max(1) as f64),
                        );
                    }
                    // These chunks are now durable at the leader.
                    unflushed[worker].retain(|c| !chunks.contains(c));
                    if let Some(c) = outstanding[worker] {
                        if chunks.contains(&c) {
                            outstanding[worker] = None;
                        }
                    }
                    acc.merge(partial);
                    completed += batch;
                    metrics.chunks += chunks.len();
                    *metrics.chunks_per_worker.entry(worker).or_default() += chunks.len();
                }
                WorkerMsg::Failed { worker } => {
                    // In-flight AND unflushed chunks are lost with the
                    // node's memory.
                    let mut lost: Vec<Chunk> = unflushed[worker].drain(..).collect();
                    lost.extend(outstanding[worker].take());
                    chunk_txs[worker] = None; // node is gone
                    if !lost.is_empty() {
                        if supports_requeue {
                            for chunk in lost {
                                scheduler.requeue(chunk);
                            }
                            metrics.failures_recovered += 1;
                        } else {
                            lost_work = true;
                        }
                    } else if !supports_requeue {
                        // Even with no in-flight chunk, a static schedule
                        // cannot move the node's unprocessed block.
                        if !scheduler.exhausted() {
                            lost_work = true;
                        }
                    }
                    if lost_work {
                        bail!(
                            "node {worker} failed under a static schedule; restart required"
                        );
                    }
                }
            }
        }

        // Tell idle workers to stop.
        for tx in chunk_txs.iter().flatten() {
            let _ = tx.send(None);
        }
        drop(chunk_txs);
        // Drain any in-flight messages so workers blocked on the bounded
        // queue can exit, then join.
        while msg_rx.try_recv().is_ok() {}
        for h in handles {
            let _ = h.join();
        }

        metrics.comm_bytes = stats.total_bytes();
        metrics.comm_messages = stats.total_messages();
        Ok(JobResult {
            pairs: acc.into_pairs(&job_arc),
            metrics,
        })
    })
}

fn worker_loop(
    w: usize,
    job: &AggJob,
    chunk_rx: std::sync::mpsc::Receiver<Option<Chunk>>,
    msg_tx: Tx<WorkerMsg>,
    slowdown: f64,
    failure: Option<Failure>,
    flush_every: usize,
) {
    let mut processed = 0usize;
    // Local accumulation between flushes (amortizes leader merge + comm).
    let mut local = Acc::for_job(job);
    let mut covered: Vec<Chunk> = Vec::new();
    let mut batch_t = Duration::ZERO;

    let flush = |local: &mut Acc,
                 covered: &mut Vec<Chunk>,
                 batch_t: &mut Duration|
     -> bool {
        if covered.is_empty() {
            return true;
        }
        let partial = std::mem::replace(local, Acc::for_job(job)).into_partial();
        let bytes = partial.wire_bytes();
        let ok = msg_tx.send(
            WorkerMsg::Done {
                worker: w,
                chunks: std::mem::take(covered),
                partial,
                elapsed: std::mem::replace(batch_t, Duration::ZERO),
            },
            bytes,
        );
        ok
    };

    loop {
        if !msg_tx.send(WorkerMsg::Request { worker: w }, 16) {
            return;
        }
        let chunk = match chunk_rx.recv() {
            Ok(Some(c)) => c,
            _ => {
                // Loop exhausted: flush what we hold, then exit.
                let _ = flush(&mut local, &mut covered, &mut batch_t);
                return;
            }
        };
        // Injected crash: die holding the in-flight chunk AND any
        // unflushed local state (both are lost with this node's memory).
        if let Some(f) = failure {
            if processed >= f.after_chunks {
                let _ = msg_tx.send(WorkerMsg::Failed { worker: w }, 16);
                return;
            }
        }
        let t0 = Instant::now();
        let partial = process_chunk(job, chunk.lo, chunk.hi);
        local.merge(partial);
        covered.push(chunk);
        let real = t0.elapsed();
        if slowdown > 1.0 {
            std::thread::sleep(real.mul_f64(slowdown - 1.0));
        }
        batch_t += t0.elapsed();
        processed += 1;
        if covered.len() >= flush_every && !flush(&mut local, &mut covered, &mut batch_t) {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Multiset, Schema};
    use crate::storage::Table;
    use crate::util::forall_seeds;
    use crate::workload::{access_log, AccessLogSpec};

    fn table(rows: usize, urls: usize, dict: bool) -> Arc<Table> {
        let m = access_log(&AccessLogSpec {
            rows,
            urls,
            skew: 1.1,
            seed: 11,
        });
        let mut t = Table::from_multiset(&m).unwrap();
        if dict {
            t.dict_encode_field(0).unwrap();
        }
        Arc::new(t)
    }

    fn oracle(t: &Arc<Table>) -> std::collections::HashMap<Value, f64> {
        let mut m = std::collections::HashMap::new();
        for r in 0..t.len() {
            *m.entry(t.value(r, 0)).or_insert(0.0) += 1.0;
        }
        m
    }

    fn check(result: &JobResult, t: &Arc<Table>) {
        let want = oracle(t);
        assert_eq!(result.pairs.len(), want.len());
        for (k, x) in &result.pairs {
            assert_eq!(want[k], *x, "key {k}");
        }
    }

    #[test]
    fn all_policies_compute_correct_counts() {
        let t = table(20_000, 500, true);
        for policy in [
            Policy::StaticBlock,
            Policy::FixedChunk(1024),
            Policy::Gss,
            Policy::Trapezoid,
            Policy::Factoring,
            Policy::FeedbackGuided,
            Policy::Hybrid {
                super_chunks_per_worker: 4,
            },
        ] {
            let cfg = ClusterConfig::new(8, policy);
            let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
            check(&r, &t);
        }
    }

    #[test]
    fn string_tables_use_assoc_path() {
        let t = table(5_000, 200, false);
        let job = AggJob::count(t.clone(), 0);
        assert!(job.num_keys.is_none());
        let r = run_job(&ClusterConfig::new(4, Policy::Gss), &job).unwrap();
        check(&r, &t);
    }

    #[test]
    fn dynamic_policy_survives_node_failure() {
        let t = table(50_000, 300, true);
        let cfg = ClusterConfig::new(4, Policy::FixedChunk(512)).with_failure(Failure {
            worker: 2,
            after_chunks: 3,
        });
        let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
        check(&r, &t);
        assert_eq!(r.metrics.failures_recovered, 1);
        assert_eq!(r.metrics.restarts, 0);
        // The dead worker did limited work.
        assert!(r.metrics.chunks_per_worker.get(&2).copied().unwrap_or(0) <= 3);
    }

    #[test]
    fn static_policy_requires_restart_on_failure() {
        let t = table(50_000, 300, true);
        let cfg = ClusterConfig::new(4, Policy::StaticBlock).with_failure(Failure {
            worker: 1,
            after_chunks: 0,
        });
        let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
        check(&r, &t);
        assert_eq!(r.metrics.restarts, 1);
    }

    #[test]
    fn hybrid_recovers_at_super_chunk_granularity() {
        let t = table(50_000, 300, true);
        let cfg = ClusterConfig::new(
            4,
            Policy::Hybrid {
                super_chunks_per_worker: 8,
            },
        )
        .with_failure(Failure {
            worker: 0,
            after_chunks: 2,
        });
        let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
        check(&r, &t);
        assert_eq!(r.metrics.failures_recovered, 1);
    }

    #[test]
    fn coordinator_matches_exec_oracle_via_multiset() {
        let t = table(3_000, 100, true);
        let r = run_job(&ClusterConfig::new(3, Policy::Gss), &AggJob::count(t.clone(), 0))
            .unwrap();
        let schema = Schema::new(vec![("url", DataType::Str), ("n", DataType::Int)]);
        let got = r.to_multiset(schema.clone());
        let mut want = Multiset::new(schema);
        for (k, v) in oracle(&t) {
            want.push(vec![k, Value::Int(v as i64)]);
        }
        assert!(got.bag_eq(&want));
    }

    #[test]
    fn distributed_join_count_matches_single_chunk_oracle() {
        let probe_t = table(20_000, 300, true);
        // Dimension side: a sample of the probe table's url values, with
        // one duplicate so multiplicities > 1 occur.
        let build = {
            let schema = Schema::new(vec![("url", DataType::Str)]);
            let mut m = Multiset::new(schema);
            for r in (0..probe_t.len()).step_by(97) {
                m.push(vec![probe_t.value(r, 0)]);
            }
            m.push(vec![probe_t.value(0, 0)]);
            Arc::new(crate::storage::Table::from_multiset(&m).unwrap())
        };
        let probe = JoinProbe::new(&build, 0, 0);
        let job = AggJob::count_join(probe_t.clone(), 0, probe);

        let mut acc = Acc::for_job(&job);
        acc.merge(process_chunk(&job, 0, probe_t.len()));
        let mut want = acc.into_pairs(&job);
        want.sort_by(|x, y| x.0.cmp(&y.0));

        for cfg in [
            ClusterConfig::new(4, Policy::Gss),
            ClusterConfig::new(4, Policy::FixedChunk(512)).with_failure(Failure {
                worker: 1,
                after_chunks: 2,
            }),
        ] {
            let r = run_job(&cfg, &job).unwrap();
            let mut got = r.pairs.clone();
            got.sort_by(|x, y| x.0.cmp(&y.0));
            assert_eq!(got, want);
        }
    }

    #[test]
    fn property_random_configs_are_exact() {
        // Seed-driven property: any (policy, workers, failure point)
        // combination yields exact counts.
        let t = table(8_000, 64, true);
        let want = oracle(&t);
        forall_seeds(12, |rng| {
            let policies = [
                Policy::FixedChunk(256 + rng.below(1024) as usize),
                Policy::Gss,
                Policy::Trapezoid,
                Policy::Factoring,
                Policy::Hybrid {
                    super_chunks_per_worker: 1 + rng.below(8) as usize,
                },
            ];
            let policy = policies[rng.below(policies.len() as u64) as usize];
            let workers = 1 + rng.below(8) as usize;
            let mut cfg = ClusterConfig::new(workers, policy);
            if rng.below(2) == 1 && workers > 1 {
                cfg = cfg.with_failure(Failure {
                    worker: rng.below(workers as u64) as usize,
                    after_chunks: rng.below(4) as usize,
                });
            }
            let r = run_job(&cfg, &AggJob::count(t.clone(), 0))
                .map_err(|e| format!("job failed: {e}"))?;
            crate::prop_assert!(
                r.pairs.len() == want.len(),
                "distinct keys {} != {}",
                r.pairs.len(),
                want.len()
            );
            for (k, x) in &r.pairs {
                crate::prop_assert!(want[k] == *x, "key {k}: {x} != {}", want[k]);
            }
            Ok(())
        });
    }
}
