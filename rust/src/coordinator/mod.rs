//! The L3 coordinator: leader/worker execution of parallelized loops on
//! the simulated cluster.
//!
//! The leader owns the loop scheduler (§III-A2) and hands chunks to
//! worker nodes over cost-accounted channels; workers run the generated
//! inner loop (`job::process_chunk`) and stream partial aggregates back
//! (bounded queue = backpressure).
//!
//! Resilience (§III-A3) is per-chunk, not per-job: the leader keeps a
//! *commit set* of merged chunks, so any chunk can safely be executed
//! more than once — the classic MapReduce re-execution model. Three
//! recovery paths hang off it, all driven by a deterministic
//! [`FaultPlan`](crate::distrib::FaultPlan):
//!
//! * **crash** — a dead worker's in-flight and unflushed chunks are
//!   re-queued under any dynamic policy (`dist.retry`); a static
//!   schedule cannot move the lost block and the whole job restarts on
//!   the surviving nodes (`dist.restart`), matching the paper's
//!   "computation has to be restarted" analysis.
//! * **straggler** — workers report virtual cost units alongside wall
//!   time; a worker whose per-iteration cost exceeds
//!   [`STRAGGLER_FACTOR`] × the fastest observed rate is marked a
//!   straggler. Its subsequent chunks are issued as single-flush
//!   speculative tasks and duplicated to the next free worker;
//!   first-result-wins via the commit set (`dist.speculative`).
//! * **lost result** — a flushed partial dropped in transit is detected
//!   at the leader (the simulation injects the drop there) and the
//!   covered chunks are re-queued (`dist.lost_result`).

pub mod job;
pub mod shuffle;

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::distrib::{channel, CommStats, Crash, FaultPlan, LinkModel, Tx};
use crate::ir::{Multiset, Schema, Value};
use crate::sched::{Chunk, Policy, Scheduler};

pub use job::{process_chunk, Acc, AggJob, AggOp, JoinProbe, Partial};
pub use shuffle::{run_shuffle_join, ShuffleJoinSpec};

/// Legacy failure injection: `worker` dies after completing
/// `after_chunks`. Kept as a convenience alias for single-crash plans;
/// [`FaultPlan`] is the general schedule.
#[derive(Debug, Clone, Copy)]
pub struct Failure {
    pub worker: usize,
    pub after_chunks: usize,
}

/// A worker is a straggler when its per-iteration cost is at least this
/// many times the fastest reporting worker's. Cost is measured in
/// *virtual units* (rows × injected multiplier), so detection is exact
/// and deterministic under a [`FaultPlan`] — no wall-clock flakiness.
pub const STRAGGLER_FACTOR: f64 = 4.0;

/// Cluster configuration (the DAS-4 stand-in).
#[derive(Clone)]
pub struct ClusterConfig {
    pub workers: usize,
    pub policy: Policy,
    pub link: LinkModel,
    /// Per-worker slowdown multiplier (1.0 = full speed). Shorter than
    /// `workers` → remaining workers run at 1.0. Merged with the fault
    /// plan's latency multipliers (the worse one wins).
    pub slowdown: Vec<f64>,
    pub failure: Option<Failure>,
    /// The deterministic fault schedule (crashes, stragglers, lost
    /// results). Applies to the first attempt only: a whole-job restart
    /// runs on reprovisioned nodes.
    pub faults: FaultPlan,
    /// Speculative duplicate launch for detected stragglers (on by
    /// default; off reproduces pure retry-only recovery).
    pub speculation: bool,
    /// Simulated per-row compute/IO cost of a worker node. Zero by
    /// default (pure wall-clock); benches set it so per-node load
    /// imbalance shows up in elapsed time independent of host core
    /// count, the same calibrated-sleep style `mapreduce::hadoop_sim`
    /// uses.
    pub row_cost: Duration,
    /// Result-queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Workers merge this many chunks locally before flushing a partial
    /// to the leader. 1 = per-chunk flush (finest failure granularity);
    /// larger values amortize merge + comm cost, at the price of
    /// re-queueing up to `flush_every` chunks when a node dies — the
    /// static-inside-dynamic trade of the paper's hybrid scheme, applied
    /// to result flushing (see EXPERIMENTS.md §Perf).
    pub flush_every: usize,
}

impl ClusterConfig {
    pub fn new(workers: usize, policy: Policy) -> Self {
        ClusterConfig {
            workers,
            policy,
            link: LinkModel::instant(),
            slowdown: vec![],
            failure: None,
            faults: FaultPlan::none(),
            speculation: true,
            row_cost: Duration::ZERO,
            queue_capacity: 64,
            flush_every: 8,
        }
    }

    pub fn with_flush_every(mut self, n: usize) -> Self {
        self.flush_every = n.max(1);
        self
    }

    pub fn with_link(mut self, link: LinkModel) -> Self {
        self.link = link;
        self
    }

    pub fn with_slowdown(mut self, s: Vec<f64>) -> Self {
        self.slowdown = s;
        self
    }

    pub fn with_failure(mut self, f: Failure) -> Self {
        self.failure = Some(f);
        self
    }

    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    pub fn with_speculation(mut self, on: bool) -> Self {
        self.speculation = on;
        self
    }

    pub fn with_row_cost(mut self, per_row: Duration) -> Self {
        self.row_cost = per_row;
        self
    }

    fn slowdown_of(&self, w: usize) -> f64 {
        let legacy = self.slowdown.get(w).copied().unwrap_or(1.0).max(1.0);
        legacy.max(self.faults.multiplier_of(w))
    }

    fn crash_of(&self, w: usize) -> Option<Crash> {
        self.faults.crash_of(w).or(self
            .failure
            .filter(|f| f.worker == w)
            .map(|f| Crash {
                worker: f.worker,
                after_chunks: f.after_chunks,
            }))
    }
}

/// Execution metrics.
///
/// `chunks`/`chunks_per_worker` count chunks *committed into the result*
/// (each chunk exactly once, final attempt only after a restart);
/// re-executed work is accounted separately in `chunks_retried` so
/// recovery cost is visible without double-counting result work.
/// Communication counters accumulate across restart attempts — the
/// traffic of an aborted attempt was still paid.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub elapsed: Duration,
    pub chunks: usize,
    pub comm_bytes: u64,
    pub comm_messages: u64,
    pub failures_recovered: usize,
    pub restarts: usize,
    /// Chunk re-executions enqueued (crash losses, dropped flushes,
    /// duplicate-contaminated batches, and work redone by a restart).
    pub chunks_retried: usize,
    /// Flushed partials dropped in transit (injected lost results).
    pub lost_flushes: usize,
    /// Workers detected as stragglers.
    pub stragglers_detected: usize,
    /// Speculative duplicate chunk copies launched.
    pub speculative_launched: usize,
    /// Duplicates that committed before the straggler's own copy.
    pub speculative_won: usize,
    /// `dist.*` execution tags describing which distributed-runtime
    /// paths fired (the runtime counterpart of `Program::opt_tags`).
    pub tags: Vec<String>,
    pub chunks_per_worker: BTreeMap<usize, usize>,
}

impl Metrics {
    /// Record a `dist.*` execution tag (deduplicated).
    pub fn note_tag(&mut self, tag: &str) {
        if !self.tags.iter().any(|t| t == tag) {
            self.tags.push(tag.to_string());
        }
    }

    /// Derive the fault-path tags from the counters.
    pub(crate) fn finalize_fault_tags(&mut self) {
        if self.restarts > 0 {
            self.note_tag("dist.restart");
        }
        if self.failures_recovered > 0 || self.chunks_retried > 0 {
            self.note_tag("dist.retry");
        }
        if self.stragglers_detected > 0 {
            self.note_tag("dist.speculative");
        }
        if self.lost_flushes > 0 {
            self.note_tag("dist.lost_result");
        }
    }

    /// One-line summary for `Engine::explain_distributed` and logs.
    pub fn render(&self) -> String {
        format!(
            "chunks={} retried={} failures_recovered={} stragglers={} \
             speculative={}/{} lost_flushes={} restarts={} comm_msgs={} tags=[{}]",
            self.chunks,
            self.chunks_retried,
            self.failures_recovered,
            self.stragglers_detected,
            self.speculative_won,
            self.speculative_launched,
            self.lost_flushes,
            self.restarts,
            self.comm_messages,
            self.tags.join(", ")
        )
    }
}

/// A completed job.
#[derive(Debug)]
pub struct JobResult {
    pub pairs: Vec<(Value, f64)>,
    pub metrics: Metrics,
}

impl JobResult {
    /// Render as a (key, count) multiset for oracle comparison.
    pub fn to_multiset(&self, schema: Schema) -> Multiset {
        let int_out = matches!(schema.dtype(1), crate::ir::DataType::Int);
        let mut m = Multiset::new(schema);
        for (k, x) in &self.pairs {
            let v = if int_out {
                Value::Int(*x as i64)
            } else {
                Value::Float(*x)
            };
            m.push(vec![k.clone(), v]);
        }
        m
    }
}

enum WorkerMsg {
    Request { worker: usize },
    /// A flushed batch: the chunks covered + their merged partial.
    /// `units` is the batch's virtual cost (rows × latency multiplier);
    /// `spec` marks a single-chunk speculative flush.
    Done {
        worker: usize,
        chunks: Vec<Chunk>,
        partial: Partial,
        elapsed: Duration,
        units: u64,
        spec: bool,
    },
    Failed { worker: usize },
}

/// A leader→worker assignment.
enum Task {
    /// Process into the local batch (normal path).
    Chunk(Chunk),
    /// Process standalone and flush immediately — used for contested
    /// chunks (a straggler's own chunk and its speculative duplicate) so
    /// a lost race never contaminates a multi-chunk batch.
    Spec(Chunk),
    /// Flush the local batch now, then ask again (the leader wants the
    /// worker's finished-but-unflushed chunks made durable before
    /// parking it).
    Drain,
}

/// `run_once` failure modes: a lost static schedule asks for a whole-job
/// restart and hands back the aborted attempt's metrics so the retry can
/// account for them.
enum RunError {
    Restart { metrics: Box<Metrics>, reason: String },
    Fatal(anyhow::Error),
}

/// Run a distributed aggregation job. Dynamic schedules recover every
/// injected fault in place (per-chunk retry + speculation); a static
/// schedule that loses work restarts once on the surviving nodes with
/// the fault plan cleared (§III-A3: "the computation has to be
/// restarted"), accounting the aborted attempt's work as retried.
pub fn run_job(cfg: &ClusterConfig, job: &AggJob) -> Result<JobResult> {
    let t0 = Instant::now();
    match run_once(cfg, job, 0) {
        Ok(mut r) => {
            r.metrics.elapsed = t0.elapsed();
            r.metrics.finalize_fault_tags();
            Ok(r)
        }
        Err(RunError::Restart { metrics: aborted, reason }) => {
            // On restart the failed node is excluded (the cluster
            // manager reprovisions): run with one fewer worker and no
            // further injected faults.
            let mut cfg2 = cfg.clone();
            cfg2.failure = None;
            cfg2.faults = FaultPlan::none();
            cfg2.workers = (cfg.workers - 1).max(1);
            let mut r = run_once(&cfg2, job, 1).map_err(|e| match e {
                RunError::Restart { reason: r2, .. } => {
                    anyhow!("job failed after restart ({reason}): {r2}")
                }
                RunError::Fatal(e) => e,
            })?;
            // Merge the aborted attempt's accounting without
            // double-counting committed work: result chunks are the
            // final attempt's; the aborted attempt's completed chunks
            // become retried work; comm traffic accumulates.
            r.metrics.restarts = 1;
            r.metrics.chunks_retried += aborted.chunks + aborted.chunks_retried;
            r.metrics.comm_bytes += aborted.comm_bytes;
            r.metrics.comm_messages += aborted.comm_messages;
            r.metrics.failures_recovered += aborted.failures_recovered;
            r.metrics.lost_flushes += aborted.lost_flushes;
            r.metrics.stragglers_detected += aborted.stragglers_detected;
            r.metrics.speculative_launched += aborted.speculative_launched;
            r.metrics.speculative_won += aborted.speculative_won;
            r.metrics.elapsed = t0.elapsed();
            r.metrics.finalize_fault_tags();
            Ok(r)
        }
        Err(RunError::Fatal(e)) => Err(e),
    }
}

/// Leader-side bookkeeping for one attempt. Owns everything the message
/// handlers mutate; the result accumulator stays outside (it needs the
/// job).
struct Leader<'a> {
    scheduler: Scheduler,
    supports_requeue: bool,
    speculation: bool,
    workers: usize,
    plan: &'a FaultPlan,
    chunk_txs: Vec<Option<Sender<Option<Task>>>>,
    /// Chunks merged into the result exactly once (first result wins).
    committed: HashSet<Chunk>,
    /// Rows committed; the attempt is done when this reaches `n`.
    completed: usize,
    /// The chunk each worker currently holds.
    outstanding: Vec<Option<Chunk>>,
    /// Chunks a worker finished but has not flushed yet: lost with the
    /// node's memory if it dies (re-queued on failure).
    unflushed: Vec<Vec<Chunk>>,
    /// Speculative duplicates awaiting a rival worker: (chunk, owner).
    spec_queue: VecDeque<(Chunk, usize)>,
    /// Chunks currently raced by two workers → original owner.
    contested: HashMap<Chunk, usize>,
    /// Workers idling because nothing was assignable when they asked.
    parked: Vec<usize>,
    /// Per-worker virtual cost units and iterations (straggler signal).
    units: Vec<f64>,
    iters: Vec<u64>,
    straggler: Vec<bool>,
    /// Per-worker count of flushes seen (lost-flush injection ordinal).
    flushes_seen: Vec<usize>,
    metrics: Metrics,
}

impl Leader<'_> {
    fn send(&mut self, worker: usize, task: Task) {
        if let Some(tx) = &self.chunk_txs[worker] {
            if tx.send(Some(task)).is_err() {
                self.chunk_txs[worker] = None;
            }
        }
    }

    /// Try to hand `worker` its next task; false → nothing assignable.
    fn assign(&mut self, worker: usize) -> bool {
        // Speculative duplicates first — never raced against their own
        // owner, and skipped once the race is already decided.
        self.spec_queue.retain(|(c, _)| !self.committed.contains(c));
        if let Some(pos) = self
            .spec_queue
            .iter()
            .position(|(_, owner)| *owner != worker)
        {
            let (c, _) = self.spec_queue.remove(pos).expect("position valid");
            self.outstanding[worker] = Some(c);
            self.send(worker, Task::Spec(c));
            return true;
        }
        let Some(chunk) = self.scheduler.next_chunk(worker) else {
            return false;
        };
        self.outstanding[worker] = Some(chunk);
        if self.straggler[worker] && self.speculation && self.supports_requeue && self.workers > 1
        {
            // Contested chunk: the straggler runs it single-flush and a
            // duplicate is queued for whoever asks next.
            self.send(worker, Task::Spec(chunk));
            self.spec_queue.push_back((chunk, worker));
            self.contested.insert(chunk, worker);
            self.metrics.speculative_launched += 1;
        } else {
            self.send(worker, Task::Chunk(chunk));
        }
        true
    }

    /// Re-queue chunks for re-execution; a static schedule cannot, so it
    /// asks for a whole-job restart.
    fn requeue(&mut self, chunks: Vec<Chunk>, why: &str) -> Result<(), String> {
        if chunks.is_empty() {
            return Ok(());
        }
        if !self.supports_requeue {
            return Err(format!("{why} under a static schedule; restart required"));
        }
        self.metrics.chunks_retried += chunks.len();
        for c in chunks {
            self.scheduler.requeue(c);
        }
        Ok(())
    }

    /// Give every parked worker another chance (new work may exist).
    fn drain_parked(&mut self) {
        let parked = std::mem::take(&mut self.parked);
        for w in parked {
            if !self.assign(w) {
                self.parked.push(w);
            }
        }
    }

    /// Re-run straggler detection over the reported per-iteration costs.
    /// Units are exact (rows × injected multiplier), so this is
    /// deterministic: a worker is flagged iff its multiplier is at least
    /// `STRAGGLER_FACTOR ×` the fastest reporting worker's.
    fn detect_stragglers(&mut self) {
        let rates: Vec<(usize, f64)> = (0..self.workers)
            .filter(|&w| self.iters[w] > 0)
            .map(|w| (w, self.units[w] / self.iters[w] as f64))
            .collect();
        if rates.len() < 2 {
            return;
        }
        let fastest = rates.iter().map(|(_, r)| *r).fold(f64::INFINITY, f64::min);
        for (w, rate) in rates {
            if !self.straggler[w] && rate >= STRAGGLER_FACTOR * fastest {
                self.straggler[w] = true;
                self.metrics.stragglers_detected += 1;
            }
        }
    }

    fn handle_request(&mut self, worker: usize, n: usize) {
        // The previously assigned chunk is now processed (the worker
        // asks again only after finishing) but unflushed.
        if let Some(done) = self.outstanding[worker].take() {
            self.unflushed[worker].push(done);
        }
        if self.assign(worker) {
            return;
        }
        if self.completed < n {
            if self.unflushed[worker].is_empty() {
                // Nothing to hand out, nothing at risk: idle until a
                // retry or speculative duplicate shows up.
                self.parked.push(worker);
            } else {
                // Make the worker's finished chunks durable first, so a
                // fully-parked cluster implies every chunk is committed
                // or queued.
                self.send(worker, Task::Drain);
            }
        } else {
            self.send_stop(worker);
        }
    }

    /// Returns the partial to merge when the flush is accepted.
    fn handle_done(
        &mut self,
        worker: usize,
        chunks: Vec<Chunk>,
        partial: Partial,
        elapsed: Duration,
        units: u64,
        spec: bool,
    ) -> Result<Option<Partial>, String> {
        let nth = self.flushes_seen[worker];
        self.flushes_seen[worker] += 1;
        // Flushed chunks leave the worker's memory either way.
        self.unflushed[worker].retain(|c| !chunks.contains(c));
        if let Some(c) = self.outstanding[worker] {
            if chunks.contains(&c) {
                self.outstanding[worker] = None;
            }
        }
        if self.plan.loses_flush(worker, nth) {
            // Injected lost result: the partial evaporates in transit;
            // recover by re-executing whatever it covered.
            self.metrics.lost_flushes += 1;
            let lost: Vec<Chunk> = chunks
                .into_iter()
                .filter(|c| !self.committed.contains(c))
                .collect();
            self.requeue(lost, "result flush lost")?;
            return Ok(None);
        }
        if chunks.iter().any(|c| self.committed.contains(c)) {
            // A rival already committed part of this flush. The merged
            // partial is all-or-nothing, so discard it and re-run any
            // still-uncommitted chunks it covered. (Speculative flushes
            // cover exactly one chunk — a lost race costs nothing.)
            let fresh: Vec<Chunk> = chunks
                .into_iter()
                .filter(|c| !self.committed.contains(c))
                .collect();
            self.requeue(fresh, "duplicate-contaminated batch")?;
            return Ok(None);
        }
        // First result wins: commit every covered chunk.
        let batch: usize = chunks.iter().map(|c| c.len()).sum();
        for chunk in &chunks {
            self.scheduler.report(
                worker,
                *chunk,
                elapsed.mul_f64(chunk.len() as f64 / batch.max(1) as f64),
            );
            self.committed.insert(*chunk);
            if let Some(owner) = self.contested.remove(chunk) {
                if spec && owner != worker {
                    self.metrics.speculative_won += 1;
                }
            }
        }
        self.completed += batch;
        self.metrics.chunks += chunks.len();
        *self.metrics.chunks_per_worker.entry(worker).or_default() += chunks.len();
        self.units[worker] += units as f64;
        self.iters[worker] += batch as u64;
        self.detect_stragglers();
        Ok(Some(partial))
    }

    /// Crash recovery: in-flight AND unflushed chunks are lost with the
    /// node's memory.
    fn handle_failed(&mut self, worker: usize) -> Result<(), String> {
        let mut lost: Vec<Chunk> = self.unflushed[worker].drain(..).collect();
        lost.extend(self.outstanding[worker].take());
        self.chunk_txs[worker] = None; // node is gone
        if !lost.is_empty() {
            self.requeue(lost, &format!("node {worker} failed"))?;
            self.metrics.failures_recovered += 1;
        } else if !self.supports_requeue && !self.scheduler.exhausted() {
            // Even with no in-flight chunk, a static schedule cannot
            // move the node's unprocessed block.
            return Err(format!(
                "node {worker} failed under a static schedule; restart required"
            ));
        }
        Ok(())
    }

    fn send_stop(&mut self, worker: usize) {
        if let Some(tx) = &self.chunk_txs[worker] {
            let _ = tx.send(None);
        }
    }
}

fn run_once(cfg: &ClusterConfig, job: &AggJob, attempt: usize) -> Result<JobResult, RunError> {
    let n = job.rows();
    let stats = CommStats::new();
    let scheduler = Scheduler::new(cfg.policy, n, cfg.workers);
    let supports_requeue = scheduler.supports_requeue();

    // Accounted, bounded worker→leader channel (backpressure).
    let (msg_tx, msg_rx) = channel::<WorkerMsg>(cfg.queue_capacity, stats.clone(), cfg.link);
    let job_arc = Arc::new(job.clone());

    std::thread::scope(|scope| -> Result<JobResult, RunError> {
        // Leader→worker chunk channels (plain; replies are tiny).
        let mut chunk_txs: Vec<Option<Sender<Option<Task>>>> = Vec::new();
        let mut handles = Vec::new();
        for w in 0..cfg.workers {
            let (ctx, crx) = std::sync::mpsc::channel::<Option<Task>>();
            chunk_txs.push(Some(ctx));
            let msg_tx = msg_tx.clone();
            let job = job_arc.clone();
            let multiplier = cfg.slowdown_of(w);
            // Faults only fire on the first attempt (the restart runs on
            // reprovisioned nodes).
            let crash = if attempt == 0 { cfg.crash_of(w) } else { None };
            let flush_every = cfg.flush_every.max(1);
            let row_cost = cfg.row_cost;
            handles.push(scope.spawn(move || {
                worker_loop(w, &job, crx, msg_tx, multiplier, crash, flush_every, row_cost);
            }));
        }
        drop(msg_tx); // leader keeps only the rx side

        let mut acc = Acc::for_job(&job_arc);
        let mut leader = Leader {
            scheduler,
            supports_requeue,
            speculation: cfg.speculation,
            workers: cfg.workers,
            plan: &cfg.faults,
            chunk_txs,
            committed: HashSet::new(),
            completed: 0,
            outstanding: vec![None; cfg.workers],
            unflushed: vec![Vec::new(); cfg.workers],
            spec_queue: VecDeque::new(),
            contested: HashMap::new(),
            parked: Vec::new(),
            units: vec![0.0; cfg.workers],
            iters: vec![0; cfg.workers],
            straggler: vec![false; cfg.workers],
            flushes_seen: vec![0; cfg.workers],
            metrics: Metrics::default(),
        };

        let mut abort: Option<String> = None;
        while leader.completed < n {
            let Ok(msg) = msg_rx.recv() else {
                // All workers gone before completion.
                abort = Some("workers exited early; restart required".into());
                break;
            };
            let outcome = match msg {
                WorkerMsg::Request { worker } => {
                    leader.handle_request(worker, n);
                    Ok(())
                }
                WorkerMsg::Done {
                    worker,
                    chunks,
                    partial,
                    elapsed,
                    units,
                    spec,
                } => leader
                    .handle_done(worker, chunks, partial, elapsed, units, spec)
                    .map(|p| {
                        if let Some(partial) = p {
                            acc.merge(partial);
                        }
                    }),
                WorkerMsg::Failed { worker } => leader.handle_failed(worker),
            };
            if let Err(reason) = outcome {
                abort = Some(reason);
                break;
            }
            // Retries and speculative duplicates may have created work
            // for idle workers.
            leader.drain_parked();
        }

        // Tell everyone to stop (normal completion or abort), then drain
        // in-flight messages so workers blocked on the bounded queue can
        // exit, and join.
        for w in 0..cfg.workers {
            leader.send_stop(w);
        }
        leader.chunk_txs.clear();
        while msg_rx.try_recv().is_ok() {}
        for h in handles {
            let _ = h.join();
        }

        let mut metrics = leader.metrics;
        metrics.comm_bytes = stats.total_bytes();
        metrics.comm_messages = stats.total_messages();
        if let Some(reason) = abort {
            return Err(RunError::Restart {
                metrics: Box::new(metrics),
                reason,
            });
        }
        Ok(JobResult {
            pairs: acc.into_pairs(&job_arc),
            metrics,
        })
    })
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    w: usize,
    job: &AggJob,
    chunk_rx: std::sync::mpsc::Receiver<Option<Task>>,
    msg_tx: Tx<WorkerMsg>,
    multiplier: f64,
    crash: Option<Crash>,
    flush_every: usize,
    row_cost: Duration,
) {
    let mut processed = 0usize;
    // Local accumulation between flushes (amortizes leader merge + comm).
    let mut local = Acc::for_job(job);
    let mut covered: Vec<Chunk> = Vec::new();
    let mut batch_t = Duration::ZERO;
    let mut batch_units = 0u64;

    let flush = |local: &mut Acc,
                 covered: &mut Vec<Chunk>,
                 batch_t: &mut Duration,
                 batch_units: &mut u64|
     -> bool {
        if covered.is_empty() {
            return true;
        }
        let partial = std::mem::replace(local, Acc::for_job(job)).into_partial();
        let bytes = partial.wire_bytes();
        msg_tx.send(
            WorkerMsg::Done {
                worker: w,
                chunks: std::mem::take(covered),
                partial,
                elapsed: std::mem::replace(batch_t, Duration::ZERO),
                units: std::mem::replace(batch_units, 0),
                spec: false,
            },
            bytes,
        )
    };

    loop {
        if !msg_tx.send(WorkerMsg::Request { worker: w }, 16) {
            return;
        }
        let task = match chunk_rx.recv() {
            Ok(Some(t)) => t,
            _ => {
                // Loop exhausted: flush what we hold, then exit.
                let _ = flush(&mut local, &mut covered, &mut batch_t, &mut batch_units);
                return;
            }
        };
        let (chunk, is_spec) = match task {
            Task::Drain => {
                if !flush(&mut local, &mut covered, &mut batch_t, &mut batch_units) {
                    return;
                }
                continue;
            }
            Task::Chunk(c) => (c, false),
            Task::Spec(c) => (c, true),
        };
        // Injected crash: die holding the in-flight chunk AND any
        // unflushed local state (both are lost with this node's memory).
        if let Some(f) = crash {
            if processed >= f.after_chunks {
                let _ = msg_tx.send(WorkerMsg::Failed { worker: w }, 16);
                return;
            }
        }
        let t0 = Instant::now();
        let partial = process_chunk(job, chunk.lo, chunk.hi);
        let real = t0.elapsed();
        // Simulated extra latency: the node's calibrated per-row cost
        // plus the injected slowdown, both scaled by the multiplier.
        let sim = row_cost.mul_f64(chunk.len() as f64 * multiplier);
        let extra = real.mul_f64(multiplier - 1.0) + sim;
        if extra > Duration::ZERO {
            std::thread::sleep(extra);
        }
        let elapsed = t0.elapsed();
        let units = (chunk.len() as f64 * multiplier) as u64;
        processed += 1;
        if is_spec {
            // Contested chunk: flush standalone so a lost race never
            // contaminates the local batch.
            let bytes = partial.wire_bytes();
            let ok = msg_tx.send(
                WorkerMsg::Done {
                    worker: w,
                    chunks: vec![chunk],
                    partial,
                    elapsed,
                    units,
                    spec: true,
                },
                bytes,
            );
            if !ok {
                return;
            }
        } else {
            local.merge(partial);
            covered.push(chunk);
            batch_t += elapsed;
            batch_units += units;
            if covered.len() >= flush_every
                && !flush(&mut local, &mut covered, &mut batch_t, &mut batch_units)
            {
                return;
            }
        }
    }
}
#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Multiset, Schema};
    use crate::storage::Table;
    use crate::util::forall_seeds;
    use crate::workload::{access_log, AccessLogSpec};

    fn table(rows: usize, urls: usize, dict: bool) -> Arc<Table> {
        let m = access_log(&AccessLogSpec {
            rows,
            urls,
            skew: 1.1,
            seed: 11,
        });
        let mut t = Table::from_multiset(&m).unwrap();
        if dict {
            t.dict_encode_field(0).unwrap();
        }
        Arc::new(t)
    }

    fn oracle(t: &Arc<Table>) -> std::collections::HashMap<Value, f64> {
        let mut m = std::collections::HashMap::new();
        for r in 0..t.len() {
            *m.entry(t.value(r, 0)).or_insert(0.0) += 1.0;
        }
        m
    }

    fn check(result: &JobResult, t: &Arc<Table>) {
        let want = oracle(t);
        assert_eq!(result.pairs.len(), want.len());
        for (k, x) in &result.pairs {
            assert_eq!(want[k], *x, "key {k}");
        }
    }

    #[test]
    fn all_policies_compute_correct_counts() {
        let t = table(20_000, 500, true);
        for policy in [
            Policy::StaticBlock,
            Policy::FixedChunk(1024),
            Policy::Gss,
            Policy::Trapezoid,
            Policy::Factoring,
            Policy::FeedbackGuided,
            Policy::Hybrid {
                super_chunks_per_worker: 4,
            },
        ] {
            let cfg = ClusterConfig::new(8, policy);
            let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
            check(&r, &t);
        }
    }

    #[test]
    fn string_tables_use_assoc_path() {
        let t = table(5_000, 200, false);
        let job = AggJob::count(t.clone(), 0);
        assert!(job.num_keys.is_none());
        let r = run_job(&ClusterConfig::new(4, Policy::Gss), &job).unwrap();
        check(&r, &t);
    }

    #[test]
    fn dynamic_policy_survives_node_failure() {
        let t = table(50_000, 300, true);
        let cfg = ClusterConfig::new(4, Policy::FixedChunk(512)).with_failure(Failure {
            worker: 2,
            after_chunks: 3,
        });
        let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
        check(&r, &t);
        assert_eq!(r.metrics.failures_recovered, 1);
        assert_eq!(r.metrics.restarts, 0);
        // The dead worker did limited work.
        assert!(r.metrics.chunks_per_worker.get(&2).copied().unwrap_or(0) <= 3);
    }

    #[test]
    fn static_policy_requires_restart_on_failure() {
        let t = table(50_000, 300, true);
        let cfg = ClusterConfig::new(4, Policy::StaticBlock).with_failure(Failure {
            worker: 1,
            after_chunks: 0,
        });
        let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
        check(&r, &t);
        assert_eq!(r.metrics.restarts, 1);
    }

    #[test]
    fn hybrid_recovers_at_super_chunk_granularity() {
        let t = table(50_000, 300, true);
        let cfg = ClusterConfig::new(
            4,
            Policy::Hybrid {
                super_chunks_per_worker: 8,
            },
        )
        .with_failure(Failure {
            worker: 0,
            after_chunks: 2,
        });
        let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
        check(&r, &t);
        assert_eq!(r.metrics.failures_recovered, 1);
    }

    #[test]
    fn coordinator_matches_exec_oracle_via_multiset() {
        let t = table(3_000, 100, true);
        let r = run_job(&ClusterConfig::new(3, Policy::Gss), &AggJob::count(t.clone(), 0))
            .unwrap();
        let schema = Schema::new(vec![("url", DataType::Str), ("n", DataType::Int)]);
        let got = r.to_multiset(schema.clone());
        let mut want = Multiset::new(schema);
        for (k, v) in oracle(&t) {
            want.push(vec![k, Value::Int(v as i64)]);
        }
        assert!(got.bag_eq(&want));
    }

    #[test]
    fn distributed_join_count_matches_single_chunk_oracle() {
        let probe_t = table(20_000, 300, true);
        // Dimension side: a sample of the probe table's url values, with
        // one duplicate so multiplicities > 1 occur.
        let build = {
            let schema = Schema::new(vec![("url", DataType::Str)]);
            let mut m = Multiset::new(schema);
            for r in (0..probe_t.len()).step_by(97) {
                m.push(vec![probe_t.value(r, 0)]);
            }
            m.push(vec![probe_t.value(0, 0)]);
            Arc::new(crate::storage::Table::from_multiset(&m).unwrap())
        };
        let probe = JoinProbe::new(&build, 0, 0);
        let job = AggJob::count_join(probe_t.clone(), 0, probe);

        let mut acc = Acc::for_job(&job);
        acc.merge(process_chunk(&job, 0, probe_t.len()));
        let mut want = acc.into_pairs(&job);
        want.sort_by(|x, y| x.0.cmp(&y.0));

        for cfg in [
            ClusterConfig::new(4, Policy::Gss),
            ClusterConfig::new(4, Policy::FixedChunk(512)).with_failure(Failure {
                worker: 1,
                after_chunks: 2,
            }),
        ] {
            let r = run_job(&cfg, &job).unwrap();
            let mut got = r.pairs.clone();
            got.sort_by(|x, y| x.0.cmp(&y.0));
            assert_eq!(got, want);
        }
    }

    #[test]
    fn property_random_configs_are_exact() {
        // Seed-driven property: any (policy, workers, failure point)
        // combination yields exact counts.
        let t = table(8_000, 64, true);
        let want = oracle(&t);
        forall_seeds(12, |rng| {
            let policies = [
                Policy::FixedChunk(256 + rng.below(1024) as usize),
                Policy::Gss,
                Policy::Trapezoid,
                Policy::Factoring,
                Policy::Hybrid {
                    super_chunks_per_worker: 1 + rng.below(8) as usize,
                },
            ];
            let policy = policies[rng.below(policies.len() as u64) as usize];
            let workers = 1 + rng.below(8) as usize;
            let mut cfg = ClusterConfig::new(workers, policy);
            if rng.below(2) == 1 && workers > 1 {
                cfg = cfg.with_failure(Failure {
                    worker: rng.below(workers as u64) as usize,
                    after_chunks: rng.below(4) as usize,
                });
            }
            let r = run_job(&cfg, &AggJob::count(t.clone(), 0))
                .map_err(|e| format!("job failed: {e}"))?;
            crate::prop_assert!(
                r.pairs.len() == want.len(),
                "distinct keys {} != {}",
                r.pairs.len(),
                want.len()
            );
            for (k, x) in &r.pairs {
                crate::prop_assert!(want[k] == *x, "key {k}: {x} != {}", want[k]);
            }
            Ok(())
        });
    }

    #[test]
    fn restart_accounting_spans_both_attempts() {
        // Pins the whole-job-restart fix: the aborted attempt's traffic
        // and completed work used to be silently discarded, so a
        // restarted job reported *less* communication than a fault-free
        // one. The restarted attempt alone sends 9 messages here (3
        // surviving workers × (2 requests + 1 final flush)); attempt 0's
        // request/failure traffic must come on top.
        let t = table(50_000, 300, true);
        let clean = run_job(
            &ClusterConfig::new(3, Policy::StaticBlock),
            &AggJob::count(t.clone(), 0),
        )
        .unwrap();
        let cfg = ClusterConfig::new(4, Policy::StaticBlock).with_failure(Failure {
            worker: 1,
            after_chunks: 0,
        });
        let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
        check(&r, &t);
        assert_eq!(r.metrics.restarts, 1);
        assert!(
            r.metrics.comm_messages > clean.metrics.comm_messages,
            "aborted attempt's messages must accumulate: {} <= {}",
            r.metrics.comm_messages,
            clean.metrics.comm_messages
        );
        // Result accounting stays single-attempt: 4 static blocks exist,
        // but only the 3 surviving workers' chunks are committed.
        assert_eq!(r.metrics.chunks, 3);
        assert_eq!(r.metrics.chunks_per_worker.values().sum::<usize>(), 3);
        assert!(r.metrics.tags.iter().any(|x| x == "dist.restart"));
    }

    #[test]
    fn straggler_is_detected_and_speculated_deterministically() {
        let t = table(40_000, 300, true);
        let cfg = ClusterConfig::new(4, Policy::FixedChunk(1024))
            .with_faults(FaultPlan::none().slow(3, 8.0));
        let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
        check(&r, &t);
        // units = rows × multiplier, so per-iteration cost is exactly
        // the injected 8× — detection is a certainty, not a race.
        assert_eq!(r.metrics.stragglers_detected, 1);
        assert!(r.metrics.speculative_launched >= 1);
        assert!(r.metrics.tags.iter().any(|x| x == "dist.speculative"));
        assert_eq!(r.metrics.restarts, 0);
    }

    #[test]
    fn speculation_off_still_completes_with_a_straggler() {
        let t = table(20_000, 200, true);
        let cfg = ClusterConfig::new(4, Policy::FixedChunk(1024))
            .with_faults(FaultPlan::none().slow(2, 10.0))
            .with_speculation(false);
        let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
        check(&r, &t);
        assert_eq!(r.metrics.speculative_launched, 0);
        assert_eq!(r.metrics.speculative_won, 0);
    }

    #[test]
    fn lost_flush_is_detected_and_reexecuted() {
        let t = table(30_000, 200, true);
        let cfg = ClusterConfig::new(4, Policy::FixedChunk(1024))
            .with_flush_every(4)
            .with_faults(FaultPlan::none().lose_flush(1, 0));
        let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
        check(&r, &t);
        assert_eq!(r.metrics.lost_flushes, 1);
        // A worker's first flush always covers exactly `flush_every`
        // chunks, all of which must be re-executed.
        assert_eq!(r.metrics.chunks_retried, 4);
        assert!(r.metrics.tags.iter().any(|x| x == "dist.lost_result"));
    }

    #[test]
    fn crash_retry_counts_match_the_injected_plan() {
        let t = table(50_000, 300, true);
        let cfg = ClusterConfig::new(4, Policy::FixedChunk(512))
            .with_flush_every(4)
            .with_faults(FaultPlan::none().crash(2, 5));
        let r = run_job(&cfg, &AggJob::count(t.clone(), 0)).unwrap();
        check(&r, &t);
        assert_eq!(r.metrics.failures_recovered, 1);
        // 5 chunks done = one flush of 4 + 1 unflushed; dying on receipt
        // of chunk 6 loses the unflushed chunk and the in-flight one.
        assert_eq!(r.metrics.chunks_retried, 2);
        assert_eq!(r.metrics.chunks_per_worker.get(&2), Some(&4));
        assert!(r.metrics.tags.iter().any(|x| x == "dist.retry"));
    }

    #[test]
    fn fault_free_runs_carry_no_fault_tags() {
        let t = table(10_000, 100, true);
        let r = run_job(
            &ClusterConfig::new(4, Policy::Gss),
            &AggJob::count(t.clone(), 0),
        )
        .unwrap();
        check(&r, &t);
        assert!(r.metrics.tags.is_empty(), "{:?}", r.metrics.tags);
        assert!(!r.metrics.render().is_empty());
    }
}
