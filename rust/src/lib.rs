//! # forelem — a compiler-technology alternative for Big Data infrastructures
//!
//! Reproduction of Rietveld & Wijshoff, *"Providing A Compiler
//! Technology-Based Alternative For Big Data Application Infrastructures"*.
//!
//! The library implements the paper's **single intermediate
//! representation** (multisets of tuples + `forelem` loops + index sets)
//! and everything the paper builds on it:
//!
//! * [`ir`] — the intermediate representation itself;
//! * [`sql`] — SQL front-end lowering queries into the IR (§IV);
//! * [`mapreduce`] — MapReduce front-end, the IR→MapReduce derivation of
//!   §IV, and a Hadoop-like disk-spilling baseline executor;
//! * [`analysis`] — def-use, dependence and cost analyses;
//! * [`opt`] — the cost-based query optimizer: column statistics,
//!   cardinality estimation, and plan decisions (join build side,
//!   predicate order, index strategies, top-k heap-vs-sort, parallel
//!   fan-out gating);
//! * [`transform`] — the re-targeted compiler transformations: loop
//!   blocking/orthogonalization (data partitioning), interchange, fusion,
//!   code motion, iteration-space expansion, DCE/CSE/const-prop, index-set
//!   materialization and data reformatting (§III);
//! * [`storage`] — physical layouts under compiler control: row files,
//!   column stores, compressed columns, string dictionaries (§III-C1);
//! * [`exec`] — the execution engine compiling transformed IR to physical
//!   plans (the in-process analogue of the paper's generated C code);
//! * [`distrib`] — the simulated cluster substrate: nodes, cost-accounted
//!   channels, partitioning and the data-distribution optimizer (§III-A);
//! * [`sched`] — static/GSS/trapezoid/factoring/feedback-guided/hybrid
//!   loop schedulers with fault tolerance (§III-A2/A3);
//! * [`serve`] — concurrent query serving: prepared statements, the
//!   engine plan cache, and a shared multi-query morsel worker pool with
//!   admission control;
//! * [`coordinator`] — the leader/worker runtime orchestrating chunked
//!   parallel execution with backpressure and failure recovery;
//! * [`runtime`] — the PJRT client loading AOT-compiled XLA artifacts
//!   (the L1/L2 numeric hot path);
//! * [`workload`] — synthetic generators for the paper's evaluation
//!   workloads (zipfian access logs, link graphs, grades).

pub mod analysis;
pub mod compiler;
pub mod coordinator;
pub mod distrib;
pub mod exec;
pub mod ir;
pub mod mapreduce;
pub mod opt;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sql;
pub mod storage;
pub mod transform;
pub mod util;
pub mod workload;

pub mod prelude {
    //! Convenient glob import for examples and tests.
    pub use crate::ir::{
        validate, AccumOp, ArrayDecl, BinOp, DataType, Domain, EmitOrder, Expr, Field, FieldId,
        IndexSet, Loop, LoopKind, Multiset, Program, Schema, Stmt, Strategy, TopKStrategy, Tuple,
        UnOp, Value,
    };
}
