//! SQL front-end: lexer → parser → lowering into the single intermediate
//! representation (§IV of the paper).

pub mod ast;
pub mod lexer;
pub mod lower;
pub mod parser;
pub mod token;

pub use ast::{Aggregate, ColumnRef, JoinClause, Select, SelectItem, SqlBinOp, SqlExpr};
pub use lower::{compile_sql, lower, lower_with_stats, Catalog};
pub use parser::parse;
