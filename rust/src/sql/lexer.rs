//! SQL lexer.

use anyhow::{bail, Result};

use super::token::Token;

/// Tokenize a SQL string.
pub fn lex(input: &str) -> Result<Vec<Token>> {
    let mut out = Vec::new();
    let chars: Vec<char> = input.chars().collect();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        match c {
            c if c.is_whitespace() => i += 1,
            '-' if chars.get(i + 1) == Some(&'-') => {
                // Line comment.
                while i < chars.len() && chars[i] != '\n' {
                    i += 1;
                }
            }
            '*' => {
                out.push(Token::Star);
                i += 1;
            }
            ',' => {
                out.push(Token::Comma);
                i += 1;
            }
            '.' => {
                out.push(Token::Dot);
                i += 1;
            }
            '(' => {
                out.push(Token::LParen);
                i += 1;
            }
            ')' => {
                out.push(Token::RParen);
                i += 1;
            }
            ';' => {
                out.push(Token::Semicolon);
                i += 1;
            }
            '+' => {
                out.push(Token::Plus);
                i += 1;
            }
            '-' => {
                out.push(Token::Minus);
                i += 1;
            }
            '/' => {
                out.push(Token::Slash);
                i += 1;
            }
            '%' => {
                out.push(Token::Percent);
                i += 1;
            }
            '=' => {
                out.push(Token::Eq);
                i += 1;
            }
            '!' if chars.get(i + 1) == Some(&'=') => {
                out.push(Token::Ne);
                i += 2;
            }
            '<' => {
                if chars.get(i + 1) == Some(&'=') {
                    out.push(Token::Le);
                    i += 2;
                } else if chars.get(i + 1) == Some(&'>') {
                    out.push(Token::Ne);
                    i += 2;
                } else {
                    out.push(Token::Lt);
                    i += 1;
                }
            }
            '>' => {
                if chars.get(i + 1) == Some(&'=') {
                    out.push(Token::Ge);
                    i += 2;
                } else {
                    out.push(Token::Gt);
                    i += 1;
                }
            }
            '?' => {
                out.push(Token::Param(None));
                i += 1;
            }
            '$' => {
                // `$n` placeholder (1-based explicit parameter index).
                let start = i + 1;
                let mut j = start;
                while j < chars.len() && chars[j].is_ascii_digit() {
                    j += 1;
                }
                if j == start {
                    bail!("`$` must be followed by a parameter number (e.g. $1)");
                }
                let text: String = chars[start..j].iter().collect();
                out.push(Token::Param(Some(text.parse()?)));
                i = j;
            }
            '\'' => {
                // String literal with '' escaping.
                let mut s = String::new();
                i += 1;
                loop {
                    match chars.get(i) {
                        Some('\'') if chars.get(i + 1) == Some(&'\'') => {
                            s.push('\'');
                            i += 2;
                        }
                        Some('\'') => {
                            i += 1;
                            break;
                        }
                        Some(&c) => {
                            s.push(c);
                            i += 1;
                        }
                        None => bail!("unterminated string literal"),
                    }
                }
                out.push(Token::Str(s));
            }
            c if c.is_ascii_digit() => {
                let start = i;
                while i < chars.len() && (chars[i].is_ascii_digit() || chars[i] == '.') {
                    i += 1;
                }
                let text: String = chars[start..i].iter().collect();
                if text.contains('.') {
                    out.push(Token::Float(text.parse()?));
                } else {
                    out.push(Token::Int(text.parse()?));
                }
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
                let word: String = chars[start..i].iter().collect();
                match Token::keyword(&word.to_uppercase()) {
                    Some(kw) => out.push(kw),
                    None => out.push(Token::Ident(word)),
                }
            }
            other => bail!("unexpected character `{other}` at offset {i}"),
        }
    }
    out.push(Token::Eof);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_group_by_query() {
        let toks = lex("SELECT url, COUNT(url) FROM access GROUP BY url").unwrap();
        assert_eq!(toks[0], Token::Select);
        assert!(toks.contains(&Token::Count));
        assert!(toks.contains(&Token::Ident("access".into())));
        assert_eq!(*toks.last().unwrap(), Token::Eof);
    }

    #[test]
    fn keywords_are_case_insensitive() {
        let toks = lex("select x from t").unwrap();
        assert_eq!(toks[0], Token::Select);
        assert_eq!(toks[2], Token::From);
    }

    #[test]
    fn string_escaping() {
        let toks = lex("SELECT 'it''s'").unwrap();
        assert_eq!(toks[1], Token::Str("it's".into()));
    }

    #[test]
    fn comparison_operators() {
        let toks = lex("a <= b <> c >= d").unwrap();
        assert!(toks.contains(&Token::Le));
        assert!(toks.contains(&Token::Ne));
        assert!(toks.contains(&Token::Ge));
    }

    #[test]
    fn comments_are_skipped() {
        let toks = lex("SELECT x -- trailing\nFROM t").unwrap();
        assert!(toks.contains(&Token::From));
    }

    #[test]
    fn rejects_garbage() {
        assert!(lex("SELECT #").is_err());
        assert!(lex("'unterminated").is_err());
    }
}
