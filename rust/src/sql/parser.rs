//! Recursive-descent SQL parser for the supported subset.

use anyhow::{bail, Result};

use super::ast::*;
use super::lexer::lex;
use super::token::Token;
use crate::ir::value::Value;

/// Parse one SELECT statement.
pub fn parse(input: &str) -> Result<Select> {
    let tokens = lex(input)?;
    let mut p = Parser {
        tokens,
        pos: 0,
        next_param: 0,
    };
    let sel = p.select()?;
    p.eat_if(&Token::Semicolon);
    p.expect(Token::Eof)?;
    Ok(sel)
}

struct Parser {
    tokens: Vec<Token>,
    pos: usize,
    /// Positional `?` placeholders seen so far (they number left-to-right,
    /// 1-based, interleaving with any explicit `$n`).
    next_param: usize,
}

impl Parser {
    fn peek(&self) -> &Token {
        &self.tokens[self.pos]
    }

    fn next(&mut self) -> Token {
        let t = self.tokens[self.pos].clone();
        if self.pos < self.tokens.len() - 1 {
            self.pos += 1;
        }
        t
    }

    fn eat_if(&mut self, t: &Token) -> bool {
        if self.peek() == t {
            self.next();
            true
        } else {
            false
        }
    }

    fn expect(&mut self, t: Token) -> Result<()> {
        if self.peek() == &t {
            self.next();
            Ok(())
        } else {
            bail!("expected {t}, found {}", self.peek())
        }
    }

    fn ident(&mut self) -> Result<String> {
        match self.next() {
            Token::Ident(s) => Ok(s),
            other => bail!("expected identifier, found {other}"),
        }
    }

    fn select(&mut self) -> Result<Select> {
        self.expect(Token::Select)?;
        let mut items = vec![self.select_item()?];
        while self.eat_if(&Token::Comma) {
            items.push(self.select_item()?);
        }
        self.expect(Token::From)?;
        let table = self.ident()?;
        let alias = self.maybe_alias()?;

        let mut joins = Vec::new();
        while self.eat_if(&Token::Inner) || matches!(self.peek(), Token::Join) {
            self.eat_if(&Token::Join);
            let jtable = self.ident()?;
            let jalias = self.maybe_alias()?;
            self.expect(Token::On)?;
            let left = self.column_ref()?;
            self.expect(Token::Eq)?;
            let right = self.column_ref()?;
            joins.push(JoinClause {
                table: jtable,
                alias: jalias,
                left,
                right,
            });
        }

        let filter = if self.eat_if(&Token::Where) {
            Some(self.expr()?)
        } else {
            None
        };

        let mut group_by = Vec::new();
        if self.eat_if(&Token::Group) {
            self.expect(Token::By)?;
            group_by.push(self.column_ref()?);
            while self.eat_if(&Token::Comma) {
                group_by.push(self.column_ref()?);
            }
        }

        let order_by = if self.eat_if(&Token::Order) {
            self.expect(Token::By)?;
            let col = self.ident()?;
            let desc = if self.eat_if(&Token::Desc) {
                true
            } else {
                self.eat_if(&Token::Asc);
                false
            };
            Some((col, desc))
        } else {
            None
        };
        let limit = if self.eat_if(&Token::Limit) {
            match self.next() {
                Token::Int(n) if n >= 0 => Some(n as usize),
                other => bail!("LIMIT wants a non-negative integer, found {other}"),
            }
        } else {
            None
        };

        Ok(Select {
            items,
            table,
            alias,
            joins,
            filter,
            group_by,
            order_by,
            limit,
        })
    }

    fn maybe_alias(&mut self) -> Result<Option<String>> {
        if self.eat_if(&Token::As) {
            return Ok(Some(self.ident()?));
        }
        if let Token::Ident(_) = self.peek() {
            // Bare alias: `FROM access a`.
            return Ok(Some(self.ident()?));
        }
        Ok(None)
    }

    fn select_item(&mut self) -> Result<SelectItem> {
        if self.eat_if(&Token::Star) {
            return Ok(SelectItem::Wildcard);
        }
        let agg = match self.peek() {
            Token::Count => Some(Aggregate::Count),
            Token::Sum => Some(Aggregate::Sum),
            Token::Min => Some(Aggregate::Min),
            Token::Max => Some(Aggregate::Max),
            Token::Avg => Some(Aggregate::Avg),
            _ => None,
        };
        if let Some(agg) = agg {
            self.next();
            self.expect(Token::LParen)?;
            let expr = if self.eat_if(&Token::Star) {
                None
            } else {
                Some(self.expr()?)
            };
            self.expect(Token::RParen)?;
            let alias = self.item_alias()?;
            return Ok(SelectItem::Agg { agg, expr, alias });
        }
        let expr = self.expr()?;
        let alias = self.item_alias()?;
        Ok(SelectItem::Expr { expr, alias })
    }

    fn item_alias(&mut self) -> Result<Option<String>> {
        if self.eat_if(&Token::As) {
            Ok(Some(self.ident()?))
        } else {
            Ok(None)
        }
    }

    fn column_ref(&mut self) -> Result<ColumnRef> {
        let first = self.ident()?;
        if self.eat_if(&Token::Dot) {
            let col = self.ident()?;
            Ok(ColumnRef::qualified(&first, &col))
        } else {
            Ok(ColumnRef::new(&first))
        }
    }

    // Precedence climbing: or < and < cmp < add < mul.
    fn expr(&mut self) -> Result<SqlExpr> {
        self.or_expr()
    }

    fn or_expr(&mut self) -> Result<SqlExpr> {
        let mut lhs = self.and_expr()?;
        while self.eat_if(&Token::Or) {
            let rhs = self.and_expr()?;
            lhs = bin(SqlBinOp::Or, lhs, rhs);
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<SqlExpr> {
        let mut lhs = self.cmp_expr()?;
        while self.eat_if(&Token::And) {
            let rhs = self.cmp_expr()?;
            lhs = bin(SqlBinOp::And, lhs, rhs);
        }
        Ok(lhs)
    }

    fn cmp_expr(&mut self) -> Result<SqlExpr> {
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Token::Eq => Some(SqlBinOp::Eq),
            Token::Ne => Some(SqlBinOp::Ne),
            Token::Lt => Some(SqlBinOp::Lt),
            Token::Le => Some(SqlBinOp::Le),
            Token::Gt => Some(SqlBinOp::Gt),
            Token::Ge => Some(SqlBinOp::Ge),
            _ => None,
        };
        if let Some(op) = op {
            self.next();
            let rhs = self.add_expr()?;
            return Ok(bin(op, lhs, rhs));
        }
        Ok(lhs)
    }

    fn add_expr(&mut self) -> Result<SqlExpr> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Token::Plus => SqlBinOp::Add,
                Token::Minus => SqlBinOp::Sub,
                _ => break,
            };
            self.next();
            let rhs = self.mul_expr()?;
            lhs = bin(op, lhs, rhs);
        }
        Ok(lhs)
    }

    fn mul_expr(&mut self) -> Result<SqlExpr> {
        let mut lhs = self.atom()?;
        loop {
            let op = match self.peek() {
                Token::Star => SqlBinOp::Mul,
                Token::Slash => SqlBinOp::Div,
                Token::Percent => SqlBinOp::Mod,
                _ => break,
            };
            self.next();
            let rhs = self.atom()?;
            lhs = bin(op, lhs, rhs);
        }
        Ok(lhs)
    }

    fn atom(&mut self) -> Result<SqlExpr> {
        match self.next() {
            Token::Int(i) => Ok(SqlExpr::Literal(Value::Int(i))),
            Token::Float(x) => Ok(SqlExpr::Literal(Value::Float(x))),
            Token::Str(s) => Ok(SqlExpr::Literal(Value::str(s)))
,
            Token::Ident(first) => {
                if self.eat_if(&Token::Dot) {
                    let col = self.ident()?;
                    Ok(SqlExpr::Column(ColumnRef::qualified(&first, &col)))
                } else {
                    Ok(SqlExpr::Column(ColumnRef::new(&first)))
                }
            }
            Token::Param(explicit) => {
                let n = match explicit {
                    Some(n) => {
                        if n == 0 {
                            bail!("parameter indices are 1-based; $0 is invalid");
                        }
                        n
                    }
                    None => {
                        self.next_param += 1;
                        self.next_param
                    }
                };
                Ok(SqlExpr::Param(n))
            }
            Token::LParen => {
                let e = self.expr()?;
                self.expect(Token::RParen)?;
                Ok(e)
            }
            other => bail!("unexpected token {other} in expression"),
        }
    }
}

fn bin(op: SqlBinOp, lhs: SqlExpr, rhs: SqlExpr) -> SqlExpr {
    SqlExpr::Binary {
        op,
        lhs: Box::new(lhs),
        rhs: Box::new(rhs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_papers_url_count_query() {
        // §IV: SELECT url, COUNT(url) FROM access GROUP BY url
        let s = parse("SELECT url, COUNT(url) FROM access GROUP BY url").unwrap();
        assert_eq!(s.table, "access");
        assert_eq!(s.group_by, vec![ColumnRef::new("url")]);
        assert_eq!(s.items.len(), 2);
        assert!(matches!(
            s.items[1],
            SelectItem::Agg {
                agg: Aggregate::Count,
                ..
            }
        ));
    }

    #[test]
    fn parses_the_papers_weblink_query() {
        // §IV: SELECT target, COUNT(target) FROM links GROUP BY target
        let s = parse("SELECT target, COUNT(target) FROM links GROUP BY target").unwrap();
        assert_eq!(s.table, "links");
        assert!(s.is_aggregate());
    }

    #[test]
    fn parses_join_on() {
        let s = parse("SELECT A.field, B.field FROM A JOIN B ON A.b_id = B.id").unwrap();
        assert_eq!(s.joins.len(), 1);
        let j = &s.joins[0];
        assert_eq!(j.table, "B");
        assert_eq!(j.left, ColumnRef::qualified("A", "b_id"));
        assert_eq!(j.right, ColumnRef::qualified("B", "id"));
    }

    #[test]
    fn parses_multi_join_chain_in_written_order() {
        let s = parse(
            "SELECT f.x FROM fact f \
             JOIN dim1 ON f.d1 = dim1.id \
             INNER JOIN dim2 d2 ON f.d2 = d2.id \
             JOIN dim3 ON d2.d3 = dim3.id",
        )
        .unwrap();
        assert_eq!(s.table, "fact");
        assert_eq!(s.alias.as_deref(), Some("f"));
        let tables: Vec<&str> = s.joins.iter().map(|j| j.table.as_str()).collect();
        assert_eq!(tables, ["dim1", "dim2", "dim3"]);
        assert_eq!(s.joins[1].alias.as_deref(), Some("d2"));
        // Snowflake edge: dim3 hangs off dim2, not the fact table.
        assert_eq!(s.joins[2].left, ColumnRef::qualified("d2", "d3"));
        assert_eq!(s.joins[2].right, ColumnRef::qualified("dim3", "id"));
    }

    #[test]
    fn parses_where_with_precedence() {
        let s = parse("SELECT x FROM t WHERE a = 1 AND b > 2 OR c < 3").unwrap();
        // ((a=1 AND b>2) OR c<3)
        match s.filter.unwrap() {
            SqlExpr::Binary { op: SqlBinOp::Or, lhs, .. } => match *lhs {
                SqlExpr::Binary { op: SqlBinOp::And, .. } => {}
                other => panic!("wrong precedence: {other:?}"),
            },
            other => panic!("expected OR at top: {other:?}"),
        }
    }

    #[test]
    fn parses_weighted_average_query() {
        // §III-B: SELECT grade, weight FROM Grades WHERE studentID = 25
        let s = parse("SELECT grade, weight FROM Grades WHERE studentID = 25").unwrap();
        assert_eq!(s.items.len(), 2);
        assert!(s.filter.is_some());
        assert!(!s.is_aggregate());
    }

    #[test]
    fn parses_arithmetic_in_select_list() {
        let s = parse("SELECT grade * weight FROM Grades").unwrap();
        assert!(matches!(
            &s.items[0],
            SelectItem::Expr {
                expr: SqlExpr::Binary { op: SqlBinOp::Mul, .. },
                ..
            }
        ));
    }

    #[test]
    fn parses_count_star_and_sum() {
        let s = parse("SELECT COUNT(*), SUM(n) AS total FROM t GROUP BY g").unwrap();
        assert!(matches!(
            &s.items[0],
            SelectItem::Agg { agg: Aggregate::Count, expr: None, .. }
        ));
        assert!(matches!(
            &s.items[1],
            SelectItem::Agg { agg: Aggregate::Sum, alias: Some(a), .. } if a == "total"
        ));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("SELECT x FROM t WHERE").is_err());
        assert!(parse("SELECT FROM t").is_err());
    }
}
