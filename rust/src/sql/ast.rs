//! SQL abstract syntax tree (the subset the paper's examples need, §IV):
//! single-table and N-way equi-join SELECTs (star/snowflake chains) with
//! WHERE, GROUP BY and aggregates.

use crate::ir::value::Value;

/// Aggregate functions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Aggregate {
    Count,
    Sum,
    Min,
    Max,
    Avg,
}

/// A column reference, optionally table-qualified (`links.target`).
#[derive(Debug, Clone, PartialEq)]
pub struct ColumnRef {
    pub table: Option<String>,
    pub column: String,
}

impl ColumnRef {
    pub fn new(column: &str) -> Self {
        ColumnRef {
            table: None,
            column: column.to_string(),
        }
    }

    pub fn qualified(table: &str, column: &str) -> Self {
        ColumnRef {
            table: Some(table.to_string()),
            column: column.to_string(),
        }
    }
}

/// A scalar SQL expression.
#[derive(Debug, Clone, PartialEq)]
pub enum SqlExpr {
    Column(ColumnRef),
    Literal(Value),
    /// Prepared-statement parameter (1-based index): `?` placeholders are
    /// numbered left-to-right by the parser, `$n` is explicit. Lowered to
    /// a late-bound IR parameter slot (`$n`), never constant-folded.
    Param(usize),
    Binary {
        op: SqlBinOp,
        lhs: Box<SqlExpr>,
        rhs: Box<SqlExpr>,
    },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SqlBinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
}

/// One SELECT-list item.
#[derive(Debug, Clone, PartialEq)]
pub enum SelectItem {
    /// `*`
    Wildcard,
    /// Plain expression (usually a column), with optional alias.
    Expr { expr: SqlExpr, alias: Option<String> },
    /// `agg(expr)` or `COUNT(*)` (expr = None), with optional alias.
    Agg {
        agg: Aggregate,
        expr: Option<SqlExpr>,
        alias: Option<String>,
    },
}

/// `JOIN table ON left = right`.
#[derive(Debug, Clone, PartialEq)]
pub struct JoinClause {
    pub table: String,
    pub alias: Option<String>,
    pub left: ColumnRef,
    pub right: ColumnRef,
}

/// A parsed SELECT statement.
#[derive(Debug, Clone, PartialEq)]
pub struct Select {
    pub items: Vec<SelectItem>,
    pub table: String,
    pub alias: Option<String>,
    /// Equi-join chain, in written order. Each clause joins one new table
    /// against a table already in scope (the FROM table or an earlier
    /// join) — star and snowflake shapes.
    pub joins: Vec<JoinClause>,
    pub filter: Option<SqlExpr>,
    pub group_by: Vec<ColumnRef>,
    /// `ORDER BY col [ASC|DESC]` — (column-or-alias name, descending).
    pub order_by: Option<(String, bool)>,
    /// `LIMIT n` — the top-k form the URL-count workload naturally wants.
    pub limit: Option<usize>,
}

impl Select {
    /// True if the query aggregates (has agg items or a GROUP BY).
    pub fn is_aggregate(&self) -> bool {
        !self.group_by.is_empty()
            || self
                .items
                .iter()
                .any(|i| matches!(i, SelectItem::Agg { .. }))
    }
}
