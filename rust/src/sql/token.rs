//! SQL tokens.

use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Token {
    // Keywords (uppercased during lexing; SQL is case-insensitive).
    Select,
    From,
    Where,
    Group,
    Order,
    By,
    Join,
    Inner,
    On,
    As,
    And,
    Or,
    Not,
    Count,
    Sum,
    Min,
    Max,
    Avg,
    Distinct,
    Asc,
    Desc,
    Limit,
    // Literals and names.
    Ident(String),
    Int(i64),
    Float(f64),
    Str(String),
    /// Prepared-statement placeholder: `?` (positional, `None`) or `$n`
    /// (explicit 1-based index, `Some(n)`).
    Param(Option<usize>),
    // Punctuation.
    Star,
    Comma,
    Dot,
    LParen,
    RParen,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    Plus,
    Minus,
    Slash,
    Percent,
    Semicolon,
    Eof,
}

impl fmt::Display for Token {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Token::Ident(s) => write!(f, "{s}"),
            Token::Int(i) => write!(f, "{i}"),
            Token::Float(x) => write!(f, "{x}"),
            Token::Str(s) => write!(f, "'{s}'"),
            Token::Param(Some(n)) => write!(f, "${n}"),
            Token::Param(None) => write!(f, "?"),
            other => write!(f, "{other:?}"),
        }
    }
}

impl Token {
    pub fn keyword(upper: &str) -> Option<Token> {
        Some(match upper {
            "SELECT" => Token::Select,
            "FROM" => Token::From,
            "WHERE" => Token::Where,
            "GROUP" => Token::Group,
            "ORDER" => Token::Order,
            "BY" => Token::By,
            "JOIN" => Token::Join,
            "INNER" => Token::Inner,
            "ON" => Token::On,
            "AS" => Token::As,
            "AND" => Token::And,
            "OR" => Token::Or,
            "NOT" => Token::Not,
            "COUNT" => Token::Count,
            "SUM" => Token::Sum,
            "MIN" => Token::Min,
            "MAX" => Token::Max,
            "AVG" => Token::Avg,
            "DISTINCT" => Token::Distinct,
            "ASC" => Token::Asc,
            "DESC" => Token::Desc,
            "LIMIT" => Token::Limit,
            _ => return None,
        })
    }
}
