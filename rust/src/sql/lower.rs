//! Lowering SQL to the single intermediate representation (§IV).
//!
//! Instead of sending queries to a DBMS at run time, queries become
//! `forelem` loop nests in the same IR as the surrounding program —
//! unlocking vertical integration (§II). The three shapes the paper's
//! examples need:
//!
//! * group-by aggregation → counting loop + distinct-iteration loop
//!   (exactly the §IV URL-count IR);
//! * equi-join → nested `forelem` with a filtered inner index set
//!   (exactly Figure 1's top spec);
//! * select-project → single loop with filter (the §III-B grades query);
//! * aggregate over a join → the Figure-1 nest accumulating into
//!   per-group arrays, followed by the distinct-iteration emit loop. The
//!   group key and aggregate arguments may come from either table; the
//!   vectorized tier executes the nest as a build+probe hash join with
//!   fused `vec.count`/`vec.sum` kernels (see `exec::compile`).
//!
//! `ORDER BY` / `LIMIT` lower into the IR as an **ordered/bounded
//! emission** ([`EmitOrder`] on the loop that appends the result rows):
//! the sort column resolves to a position in the result schema, and the
//! clause becomes a `topk`-annotated emit loop — the §IV URL-count query
//! ends in `forelem (i; i ∈ paccess.distinct(url)) topk(#1 desc, k=5)`.
//! The optimizer decides heap-vs-sort execution (`opt.topk_heap` /
//! `opt.topk_sort`) and the vectorized tier runs bounded emissions as the
//! fused O(n log k) `vec.topk` kernel.
//!
//! Like the plain group-by shape, an aggregate over a join emits one row
//! per distinct group-key value of the owning table — groups with no
//! matching rows surface with the accumulator's init value, matching the
//! reference interpreter on the same IR.
//!
//! Join nest order is a *contract*, not a plan choice: lowering always
//! emits the FROM table as the outer loop and the JOIN table as the
//! filtered inner loop (which `exec::compile` hashes). Picking the
//! cheaper orientation is the cost-based optimizer's job —
//! `opt::optimize` swaps the nest when statistics say the written-first
//! table is the smaller build side (`opt.join_build_side`).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::ast::{Aggregate, ColumnRef, JoinClause, Select, SelectItem, SqlBinOp, SqlExpr};
use crate::ir::{
    ArrayDecl, BinOp, DataType, EmitOrder, Expr, IndexSet, Loop, Program, Schema, Stmt,
};

/// The relation catalog lowering resolves column references against.
pub type Catalog = BTreeMap<String, Schema>;

/// Lower a parsed SELECT into a forelem program.
///
/// The produced program reads the catalog relations and fills one result
/// multiset named `R`. `ORDER BY`/`LIMIT` lower into an [`EmitOrder`]
/// annotation on the loop that appends the result rows — the whole query,
/// top-k included, is one IR program.
pub fn lower(sel: &Select, catalog: &Catalog) -> Result<Program> {
    let ctx = LowerCtx::new(sel, catalog)?;
    if sel.is_aggregate() {
        ctx.lower_aggregate(sel)
    } else if sel.join.is_some() {
        ctx.lower_join(sel)
    } else {
        ctx.lower_select_project(sel)
    }
}

/// Resolve `ORDER BY`/`LIMIT` against the result schema's output names
/// (aliases included) into the IR's ordered/bounded emission contract.
/// `None` when the query has neither clause.
fn emit_order(sel: &Select, result_fields: &[(String, DataType)]) -> Result<Option<EmitOrder>> {
    let key = match &sel.order_by {
        Some((name, desc)) => {
            let id = result_fields
                .iter()
                .position(|(n, _)| n == name)
                .with_context(|| {
                    format!(
                        "ORDER BY unknown column `{name}` (result columns: {})",
                        result_fields
                            .iter()
                            .map(|(n, _)| n.clone())
                            .collect::<Vec<_>>()
                            .join(", ")
                    )
                })?;
            Some((id, *desc))
        }
        None => None,
    };
    Ok(match (key, sel.limit) {
        (None, None) => None,
        (key, limit) => Some(EmitOrder {
            key: key.map(|(id, _)| id),
            descending: key.map(|(_, d)| d).unwrap_or(false),
            limit,
            strategy: Default::default(),
        }),
    })
}

/// Convenience: parse + lower in one step.
pub fn compile_sql(input: &str, catalog: &Catalog) -> Result<Program> {
    let sel = super::parser::parse(input)?;
    lower(&sel, catalog)
}

struct LowerCtx<'a> {
    catalog: &'a Catalog,
    /// (cursor var, table name) for the main table and optional join table.
    main: (String, String),
    joined: Option<(String, String)>,
    /// alias → table.
    aliases: BTreeMap<String, String>,
}

impl<'a> LowerCtx<'a> {
    fn new(sel: &Select, catalog: &'a Catalog) -> Result<Self> {
        if !catalog.contains_key(&sel.table) {
            bail!(
                "unknown table `{}` (known tables: {})",
                sel.table,
                known_tables(catalog)
            );
        }
        let mut aliases = BTreeMap::new();
        aliases.insert(sel.table.clone(), sel.table.clone());
        if let Some(a) = &sel.alias {
            aliases.insert(a.clone(), sel.table.clone());
        }
        let joined = match &sel.join {
            Some(j) => {
                if !catalog.contains_key(&j.table) {
                    bail!(
                        "unknown join table `{}` (known tables: {})",
                        j.table,
                        known_tables(catalog)
                    );
                }
                aliases.insert(j.table.clone(), j.table.clone());
                if let Some(a) = &j.alias {
                    aliases.insert(a.clone(), j.table.clone());
                }
                Some(("j".to_string(), j.table.clone()))
            }
            None => None,
        };
        Ok(LowerCtx {
            catalog,
            main: ("i".to_string(), sel.table.clone()),
            joined,
            aliases,
        })
    }

    fn schema(&self, table: &str) -> &Schema {
        &self.catalog[table]
    }

    /// Tables this query's columns can resolve against (FROM + JOIN).
    fn tables_in_scope(&self) -> String {
        let mut names = vec![self.main.1.clone()];
        if let Some((_, jtable)) = &self.joined {
            names.push(jtable.clone());
        }
        names.join(", ")
    }

    /// Resolve a column reference to (cursor var, table, field name).
    fn resolve(&self, c: &ColumnRef) -> Result<(String, String, String)> {
        if let Some(t) = &c.table {
            let table = self.aliases.get(t).with_context(|| {
                format!(
                    "unknown table or alias `{t}` (tables in scope: {})",
                    self.tables_in_scope()
                )
            })?;
            let (var, _) = self.cursor_for(table)?;
            if self.schema(table).field_id(&c.column).is_none() {
                let columns = self
                    .schema(table)
                    .fields()
                    .iter()
                    .map(|f| f.name.clone())
                    .collect::<Vec<_>>()
                    .join(", ");
                bail!(
                    "no column `{}` in table `{table}` (columns: {columns})",
                    c.column
                );
            }
            return Ok((var, table.clone(), c.column.clone()));
        }
        // Unqualified: search the main table, then the join table.
        let (mvar, mtable) = &self.main;
        if self.schema(mtable).field_id(&c.column).is_some() {
            return Ok((mvar.clone(), mtable.clone(), c.column.clone()));
        }
        if let Some((jvar, jtable)) = &self.joined {
            if self.schema(jtable).field_id(&c.column).is_some() {
                return Ok((jvar.clone(), jtable.clone(), c.column.clone()));
            }
        }
        bail!(
            "column `{}` not found in any table (searched {})",
            c.column,
            self.tables_in_scope()
        )
    }

    fn cursor_for(&self, table: &str) -> Result<(String, String)> {
        if table == self.main.1 {
            return Ok(self.main.clone());
        }
        if let Some(j) = &self.joined {
            if table == j.1 {
                return Ok(j.clone());
            }
        }
        bail!("table `{table}` not in FROM clause")
    }

    fn expr(&self, e: &SqlExpr) -> Result<Expr> {
        Ok(match e {
            SqlExpr::Column(c) => {
                let (var, _, field) = self.resolve(c)?;
                Expr::field(&var, &field)
            }
            SqlExpr::Literal(v) => Expr::Const(v.clone()),
            SqlExpr::Binary { op, lhs, rhs } => Expr::bin(
                binop(*op),
                self.expr(lhs)?,
                self.expr(rhs)?,
            ),
        })
    }

    fn expr_dtype(&self, e: &SqlExpr) -> Result<DataType> {
        Ok(match e {
            SqlExpr::Column(c) => {
                let (_, table, field) = self.resolve(c)?;
                let s = self.schema(&table);
                s.dtype(s.field_id(&field).unwrap())
            }
            SqlExpr::Literal(v) => v.data_type().unwrap_or(DataType::Int),
            SqlExpr::Binary { op, lhs, rhs } => {
                if matches!(
                    op,
                    SqlBinOp::Eq
                        | SqlBinOp::Ne
                        | SqlBinOp::Lt
                        | SqlBinOp::Le
                        | SqlBinOp::Gt
                        | SqlBinOp::Ge
                        | SqlBinOp::And
                        | SqlBinOp::Or
                ) {
                    DataType::Bool
                } else if self.expr_dtype(lhs)? == DataType::Float
                    || self.expr_dtype(rhs)? == DataType::Float
                {
                    DataType::Float
                } else {
                    DataType::Int
                }
            }
        })
    }

    /// Split a WHERE conjunction into (single equality usable as an index
    /// set filter on the main table, remaining residual predicate).
    fn split_filter(&self, filter: &SqlExpr) -> (Option<(String, Expr)>, Option<SqlExpr>) {
        // Only top-level conjuncts are candidates.
        let mut conjuncts = Vec::new();
        collect_conjuncts(filter, &mut conjuncts);
        let mut index_filter = None;
        let mut residual: Vec<SqlExpr> = Vec::new();
        for c in conjuncts {
            if index_filter.is_none() {
                if let SqlExpr::Binary {
                    op: SqlBinOp::Eq,
                    lhs,
                    rhs,
                } = &c
                {
                    // column = literal (either side) on the MAIN table.
                    let col_lit = match (lhs.as_ref(), rhs.as_ref()) {
                        (SqlExpr::Column(col), SqlExpr::Literal(v))
                        | (SqlExpr::Literal(v), SqlExpr::Column(col)) => Some((col, v)),
                        _ => None,
                    };
                    if let Some((col, v)) = col_lit {
                        if let Ok((var, table, field)) = self.resolve(col) {
                            if var == self.main.0 && table == self.main.1 {
                                index_filter = Some((field, Expr::Const(v.clone())));
                                continue;
                            }
                        }
                    }
                }
            }
            residual.push(c);
        }
        let residual = residual.into_iter().reduce(|a, b| SqlExpr::Binary {
            op: SqlBinOp::And,
            lhs: Box::new(a),
            rhs: Box::new(b),
        });
        (index_filter, residual)
    }

    /// Wrap `body` in the residual-predicate If, if any.
    fn guard(&self, residual: &Option<SqlExpr>, body: Vec<Stmt>) -> Result<Vec<Stmt>> {
        Ok(match residual {
            Some(pred) => vec![Stmt::If {
                cond: self.expr(pred)?,
                then: body,
                els: vec![],
            }],
            None => body,
        })
    }

    // ---- shapes ---------------------------------------------------------

    /// `SELECT g, AGG(x) FROM t [JOIN u ON ...] [WHERE ...] GROUP BY g` →
    /// counting loop (a Figure-1 join nest when a JOIN is present) +
    /// distinct emit loop (§IV). The group key and aggregate arguments may
    /// come from either joined table.
    fn lower_aggregate(&self, sel: &Select) -> Result<Program> {
        if sel.group_by.len() != 1 {
            bail!(
                "exactly one GROUP BY column is supported (got {})",
                sel.group_by.len()
            );
        }
        let (gvar, gtable, gfield) = self.resolve(&sel.group_by[0])?;
        let gdtype = {
            let s = self.schema(&gtable);
            s.dtype(s.field_id(&gfield).unwrap())
        };

        let (index_filter, residual) = match &sel.filter {
            Some(f) => self.split_filter(f),
            None => (None, None),
        };

        let (ivar, itable) = self.main.clone();
        let mut program = Program::new(&format!("groupby_{}", gtable));
        program = program.with_relation(&itable, self.schema(&itable).clone());
        if let Some((_, jtable)) = &self.joined {
            if jtable != &itable {
                program = program.with_relation(jtable, self.schema(jtable).clone());
            }
        }

        // One accumulator array per aggregate item + the result schema.
        let mut result_fields: Vec<(String, DataType)> = Vec::new();
        let mut accum_stmts: Vec<Stmt> = Vec::new();
        let mut union_tuple: Vec<Expr> = Vec::new();
        let group_key = Expr::field(&gvar, &gfield);

        for (idx, item) in sel.items.iter().enumerate() {
            match item {
                SelectItem::Wildcard => bail!("SELECT * not allowed with GROUP BY"),
                SelectItem::Expr { expr, alias } => {
                    // Must be the group key.
                    let lowered = self.expr(expr)?;
                    if lowered != group_key {
                        bail!("non-aggregate select item must be the GROUP BY column");
                    }
                    result_fields.push((
                        alias.clone().unwrap_or_else(|| gfield.clone()),
                        gdtype,
                    ));
                    union_tuple.push(group_key.clone());
                }
                SelectItem::Agg { agg, expr, alias } => {
                    let array = format!("agg{idx}");
                    let (decl, accum, read_back, dtype) =
                        self.lower_agg(*agg, expr, &array, &group_key)?;
                    program = program.with_array(&array, decl);
                    if let Some((extra_name, extra_decl)) = accum.1 {
                        program = program.with_array(&extra_name, extra_decl);
                    }
                    accum_stmts.extend(accum.0);
                    result_fields.push((
                        alias.clone().unwrap_or_else(|| format!("{agg:?}").to_lowercase()),
                        dtype,
                    ));
                    union_tuple.push(read_back);
                }
            }
        }

        let result_schema = Schema::new(
            result_fields
                .iter()
                .map(|(n, t)| (n.as_str(), *t))
                .collect(),
        );
        program = program.with_result("R", result_schema);

        // Loop 1: accumulate — a plain scan of the FROM table, or the
        // Figure-1 join nest when a JOIN is present.
        let outer_ix = match &index_filter {
            Some((f, v)) => IndexSet::filtered(&itable, f, v.clone()),
            None => IndexSet::all(&itable),
        };
        let accum_body = self.guard(&residual, accum_stmts)?;
        let loop1 = match &self.joined {
            Some((jvar, jtable)) => {
                let (outer_field, inner_field) = self.join_on_fields(sel)?;
                let inner_ix = IndexSet::filtered(
                    jtable,
                    &inner_field,
                    Expr::field(&ivar, &outer_field),
                );
                Loop::forelem(
                    &ivar,
                    outer_ix,
                    vec![Stmt::Loop(Loop::forelem(jvar, inner_ix, accum_body))],
                )
            }
            None => Loop::forelem(&ivar, outer_ix, accum_body),
        };
        // Loop 2: iterate distinct group keys of the owning table, emit
        // result rows (the emit cursor reuses the group key's cursor var).
        // ORDER BY/LIMIT annotate this loop: the paper's URL-count query
        // ends in a `topk`-bounded emission over the distinct domain.
        let ix2 = IndexSet::distinct_of(&gtable, &gfield);
        let body2 = vec![Stmt::result_union("R", union_tuple)];
        let mut loop2 = Loop::forelem(&gvar, ix2, body2);
        if let Some(e) = emit_order(sel, &result_fields)? {
            loop2 = loop2.with_emit(e);
        }

        program.body = vec![Stmt::Loop(loop1), Stmt::Loop(loop2)];
        crate::ir::validate(&program)?;
        Ok(program)
    }

    /// Orient the JOIN's ON clause: returns (main-table field, join-table
    /// field) regardless of which side each was written on.
    fn join_on_fields(&self, sel: &Select) -> Result<(String, String)> {
        let join: &JoinClause = sel.join.as_ref().context("no JOIN clause")?;
        let (ivar, _) = &self.main;
        let (jvar, _) = self.joined.as_ref().context("no JOIN clause")?;
        let (lvar, _, lfield) = self.resolve(&join.left)?;
        let (rvar, _, rfield) = self.resolve(&join.right)?;
        if &lvar == ivar && &rvar == jvar {
            Ok((lfield, rfield))
        } else if &lvar == jvar && &rvar == ivar {
            Ok((rfield, lfield))
        } else {
            bail!("JOIN ON must relate the two FROM tables")
        }
    }

    /// Build the accumulation statement(s) + read-back expression for one
    /// aggregate item.
    #[allow(clippy::type_complexity)]
    fn lower_agg(
        &self,
        agg: Aggregate,
        arg: &Option<SqlExpr>,
        array: &str,
        group_key: &Expr,
    ) -> Result<(
        ArrayDecl,
        (Vec<Stmt>, Option<(String, ArrayDecl)>),
        Expr,
        DataType,
    )> {
        use crate::ir::AccumOp;
        let read = Expr::array(array, vec![group_key.clone()]);
        match agg {
            Aggregate::Count => Ok((
                ArrayDecl::counter(),
                (
                    vec![Stmt::increment(array, vec![group_key.clone()])],
                    None,
                ),
                read,
                DataType::Int,
            )),
            Aggregate::Sum | Aggregate::Min | Aggregate::Max => {
                let arg = arg
                    .as_ref()
                    .with_context(|| format!("{agg:?} requires an argument"))?;
                let dtype = self.expr_dtype(arg)?;
                let op = match agg {
                    Aggregate::Sum => AccumOp::Add,
                    Aggregate::Min => AccumOp::Min,
                    Aggregate::Max => AccumOp::Max,
                    _ => unreachable!(),
                };
                Ok((
                    ArrayDecl::accumulator(dtype),
                    (
                        vec![Stmt::accum(
                            array,
                            vec![group_key.clone()],
                            op,
                            self.expr(arg)?,
                        )],
                        None,
                    ),
                    read,
                    dtype,
                ))
            }
            Aggregate::Avg => {
                let arg = arg.as_ref().context("AVG requires an argument")?;
                let narray = format!("{array}_n");
                let stmts = vec![
                    Stmt::accum(
                        array,
                        vec![group_key.clone()],
                        AccumOp::Add,
                        self.expr(arg)?,
                    ),
                    Stmt::increment(&narray, vec![group_key.clone()]),
                ];
                let read = Expr::bin(
                    BinOp::Div,
                    Expr::array(array, vec![group_key.clone()]),
                    Expr::array(&narray, vec![group_key.clone()]),
                );
                Ok((
                    ArrayDecl::accumulator(DataType::Float),
                    (stmts, Some((narray, ArrayDecl::counter()))),
                    read,
                    DataType::Float,
                ))
            }
        }
    }

    /// Equi-join → nested forelem with filtered inner index set (Figure 1).
    fn lower_join(&self, sel: &Select) -> Result<Program> {
        let (ivar, itable) = self.main.clone();
        let (jvar, jtable) = self.joined.clone().unwrap();
        let (outer_field, inner_field) = self.join_on_fields(sel)?;

        let (index_filter, residual) = match &sel.filter {
            Some(f) => self.split_filter(f),
            None => (None, None),
        };

        // Result tuple from the select list.
        let mut fields = Vec::new();
        let mut tuple = Vec::new();
        for item in &sel.items {
            match item {
                SelectItem::Wildcard => {
                    for (var, table) in [(&ivar, &itable), (&jvar, &jtable)] {
                        for f in self.schema(table).fields() {
                            fields.push((format!("{table}.{}", f.name), f.dtype));
                            tuple.push(Expr::field(var, &f.name));
                        }
                    }
                }
                SelectItem::Expr { expr, alias } => {
                    let name = alias.clone().unwrap_or_else(|| display_name(expr));
                    fields.push((name, self.expr_dtype(expr)?));
                    tuple.push(self.expr(expr)?);
                }
                SelectItem::Agg { .. } => unreachable!("handled by lower_aggregate"),
            }
        }
        let result_schema =
            Schema::new(fields.iter().map(|(n, t)| (n.as_str(), *t)).collect());

        let inner_ix =
            IndexSet::filtered(&jtable, &inner_field, Expr::field(&ivar, &outer_field));
        let inner_body = self.guard(&residual, vec![Stmt::result_union("R", tuple)])?;
        let outer_ix = match &index_filter {
            Some((f, v)) => IndexSet::filtered(&itable, f, v.clone()),
            None => IndexSet::all(&itable),
        };

        let mut program = Program::new(&format!("join_{itable}_{jtable}"))
            .with_relation(&itable, self.schema(&itable).clone())
            .with_relation(&jtable, self.schema(&jtable).clone())
            .with_result("R", result_schema);
        // ORDER BY/LIMIT annotate the outer loop: the emission bound
        // covers the whole nest's appended rows.
        let mut nest = Loop::forelem(
            &ivar,
            outer_ix,
            vec![Stmt::Loop(Loop::forelem(&jvar, inner_ix, inner_body))],
        );
        if let Some(e) = emit_order(sel, &fields)? {
            nest = nest.with_emit(e);
        }
        program.body = vec![Stmt::Loop(nest)];
        crate::ir::validate(&program)?;
        Ok(program)
    }

    /// Plain select-project (§III-B grades query).
    fn lower_select_project(&self, sel: &Select) -> Result<Program> {
        let (ivar, itable) = self.main.clone();
        let (index_filter, residual) = match &sel.filter {
            Some(f) => self.split_filter(f),
            None => (None, None),
        };

        let mut fields = Vec::new();
        let mut tuple = Vec::new();
        for item in &sel.items {
            match item {
                SelectItem::Wildcard => {
                    for f in self.schema(&itable).fields() {
                        fields.push((f.name.clone(), f.dtype));
                        tuple.push(Expr::field(&ivar, &f.name));
                    }
                }
                SelectItem::Expr { expr, alias } => {
                    let name = alias.clone().unwrap_or_else(|| display_name(expr));
                    fields.push((name, self.expr_dtype(expr)?));
                    tuple.push(self.expr(expr)?);
                }
                SelectItem::Agg { .. } => unreachable!("handled by lower_aggregate"),
            }
        }
        let result_schema =
            Schema::new(fields.iter().map(|(n, t)| (n.as_str(), *t)).collect());

        let ix = match &index_filter {
            Some((f, v)) => IndexSet::filtered(&itable, f, v.clone()),
            None => IndexSet::all(&itable),
        };
        let body = self.guard(&residual, vec![Stmt::result_union("R", tuple)])?;

        let mut program = Program::new(&format!("select_{itable}"))
            .with_relation(&itable, self.schema(&itable).clone())
            .with_result("R", result_schema);
        let mut scan = Loop::forelem(&ivar, ix, body);
        if let Some(e) = emit_order(sel, &fields)? {
            scan = scan.with_emit(e);
        }
        program.body = vec![Stmt::Loop(scan)];
        crate::ir::validate(&program)?;
        Ok(program)
    }
}

/// Comma-separated catalog table names, for error messages.
fn known_tables(catalog: &Catalog) -> String {
    catalog.keys().cloned().collect::<Vec<_>>().join(", ")
}

fn collect_conjuncts(e: &SqlExpr, out: &mut Vec<SqlExpr>) {
    match e {
        SqlExpr::Binary {
            op: SqlBinOp::And,
            lhs,
            rhs,
        } => {
            collect_conjuncts(lhs, out);
            collect_conjuncts(rhs, out);
        }
        other => out.push(other.clone()),
    }
}

fn display_name(e: &SqlExpr) -> String {
    match e {
        SqlExpr::Column(c) => c.column.clone(),
        SqlExpr::Literal(v) => v.to_string(),
        SqlExpr::Binary { .. } => "expr".to_string(),
    }
}

fn binop(op: SqlBinOp) -> BinOp {
    match op {
        SqlBinOp::Add => BinOp::Add,
        SqlBinOp::Sub => BinOp::Sub,
        SqlBinOp::Mul => BinOp::Mul,
        SqlBinOp::Div => BinOp::Div,
        SqlBinOp::Mod => BinOp::Mod,
        SqlBinOp::Eq => BinOp::Eq,
        SqlBinOp::Ne => BinOp::Ne,
        SqlBinOp::Lt => BinOp::Lt,
        SqlBinOp::Le => BinOp::Le,
        SqlBinOp::Gt => BinOp::Gt,
        SqlBinOp::Ge => BinOp::Ge,
        SqlBinOp::And => BinOp::And,
        SqlBinOp::Or => BinOp::Or,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::pretty;

    fn catalog() -> Catalog {
        let mut c = Catalog::new();
        c.insert("access".into(), Schema::new(vec![("url", DataType::Str)]));
        c.insert(
            "links".into(),
            Schema::new(vec![("source", DataType::Str), ("target", DataType::Str)]),
        );
        c.insert(
            "Grades".into(),
            Schema::new(vec![
                ("studentID", DataType::Int),
                ("grade", DataType::Float),
                ("weight", DataType::Float),
            ]),
        );
        c.insert(
            "A".into(),
            Schema::new(vec![("b_id", DataType::Int), ("field", DataType::Str)]),
        );
        c.insert(
            "B".into(),
            Schema::new(vec![("id", DataType::Int), ("field", DataType::Str)]),
        );
        c
    }

    #[test]
    fn url_count_lowers_to_the_papers_ir() {
        let p =
            compile_sql("SELECT url, COUNT(url) FROM access GROUP BY url", &catalog()).unwrap();
        let text = pretty::program(&p);
        // §IV: counting loop over pAccess + distinct loop.
        assert!(text.contains("forelem (i; i ∈ paccess)"), "{text}");
        assert!(text.contains("agg1[i.url]++;"), "{text}");
        assert!(text.contains("i ∈ paccess.distinct(url)"), "{text}");
        assert!(text.contains("R = R ∪ (i.url, agg1[i.url]);"), "{text}");
    }

    #[test]
    fn join_lowers_to_figure1_spec() {
        let p = compile_sql(
            "SELECT A.field, B.field FROM A JOIN B ON A.b_id = B.id",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        assert!(text.contains("forelem (i; i ∈ pA)"), "{text}");
        assert!(text.contains("forelem (j; j ∈ pB.id[i.b_id])"), "{text}");
        assert!(text.contains("R = R ∪ (i.field, j.field);"), "{text}");
    }

    #[test]
    fn grades_query_uses_index_filter() {
        let p = compile_sql(
            "SELECT grade, weight FROM Grades WHERE studentID = 25",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        assert!(text.contains("i ∈ pGrades.studentID[25]"), "{text}");
    }

    #[test]
    fn residual_predicates_become_guards() {
        let p = compile_sql(
            "SELECT grade FROM Grades WHERE studentID = 25 AND grade > 5.5",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        assert!(text.contains("pGrades.studentID[25]"), "{text}");
        assert!(text.contains("if ((i.grade > 5.5))"), "{text}");
    }

    #[test]
    fn sum_and_avg_aggregates() {
        let p = compile_sql(
            "SELECT studentID, SUM(grade) AS total, AVG(weight) FROM Grades GROUP BY studentID",
            &catalog(),
        )
        .unwrap();
        assert!(p.arrays.len() >= 3); // sum + avg-sum + avg-count
        let schema = &p.results["R"];
        assert_eq!(schema.field(1).name, "total");
        assert_eq!(schema.dtype(1), DataType::Float);
    }

    #[test]
    fn reverse_weblink_query_lowers() {
        let p = compile_sql(
            "SELECT target, COUNT(target) FROM links GROUP BY target",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        assert!(text.contains("forelem (i; i ∈ plinks)"), "{text}");
        assert!(text.contains("agg1[i.target]++;"), "{text}");
    }

    #[test]
    fn join_aggregate_lowers_to_figure1_nest_plus_emit() {
        let p = compile_sql(
            "SELECT A.field, COUNT(A.field) FROM A JOIN B ON A.b_id = B.id GROUP BY A.field",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        // Figure-1 nest accumulating per group key...
        assert!(text.contains("forelem (i; i ∈ pA)"), "{text}");
        assert!(text.contains("forelem (j; j ∈ pB.id[i.b_id])"), "{text}");
        assert!(text.contains("agg1[i.field]++;"), "{text}");
        // ...then the distinct emit loop over the owning table.
        assert!(text.contains("i ∈ pA.distinct(field)"), "{text}");
        assert!(text.contains("R = R ∪ (i.field, agg1[i.field]);"), "{text}");
    }

    #[test]
    fn join_aggregate_group_key_may_come_from_join_table() {
        let p = compile_sql(
            "SELECT B.field, SUM(A.b_id) FROM A JOIN B ON A.b_id = B.id GROUP BY B.field",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        assert!(text.contains("forelem (j; j ∈ pB.id[i.b_id])"), "{text}");
        assert!(text.contains("agg1[j.field] += i.b_id;"), "{text}");
        // Emit loop binds the join table's cursor var.
        assert!(text.contains("forelem (j; j ∈ pB.distinct(field))"), "{text}");
    }

    #[test]
    fn errors_are_descriptive() {
        let c = catalog();
        assert!(compile_sql("SELECT x FROM nope", &c)
            .unwrap_err()
            .to_string()
            .contains("unknown table"));
        assert!(compile_sql("SELECT nope FROM access", &c)
            .unwrap_err()
            .to_string()
            .contains("not found"));
        assert!(compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url, url",
            &c
        )
        .is_err());
    }

    #[test]
    fn unknown_join_tables_and_columns_name_candidates() {
        let c = catalog();
        // Unknown JOIN table: the message lists the catalog's tables.
        let err = compile_sql("SELECT url FROM access JOIN nope ON access.url = nope.x", &c)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown join table `nope`"), "{err}");
        assert!(err.contains("known tables:"), "{err}");
        assert!(err.contains("access") && err.contains("links"), "{err}");
        // Unknown column in a join: the message names the searched tables.
        let err = compile_sql("SELECT nope FROM A JOIN B ON A.b_id = B.id", &c)
            .unwrap_err()
            .to_string();
        assert!(err.contains("searched A, B"), "{err}");
        // Unknown qualified column: the message lists the table's columns.
        let err = compile_sql("SELECT A.nope FROM A JOIN B ON A.b_id = B.id", &c)
            .unwrap_err()
            .to_string();
        assert!(err.contains("columns: b_id, field"), "{err}");
        // Unknown alias: the message names the tables in scope.
        let err = compile_sql("SELECT Z.field FROM A JOIN B ON A.b_id = B.id", &c)
            .unwrap_err()
            .to_string();
        assert!(err.contains("tables in scope: A, B"), "{err}");
    }

    #[test]
    fn order_by_limit_lowers_to_topk_annotated_emit_loop() {
        use crate::ir::EmitOrder;
        let c = catalog();
        // The paper's flagship form: group-by ending in a bounded emit.
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url ORDER BY count DESC LIMIT 5",
            &c,
        )
        .unwrap();
        let Stmt::Loop(emit) = &p.body[1] else {
            panic!("expected the distinct emit loop")
        };
        assert_eq!(emit.emit, Some(EmitOrder::top_k(1, true, 5)));
        let text = pretty::program(&p);
        assert!(
            text.contains("i ∈ paccess.distinct(url)) topk(#1 desc, k=5)"),
            "{text}"
        );

        // Alias resolution: ORDER BY the aliased aggregate column.
        let p = compile_sql(
            "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n ASC",
            &c,
        )
        .unwrap();
        let Stmt::Loop(emit) = &p.body[1] else {
            panic!("expected the distinct emit loop")
        };
        assert_eq!(emit.emit, Some(EmitOrder::ordered(1, false)));

        // Select-project: the single scan loop carries the annotation.
        let p = compile_sql("SELECT url FROM access LIMIT 10", &c).unwrap();
        let Stmt::Loop(scan) = &p.body[0] else {
            panic!("expected scan loop")
        };
        assert_eq!(scan.emit, Some(EmitOrder::first_k(10)));

        // Join: the outer loop of the nest carries the annotation.
        let p = compile_sql(
            "SELECT A.field, B.field FROM A JOIN B ON A.b_id = B.id ORDER BY field DESC LIMIT 2",
            &c,
        )
        .unwrap();
        let Stmt::Loop(outer) = &p.body[0] else {
            panic!("expected join nest")
        };
        assert_eq!(outer.emit, Some(EmitOrder::top_k(0, true, 2)));
        let [Stmt::Loop(inner)] = outer.body.as_slice() else {
            panic!("outer body must be the inner loop")
        };
        assert!(inner.emit.is_none());
    }

    #[test]
    fn order_by_unknown_column_names_result_columns() {
        let c = catalog();
        let err = compile_sql(
            "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY nope",
            &c,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("ORDER BY unknown column `nope`"), "{err}");
        assert!(err.contains("result columns: url, n"), "{err}");
    }

    #[test]
    fn wildcard_select_expands_schema() {
        let p = compile_sql("SELECT * FROM Grades", &catalog()).unwrap();
        assert_eq!(p.results["R"].len(), 3);
    }

    #[test]
    fn join_nest_order_is_the_optimizer_contract() {
        // `opt::optimize` swaps the Figure-1 nest by matching exactly
        // this shape: FROM table outer, JOIN table inner, inner index
        // set filtered on a plain field of the outer cursor. Pin it.
        use crate::ir::Domain;
        for q in [
            "SELECT A.field FROM A JOIN B ON A.b_id = B.id",
            "SELECT A.field, COUNT(A.field) FROM A JOIN B ON A.b_id = B.id GROUP BY A.field",
        ] {
            let p = compile_sql(q, &catalog()).unwrap();
            let Stmt::Loop(outer) = &p.body[0] else {
                panic!("`{q}`: first statement must be the join nest")
            };
            let Domain::IndexSet(ox) = &outer.domain else {
                panic!("`{q}`: outer domain must be an index set")
            };
            assert_eq!(ox.relation, "A", "`{q}`: FROM table is the outer loop");
            assert!(ox.field_filter.is_none());
            let [Stmt::Loop(inner)] = outer.body.as_slice() else {
                panic!("`{q}`: outer body must be exactly the inner loop")
            };
            let Domain::IndexSet(iix) = &inner.domain else {
                panic!("`{q}`: inner domain must be an index set")
            };
            assert_eq!(iix.relation, "B", "`{q}`: JOIN table is the inner loop");
            let Some((field, key)) = &iix.field_filter else {
                panic!("`{q}`: inner loop must be key-filtered")
            };
            assert_eq!(field, "id");
            assert_eq!(
                key,
                &Expr::field(&outer.var, "b_id"),
                "`{q}`: inner filter keys on a plain outer-cursor field"
            );
        }
    }
}
