//! Lowering SQL to the single intermediate representation (§IV).
//!
//! Instead of sending queries to a DBMS at run time, queries become
//! `forelem` loop nests in the same IR as the surrounding program —
//! unlocking vertical integration (§II). The three shapes the paper's
//! examples need:
//!
//! * group-by aggregation → counting loop + distinct-iteration loop
//!   (exactly the §IV URL-count IR);
//! * equi-join → nested `forelem` with a filtered inner index set
//!   (exactly Figure 1's top spec). N-way equi-join chains (star and
//!   snowflake shapes) generalize the figure: each `JOIN t ON ...`
//!   clause becomes one more filtered `forelem` level keyed on an
//!   enclosing cursor's field — the FROM table for a star, an earlier
//!   join's cursor for a snowflake;
//! * select-project → single loop with filter (the §III-B grades query);
//! * aggregate over a join → the join nest accumulating into per-group
//!   arrays, followed by the distinct-iteration emit loop. The group key
//!   and aggregate arguments may come from any joined table; the
//!   vectorized tier executes the nest as a pipelined multi-level
//!   build+probe hash join with fused `vec.count`/`vec.sum` kernels
//!   (see `exec::compile`).
//!
//! `ORDER BY` / `LIMIT` lower into the IR as an **ordered/bounded
//! emission** ([`EmitOrder`] on the loop that appends the result rows):
//! the sort column resolves to a position in the result schema, and the
//! clause becomes a `topk`-annotated emit loop — the §IV URL-count query
//! ends in `forelem (i; i ∈ paccess.distinct(url)) topk(#1 desc, k=5)`.
//! The optimizer decides heap-vs-sort execution (`opt.topk_heap` /
//! `opt.topk_sort`) and the vectorized tier runs bounded emissions as the
//! fused O(n log k) `vec.topk` kernel.
//!
//! Like the plain group-by shape, an aggregate over a join emits one row
//! per distinct group-key value of the owning table — groups with no
//! matching rows surface with the accumulator's init value, matching the
//! reference interpreter on the same IR.
//!
//! Join nest order is a *contract*, not a plan choice: lowering always
//! emits the FROM table as the outer loop and each JOIN as one more
//! filtered inner loop in written order (which `exec::compile` hashes).
//! Picking the cheaper order is the cost-based optimizer's job —
//! `opt::optimize` swaps a two-table nest when statistics say the
//! written-first table is the smaller build side (`opt.join_build_side`)
//! and runs a Selinger-style DP over deeper chains (`opt.join_order`).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::ast::{Aggregate, ColumnRef, Select, SelectItem, SqlBinOp, SqlExpr};
use crate::ir::{
    ArrayDecl, BinOp, DataType, EmitOrder, Expr, IndexSet, Loop, Program, Schema, Stmt,
};

/// The relation catalog lowering resolves column references against.
pub type Catalog = BTreeMap<String, Schema>;

/// Lower a parsed SELECT into a forelem program.
///
/// The produced program reads the catalog relations and fills one result
/// multiset named `R`. `ORDER BY`/`LIMIT` lower into an [`EmitOrder`]
/// annotation on the loop that appends the result rows — the whole query,
/// top-k included, is one IR program.
pub fn lower(sel: &Select, catalog: &Catalog) -> Result<Program> {
    lower_with_stats(sel, catalog, &|_, _| None)
}

/// [`lower`] with column statistics: `ndv(table, column)` returns the
/// number of distinct values when known. Lowering uses it to lift the
/// *most selective* liftable equality conjunct into the index-set filter
/// (equality selectivity ≈ 1/NDV, so the highest-NDV column prunes the
/// scan hardest). With no statistics, written order decides — identical
/// to [`lower`].
pub fn lower_with_stats(
    sel: &Select,
    catalog: &Catalog,
    ndv: &dyn Fn(&str, &str) -> Option<u64>,
) -> Result<Program> {
    let ctx = LowerCtx::new(sel, catalog, ndv)?;
    if sel.is_aggregate() {
        ctx.lower_aggregate(sel)
    } else if !sel.joins.is_empty() {
        ctx.lower_join(sel)
    } else {
        ctx.lower_select_project(sel)
    }
}

/// Resolve `ORDER BY`/`LIMIT` against the result schema's output names
/// (aliases included) into the IR's ordered/bounded emission contract.
/// `None` when the query has neither clause.
fn emit_order(sel: &Select, result_fields: &[(String, DataType)]) -> Result<Option<EmitOrder>> {
    let key = match &sel.order_by {
        Some((name, desc)) => {
            let id = result_fields
                .iter()
                .position(|(n, _)| n == name)
                .with_context(|| {
                    format!(
                        "ORDER BY unknown column `{name}` (result columns: {})",
                        result_fields
                            .iter()
                            .map(|(n, _)| n.clone())
                            .collect::<Vec<_>>()
                            .join(", ")
                    )
                })?;
            Some((id, *desc))
        }
        None => None,
    };
    Ok(match (key, sel.limit) {
        (None, None) => None,
        (key, limit) => Some(EmitOrder {
            key: key.map(|(id, _)| id),
            descending: key.map(|(_, d)| d).unwrap_or(false),
            limit,
            strategy: Default::default(),
        }),
    })
}

/// Convenience: parse + lower in one step.
pub fn compile_sql(input: &str, catalog: &Catalog) -> Result<Program> {
    let sel = super::parser::parse(input)?;
    lower(&sel, catalog)
}

struct LowerCtx<'a> {
    catalog: &'a Catalog,
    /// (cursor var, table name) for the FROM table.
    main: (String, String),
    /// (cursor var, table name) per JOIN clause, in written order. Cursor
    /// vars are `j`, `j2`, `j3`, …
    joins: Vec<(String, String)>,
    /// alias → table.
    aliases: BTreeMap<String, String>,
    /// Column statistics: `ndv(table, column)` when known.
    ndv: &'a dyn Fn(&str, &str) -> Option<u64>,
}

/// One lowered JOIN level:
/// `forelem (var; var ∈ p{table}.{field}[{parent_var}.{parent_field}])`.
struct JoinEdge {
    var: String,
    table: String,
    /// Key field on the newly joined (inner) table.
    field: String,
    /// Enclosing cursor the level's filter keys on — the FROM cursor for
    /// a star edge, an earlier join's cursor for a snowflake edge.
    parent_var: String,
    parent_field: String,
}

impl<'a> LowerCtx<'a> {
    fn new(
        sel: &Select,
        catalog: &'a Catalog,
        ndv: &'a dyn Fn(&str, &str) -> Option<u64>,
    ) -> Result<Self> {
        if !catalog.contains_key(&sel.table) {
            bail!(
                "unknown table `{}` (known tables: {})",
                sel.table,
                known_tables(catalog)
            );
        }
        let mut aliases = BTreeMap::new();
        aliases.insert(sel.table.clone(), sel.table.clone());
        if let Some(a) = &sel.alias {
            aliases.insert(a.clone(), sel.table.clone());
        }
        let mut joins: Vec<(String, String)> = Vec::new();
        for (k, j) in sel.joins.iter().enumerate() {
            if !catalog.contains_key(&j.table) {
                bail!(
                    "unknown join table `{}` (known tables: {})",
                    j.table,
                    known_tables(catalog)
                );
            }
            if j.table == sel.table || joins.iter().any(|(_, t)| t == &j.table) {
                bail!(
                    "duplicate table `{}` in the join chain (self-joins are not supported)",
                    j.table
                );
            }
            aliases.insert(j.table.clone(), j.table.clone());
            if let Some(a) = &j.alias {
                if let Some(prev) = aliases.insert(a.clone(), j.table.clone()) {
                    if prev != j.table {
                        bail!("alias `{a}` is already bound to table `{prev}`");
                    }
                }
            }
            let var = if k == 0 {
                "j".to_string()
            } else {
                format!("j{}", k + 1)
            };
            joins.push((var, j.table.clone()));
        }
        Ok(LowerCtx {
            catalog,
            main: ("i".to_string(), sel.table.clone()),
            joins,
            aliases,
            ndv,
        })
    }

    fn schema(&self, table: &str) -> &Schema {
        &self.catalog[table]
    }

    /// Tables this query's columns can resolve against (FROM + JOINs).
    fn tables_in_scope(&self) -> String {
        let mut names = vec![self.main.1.clone()];
        names.extend(self.joins.iter().map(|(_, t)| t.clone()));
        names.join(", ")
    }

    /// Resolve a column reference to (cursor var, table, field name).
    fn resolve(&self, c: &ColumnRef) -> Result<(String, String, String)> {
        if let Some(t) = &c.table {
            let table = self.aliases.get(t).with_context(|| {
                format!(
                    "unknown table or alias `{t}` (tables in scope: {})",
                    self.tables_in_scope()
                )
            })?;
            let (var, _) = self.cursor_for(table)?;
            if self.schema(table).field_id(&c.column).is_none() {
                let columns = self
                    .schema(table)
                    .fields()
                    .iter()
                    .map(|f| f.name.clone())
                    .collect::<Vec<_>>()
                    .join(", ");
                bail!(
                    "no column `{}` in table `{table}` (columns: {columns})",
                    c.column
                );
            }
            return Ok((var, table.clone(), c.column.clone()));
        }
        // Unqualified: search the main table, then the join tables in
        // written order.
        let (mvar, mtable) = &self.main;
        if self.schema(mtable).field_id(&c.column).is_some() {
            return Ok((mvar.clone(), mtable.clone(), c.column.clone()));
        }
        for (jvar, jtable) in &self.joins {
            if self.schema(jtable).field_id(&c.column).is_some() {
                return Ok((jvar.clone(), jtable.clone(), c.column.clone()));
            }
        }
        bail!(
            "column `{}` not found in any table (searched {})",
            c.column,
            self.tables_in_scope()
        )
    }

    fn cursor_for(&self, table: &str) -> Result<(String, String)> {
        if table == self.main.1 {
            return Ok(self.main.clone());
        }
        for j in &self.joins {
            if table == j.1 {
                return Ok(j.clone());
            }
        }
        bail!("table `{table}` not in FROM clause")
    }

    fn expr(&self, e: &SqlExpr) -> Result<Expr> {
        Ok(match e {
            SqlExpr::Column(c) => {
                let (var, _, field) = self.resolve(c)?;
                Expr::field(&var, &field)
            }
            SqlExpr::Literal(v) => Expr::Const(v.clone()),
            // Prepared-statement placeholder → a late-bound IR parameter
            // slot. The slot is a plain Var the interpreter and compiler
            // resolve against `Program::params`, so one lowered program
            // serves every binding.
            SqlExpr::Param(n) => Expr::var(&param_slot(*n)),
            SqlExpr::Binary { op, lhs, rhs } => Expr::bin(
                binop(*op),
                self.expr(lhs)?,
                self.expr(rhs)?,
            ),
        })
    }

    fn expr_dtype(&self, e: &SqlExpr) -> Result<DataType> {
        Ok(match e {
            SqlExpr::Column(c) => {
                let (_, table, field) = self.resolve(c)?;
                let s = self.schema(&table);
                s.dtype(s.field_id(&field).unwrap())
            }
            SqlExpr::Literal(v) => v.data_type().unwrap_or(DataType::Int),
            // Bindings are untyped until execute; Int is the placeholder
            // dtype (comparisons coerce, and placeholders only appear in
            // predicates/arguments, never as result columns).
            SqlExpr::Param(_) => DataType::Int,
            SqlExpr::Binary { op, lhs, rhs } => {
                if matches!(
                    op,
                    SqlBinOp::Eq
                        | SqlBinOp::Ne
                        | SqlBinOp::Lt
                        | SqlBinOp::Le
                        | SqlBinOp::Gt
                        | SqlBinOp::Ge
                        | SqlBinOp::And
                        | SqlBinOp::Or
                ) {
                    DataType::Bool
                } else if self.expr_dtype(lhs)? == DataType::Float
                    || self.expr_dtype(rhs)? == DataType::Float
                {
                    DataType::Float
                } else {
                    DataType::Int
                }
            }
        })
    }

    /// Split a WHERE conjunction into (single equality usable as an index
    /// set filter on the main table, remaining residual predicate).
    ///
    /// When several conjuncts are liftable, the *most selective* one wins:
    /// equality selectivity is ≈ 1/NDV, so the highest-NDV column prunes
    /// the scan hardest. Unknown NDV scores 0 and ties keep written order,
    /// so without statistics this reduces to "first liftable conjunct".
    fn split_filter(&self, filter: &SqlExpr) -> (Option<(String, Expr)>, Option<SqlExpr>) {
        // Only top-level conjuncts are candidates.
        let mut conjuncts = Vec::new();
        collect_conjuncts(filter, &mut conjuncts);
        let mut lift: Option<(usize, String, Expr)> = None;
        let mut lift_score = 0u64;
        for (i, c) in conjuncts.iter().enumerate() {
            if let Some((field, value)) = self.liftable_eq(c) {
                let score = (self.ndv)(&self.main.1, &field).unwrap_or(0);
                if lift.is_none() || score > lift_score {
                    lift = Some((i, field, value));
                    lift_score = score;
                }
            }
        }
        let lift_idx = lift.as_ref().map(|(i, _, _)| *i);
        let index_filter = lift.map(|(_, f, v)| (f, v));
        let residual = conjuncts
            .into_iter()
            .enumerate()
            .filter(|(i, _)| Some(*i) != lift_idx)
            .map(|(_, c)| c)
            .reduce(|a, b| SqlExpr::Binary {
                op: SqlBinOp::And,
                lhs: Box::new(a),
                rhs: Box::new(b),
            });
        (index_filter, residual)
    }

    /// `column = literal` (either side) on the MAIN table → (field, const).
    fn liftable_eq(&self, c: &SqlExpr) -> Option<(String, Expr)> {
        let SqlExpr::Binary {
            op: SqlBinOp::Eq,
            lhs,
            rhs,
        } = c
        else {
            return None;
        };
        let (col, v) = match (lhs.as_ref(), rhs.as_ref()) {
            (SqlExpr::Column(col), SqlExpr::Literal(v))
            | (SqlExpr::Literal(v), SqlExpr::Column(col)) => (col, v),
            _ => return None,
        };
        let (var, table, field) = self.resolve(col).ok()?;
        (var == self.main.0 && table == self.main.1)
            .then(|| (field, Expr::Const(v.clone())))
    }

    /// Wrap `body` in the residual-predicate If, if any.
    fn guard(&self, residual: &Option<SqlExpr>, body: Vec<Stmt>) -> Result<Vec<Stmt>> {
        Ok(match residual {
            Some(pred) => vec![Stmt::If {
                cond: self.expr(pred)?,
                then: body,
                els: vec![],
            }],
            None => body,
        })
    }

    // ---- shapes ---------------------------------------------------------

    /// `SELECT g, AGG(x) FROM t [JOIN u ON ...] [WHERE ...] GROUP BY g` →
    /// counting loop (a Figure-1 join nest when a JOIN is present) +
    /// distinct emit loop (§IV). The group key and aggregate arguments may
    /// come from either joined table.
    fn lower_aggregate(&self, sel: &Select) -> Result<Program> {
        if sel.group_by.len() != 1 {
            bail!(
                "exactly one GROUP BY column is supported (got {})",
                sel.group_by.len()
            );
        }
        let (gvar, gtable, gfield) = self.resolve(&sel.group_by[0])?;
        let gdtype = {
            let s = self.schema(&gtable);
            s.dtype(s.field_id(&gfield).unwrap())
        };

        let (index_filter, residual) = match &sel.filter {
            Some(f) => self.split_filter(f),
            None => (None, None),
        };

        let (ivar, itable) = self.main.clone();
        let mut program = Program::new(&format!("groupby_{}", gtable));
        program = program.with_relation(&itable, self.schema(&itable).clone());
        for (_, jtable) in &self.joins {
            program = program.with_relation(jtable, self.schema(jtable).clone());
        }

        // One accumulator array per aggregate item + the result schema.
        let mut result_fields: Vec<(String, DataType)> = Vec::new();
        let mut accum_stmts: Vec<Stmt> = Vec::new();
        let mut union_tuple: Vec<Expr> = Vec::new();
        let group_key = Expr::field(&gvar, &gfield);

        for (idx, item) in sel.items.iter().enumerate() {
            match item {
                SelectItem::Wildcard => bail!("SELECT * not allowed with GROUP BY"),
                SelectItem::Expr { expr, alias } => {
                    // Must be the group key.
                    let lowered = self.expr(expr)?;
                    if lowered != group_key {
                        bail!("non-aggregate select item must be the GROUP BY column");
                    }
                    result_fields.push((
                        alias.clone().unwrap_or_else(|| gfield.clone()),
                        gdtype,
                    ));
                    union_tuple.push(group_key.clone());
                }
                SelectItem::Agg { agg, expr, alias } => {
                    let array = format!("agg{idx}");
                    let (decl, accum, read_back, dtype) =
                        self.lower_agg(*agg, expr, &array, &group_key)?;
                    program = program.with_array(&array, decl);
                    if let Some((extra_name, extra_decl)) = accum.1 {
                        program = program.with_array(&extra_name, extra_decl);
                    }
                    accum_stmts.extend(accum.0);
                    result_fields.push((
                        alias.clone().unwrap_or_else(|| format!("{agg:?}").to_lowercase()),
                        dtype,
                    ));
                    union_tuple.push(read_back);
                }
            }
        }

        let result_schema = Schema::new(
            result_fields
                .iter()
                .map(|(n, t)| (n.as_str(), *t))
                .collect(),
        );
        program = program.with_result("R", result_schema);

        // Loop 1: accumulate — a plain scan of the FROM table, or the
        // join nest (Figure 1, generalized to N levels) when JOINs are
        // present.
        let outer_ix = match &index_filter {
            Some((f, v)) => IndexSet::filtered(&itable, f, v.clone()),
            None => IndexSet::all(&itable),
        };
        let accum_body = self.guard(&residual, accum_stmts)?;
        let loop1 = if self.joins.is_empty() {
            Loop::forelem(&ivar, outer_ix, accum_body)
        } else {
            let edges = self.join_edges(sel)?;
            self.join_nest(&ivar, outer_ix, &edges, accum_body)
        };
        // Loop 2: iterate distinct group keys of the owning table, emit
        // result rows (the emit cursor reuses the group key's cursor var).
        // ORDER BY/LIMIT annotate this loop: the paper's URL-count query
        // ends in a `topk`-bounded emission over the distinct domain.
        let ix2 = IndexSet::distinct_of(&gtable, &gfield);
        let body2 = vec![Stmt::result_union("R", union_tuple)];
        let mut loop2 = Loop::forelem(&gvar, ix2, body2);
        if let Some(e) = emit_order(sel, &result_fields)? {
            loop2 = loop2.with_emit(e);
        }

        program.body = vec![Stmt::Loop(loop1), Stmt::Loop(loop2)];
        program = register_params(sel, program);
        crate::ir::validate(&program)?;
        Ok(program)
    }

    /// Orient each JOIN's ON clause into a [`JoinEdge`], validating that
    /// the clauses form a connected, acyclic join graph: every ON must
    /// relate the clause's *new* table to exactly one table already in
    /// scope (the FROM table or an earlier join). An ON that never
    /// mentions the new table leaves it disconnected; one that mentions
    /// only the new table is a cycle-forming self-edge; one that reaches
    /// forward joins against a table not yet in scope. All three are
    /// rejected with a message naming the offending table.
    fn join_edges(&self, sel: &Select) -> Result<Vec<JoinEdge>> {
        let mut edges: Vec<JoinEdge> = Vec::new();
        for (k, clause) in sel.joins.iter().enumerate() {
            let (var, table) = self.joins[k].clone();
            let placed: Vec<&str> = std::iter::once(self.main.0.as_str())
                .chain(self.joins[..k].iter().map(|(v, _)| v.as_str()))
                .collect();
            let (lvar, ltable, lfield) = self.resolve(&clause.left)?;
            let (rvar, rtable, rfield) = self.resolve(&clause.right)?;
            let (field, parent_var, parent_table, parent_field) = if lvar == var && rvar == var
            {
                bail!(
                    "JOIN `{table}` ON clause references only `{table}`: a self-edge makes \
                     the join graph cyclic (each JOIN must link its new table to one \
                     already-joined table)"
                );
            } else if lvar == var {
                (lfield, rvar, rtable, rfield)
            } else if rvar == var {
                (rfield, lvar, ltable, lfield)
            } else {
                bail!(
                    "JOIN `{table}` ON clause does not reference `{table}`: the join graph \
                     would leave `{table}` disconnected (each JOIN must link its new table \
                     to one already-joined table)"
                );
            };
            if !placed.contains(&parent_var.as_str()) {
                let scope = std::iter::once(self.main.1.as_str())
                    .chain(self.joins[..k].iter().map(|(_, t)| t.as_str()))
                    .collect::<Vec<_>>()
                    .join(", ");
                bail!(
                    "JOIN `{table}` ON clause references `{parent_table}` before it is \
                     joined (tables in scope so far: {scope})"
                );
            }
            edges.push(JoinEdge {
                var,
                table,
                field,
                parent_var,
                parent_field,
            });
        }
        Ok(edges)
    }

    /// Fold the join chain into the nested-forelem shape: the FROM table
    /// is the outer loop and each JOIN becomes one more filtered level
    /// keyed on its parent's cursor, in written order (innermost = last
    /// JOIN). The optimizer reorders this nest when statistics justify it
    /// (`opt.join_order` for 3+ tables, `opt.join_build_side` for two).
    fn join_nest(
        &self,
        ivar: &str,
        outer_ix: IndexSet,
        edges: &[JoinEdge],
        innermost: Vec<Stmt>,
    ) -> Loop {
        let mut body = innermost;
        for e in edges.iter().rev() {
            let ix = IndexSet::filtered(
                &e.table,
                &e.field,
                Expr::field(&e.parent_var, &e.parent_field),
            );
            body = vec![Stmt::Loop(Loop::forelem(&e.var, ix, body))];
        }
        Loop::forelem(ivar, outer_ix, body)
    }

    /// Build the accumulation statement(s) + read-back expression for one
    /// aggregate item.
    #[allow(clippy::type_complexity)]
    fn lower_agg(
        &self,
        agg: Aggregate,
        arg: &Option<SqlExpr>,
        array: &str,
        group_key: &Expr,
    ) -> Result<(
        ArrayDecl,
        (Vec<Stmt>, Option<(String, ArrayDecl)>),
        Expr,
        DataType,
    )> {
        use crate::ir::AccumOp;
        let read = Expr::array(array, vec![group_key.clone()]);
        match agg {
            Aggregate::Count => Ok((
                ArrayDecl::counter(),
                (
                    vec![Stmt::increment(array, vec![group_key.clone()])],
                    None,
                ),
                read,
                DataType::Int,
            )),
            Aggregate::Sum | Aggregate::Min | Aggregate::Max => {
                let arg = arg
                    .as_ref()
                    .with_context(|| format!("{agg:?} requires an argument"))?;
                let dtype = self.expr_dtype(arg)?;
                let op = match agg {
                    Aggregate::Sum => AccumOp::Add,
                    Aggregate::Min => AccumOp::Min,
                    Aggregate::Max => AccumOp::Max,
                    _ => unreachable!(),
                };
                Ok((
                    ArrayDecl::accumulator(dtype),
                    (
                        vec![Stmt::accum(
                            array,
                            vec![group_key.clone()],
                            op,
                            self.expr(arg)?,
                        )],
                        None,
                    ),
                    read,
                    dtype,
                ))
            }
            Aggregate::Avg => {
                let arg = arg.as_ref().context("AVG requires an argument")?;
                let narray = format!("{array}_n");
                let stmts = vec![
                    Stmt::accum(
                        array,
                        vec![group_key.clone()],
                        AccumOp::Add,
                        self.expr(arg)?,
                    ),
                    Stmt::increment(&narray, vec![group_key.clone()]),
                ];
                let read = Expr::bin(
                    BinOp::Div,
                    Expr::array(array, vec![group_key.clone()]),
                    Expr::array(&narray, vec![group_key.clone()]),
                );
                Ok((
                    ArrayDecl::accumulator(DataType::Float),
                    (stmts, Some((narray, ArrayDecl::counter()))),
                    read,
                    DataType::Float,
                ))
            }
        }
    }

    /// Equi-join → nested forelem with filtered inner index sets
    /// (Figure 1, one level per JOIN clause).
    fn lower_join(&self, sel: &Select) -> Result<Program> {
        let (ivar, itable) = self.main.clone();
        let edges = self.join_edges(sel)?;

        let (index_filter, residual) = match &sel.filter {
            Some(f) => self.split_filter(f),
            None => (None, None),
        };

        // Result tuple from the select list.
        let mut fields = Vec::new();
        let mut tuple = Vec::new();
        for item in &sel.items {
            match item {
                SelectItem::Wildcard => {
                    let cursors = std::iter::once((&ivar, &itable))
                        .chain(self.joins.iter().map(|(v, t)| (v, t)));
                    for (var, table) in cursors {
                        for f in self.schema(table).fields() {
                            fields.push((format!("{table}.{}", f.name), f.dtype));
                            tuple.push(Expr::field(var, &f.name));
                        }
                    }
                }
                SelectItem::Expr { expr, alias } => {
                    let name = alias.clone().unwrap_or_else(|| display_name(expr));
                    fields.push((name, self.expr_dtype(expr)?));
                    tuple.push(self.expr(expr)?);
                }
                SelectItem::Agg { .. } => unreachable!("handled by lower_aggregate"),
            }
        }
        let result_schema =
            Schema::new(fields.iter().map(|(n, t)| (n.as_str(), *t)).collect());

        let innermost = self.guard(&residual, vec![Stmt::result_union("R", tuple)])?;
        let outer_ix = match &index_filter {
            Some((f, v)) => IndexSet::filtered(&itable, f, v.clone()),
            None => IndexSet::all(&itable),
        };

        let name = std::iter::once(itable.as_str())
            .chain(self.joins.iter().map(|(_, t)| t.as_str()))
            .collect::<Vec<_>>()
            .join("_");
        let mut program = Program::new(&format!("join_{name}"))
            .with_relation(&itable, self.schema(&itable).clone())
            .with_result("R", result_schema);
        for (_, jtable) in &self.joins {
            program = program.with_relation(jtable, self.schema(jtable).clone());
        }
        // ORDER BY/LIMIT annotate the outer loop: the emission bound
        // covers the whole nest's appended rows.
        let mut nest = self.join_nest(&ivar, outer_ix, &edges, innermost);
        if let Some(e) = emit_order(sel, &fields)? {
            nest = nest.with_emit(e);
        }
        program.body = vec![Stmt::Loop(nest)];
        program = register_params(sel, program);
        crate::ir::validate(&program)?;
        Ok(program)
    }

    /// Plain select-project (§III-B grades query).
    fn lower_select_project(&self, sel: &Select) -> Result<Program> {
        let (ivar, itable) = self.main.clone();
        let (index_filter, residual) = match &sel.filter {
            Some(f) => self.split_filter(f),
            None => (None, None),
        };

        let mut fields = Vec::new();
        let mut tuple = Vec::new();
        for item in &sel.items {
            match item {
                SelectItem::Wildcard => {
                    for f in self.schema(&itable).fields() {
                        fields.push((f.name.clone(), f.dtype));
                        tuple.push(Expr::field(&ivar, &f.name));
                    }
                }
                SelectItem::Expr { expr, alias } => {
                    let name = alias.clone().unwrap_or_else(|| display_name(expr));
                    fields.push((name, self.expr_dtype(expr)?));
                    tuple.push(self.expr(expr)?);
                }
                SelectItem::Agg { .. } => unreachable!("handled by lower_aggregate"),
            }
        }
        let result_schema =
            Schema::new(fields.iter().map(|(n, t)| (n.as_str(), *t)).collect());

        let ix = match &index_filter {
            Some((f, v)) => IndexSet::filtered(&itable, f, v.clone()),
            None => IndexSet::all(&itable),
        };
        let body = self.guard(&residual, vec![Stmt::result_union("R", tuple)])?;

        let mut program = Program::new(&format!("select_{itable}"))
            .with_relation(&itable, self.schema(&itable).clone())
            .with_result("R", result_schema);
        let mut scan = Loop::forelem(&ivar, ix, body);
        if let Some(e) = emit_order(sel, &fields)? {
            scan = scan.with_emit(e);
        }
        program.body = vec![Stmt::Loop(scan)];
        program = register_params(sel, program);
        crate::ir::validate(&program)?;
        Ok(program)
    }
}

/// Register a default-initialized late-bound slot for every placeholder
/// the statement mentions, so validation sees the `$n` vars in scope and
/// callers re-bind them via [`Program::with_param`] at execute time.
fn register_params(sel: &Select, mut program: Program) -> Program {
    for n in param_indices(sel) {
        program = program.with_param(&param_slot(n), crate::ir::value::Value::Int(0));
    }
    program
}

/// Comma-separated catalog table names, for error messages.
fn known_tables(catalog: &Catalog) -> String {
    catalog.keys().cloned().collect::<Vec<_>>().join(", ")
}

fn collect_conjuncts(e: &SqlExpr, out: &mut Vec<SqlExpr>) {
    match e {
        SqlExpr::Binary {
            op: SqlBinOp::And,
            lhs,
            rhs,
        } => {
            collect_conjuncts(lhs, out);
            collect_conjuncts(rhs, out);
        }
        other => out.push(other.clone()),
    }
}

fn display_name(e: &SqlExpr) -> String {
    match e {
        SqlExpr::Column(c) => c.column.clone(),
        SqlExpr::Literal(v) => v.to_string(),
        SqlExpr::Param(n) => param_slot(*n),
        SqlExpr::Binary { .. } => "expr".to_string(),
    }
}

/// IR name of the late-bound slot for SQL parameter `n` (1-based).
pub fn param_slot(n: usize) -> String {
    format!("${n}")
}

/// Collect every parameter index mentioned anywhere in the statement, in
/// ascending order.
pub fn param_indices(sel: &Select) -> Vec<usize> {
    fn walk(e: &SqlExpr, out: &mut Vec<usize>) {
        match e {
            SqlExpr::Param(n) => out.push(*n),
            SqlExpr::Binary { lhs, rhs, .. } => {
                walk(lhs, out);
                walk(rhs, out);
            }
            SqlExpr::Column(_) | SqlExpr::Literal(_) => {}
        }
    }
    let mut out = Vec::new();
    for item in &sel.items {
        match item {
            SelectItem::Expr { expr, .. } => walk(expr, &mut out),
            SelectItem::Agg { expr: Some(e), .. } => walk(e, &mut out),
            SelectItem::Agg { expr: None, .. } | SelectItem::Wildcard => {}
        }
    }
    if let Some(f) = &sel.filter {
        walk(f, &mut out);
    }
    out.sort_unstable();
    out.dedup();
    out
}

fn binop(op: SqlBinOp) -> BinOp {
    match op {
        SqlBinOp::Add => BinOp::Add,
        SqlBinOp::Sub => BinOp::Sub,
        SqlBinOp::Mul => BinOp::Mul,
        SqlBinOp::Div => BinOp::Div,
        SqlBinOp::Mod => BinOp::Mod,
        SqlBinOp::Eq => BinOp::Eq,
        SqlBinOp::Ne => BinOp::Ne,
        SqlBinOp::Lt => BinOp::Lt,
        SqlBinOp::Le => BinOp::Le,
        SqlBinOp::Gt => BinOp::Gt,
        SqlBinOp::Ge => BinOp::Ge,
        SqlBinOp::And => BinOp::And,
        SqlBinOp::Or => BinOp::Or,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::pretty;

    fn catalog() -> Catalog {
        let mut c = Catalog::new();
        c.insert("access".into(), Schema::new(vec![("url", DataType::Str)]));
        c.insert(
            "links".into(),
            Schema::new(vec![("source", DataType::Str), ("target", DataType::Str)]),
        );
        c.insert(
            "Grades".into(),
            Schema::new(vec![
                ("studentID", DataType::Int),
                ("grade", DataType::Float),
                ("weight", DataType::Float),
            ]),
        );
        c.insert(
            "A".into(),
            Schema::new(vec![("b_id", DataType::Int), ("field", DataType::Str)]),
        );
        c.insert(
            "B".into(),
            Schema::new(vec![("id", DataType::Int), ("field", DataType::Str)]),
        );
        // Star/snowflake fixtures: fact F with two dimension keys, dims
        // D and E, and G one hop off D (the snowflake arm).
        c.insert(
            "F".into(),
            Schema::new(vec![
                ("d_id", DataType::Int),
                ("e_id", DataType::Int),
                ("v", DataType::Int),
            ]),
        );
        c.insert(
            "D".into(),
            Schema::new(vec![
                ("id", DataType::Int),
                ("g_id", DataType::Int),
                ("tag", DataType::Str),
            ]),
        );
        c.insert(
            "E".into(),
            Schema::new(vec![("id", DataType::Int), ("name", DataType::Str)]),
        );
        c.insert(
            "G".into(),
            Schema::new(vec![("id", DataType::Int), ("name", DataType::Str)]),
        );
        c
    }

    #[test]
    fn url_count_lowers_to_the_papers_ir() {
        let p =
            compile_sql("SELECT url, COUNT(url) FROM access GROUP BY url", &catalog()).unwrap();
        let text = pretty::program(&p);
        // §IV: counting loop over pAccess + distinct loop.
        assert!(text.contains("forelem (i; i ∈ paccess)"), "{text}");
        assert!(text.contains("agg1[i.url]++;"), "{text}");
        assert!(text.contains("i ∈ paccess.distinct(url)"), "{text}");
        assert!(text.contains("R = R ∪ (i.url, agg1[i.url]);"), "{text}");
    }

    #[test]
    fn join_lowers_to_figure1_spec() {
        let p = compile_sql(
            "SELECT A.field, B.field FROM A JOIN B ON A.b_id = B.id",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        assert!(text.contains("forelem (i; i ∈ pA)"), "{text}");
        assert!(text.contains("forelem (j; j ∈ pB.id[i.b_id])"), "{text}");
        assert!(text.contains("R = R ∪ (i.field, j.field);"), "{text}");
    }

    #[test]
    fn grades_query_uses_index_filter() {
        let p = compile_sql(
            "SELECT grade, weight FROM Grades WHERE studentID = 25",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        assert!(text.contains("i ∈ pGrades.studentID[25]"), "{text}");
    }

    #[test]
    fn residual_predicates_become_guards() {
        let p = compile_sql(
            "SELECT grade FROM Grades WHERE studentID = 25 AND grade > 5.5",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        assert!(text.contains("pGrades.studentID[25]"), "{text}");
        assert!(text.contains("if ((i.grade > 5.5))"), "{text}");
    }

    #[test]
    fn sum_and_avg_aggregates() {
        let p = compile_sql(
            "SELECT studentID, SUM(grade) AS total, AVG(weight) FROM Grades GROUP BY studentID",
            &catalog(),
        )
        .unwrap();
        assert!(p.arrays.len() >= 3); // sum + avg-sum + avg-count
        let schema = &p.results["R"];
        assert_eq!(schema.field(1).name, "total");
        assert_eq!(schema.dtype(1), DataType::Float);
    }

    #[test]
    fn reverse_weblink_query_lowers() {
        let p = compile_sql(
            "SELECT target, COUNT(target) FROM links GROUP BY target",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        assert!(text.contains("forelem (i; i ∈ plinks)"), "{text}");
        assert!(text.contains("agg1[i.target]++;"), "{text}");
    }

    #[test]
    fn join_aggregate_lowers_to_figure1_nest_plus_emit() {
        let p = compile_sql(
            "SELECT A.field, COUNT(A.field) FROM A JOIN B ON A.b_id = B.id GROUP BY A.field",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        // Figure-1 nest accumulating per group key...
        assert!(text.contains("forelem (i; i ∈ pA)"), "{text}");
        assert!(text.contains("forelem (j; j ∈ pB.id[i.b_id])"), "{text}");
        assert!(text.contains("agg1[i.field]++;"), "{text}");
        // ...then the distinct emit loop over the owning table.
        assert!(text.contains("i ∈ pA.distinct(field)"), "{text}");
        assert!(text.contains("R = R ∪ (i.field, agg1[i.field]);"), "{text}");
    }

    #[test]
    fn join_aggregate_group_key_may_come_from_join_table() {
        let p = compile_sql(
            "SELECT B.field, SUM(A.b_id) FROM A JOIN B ON A.b_id = B.id GROUP BY B.field",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        assert!(text.contains("forelem (j; j ∈ pB.id[i.b_id])"), "{text}");
        assert!(text.contains("agg1[j.field] += i.b_id;"), "{text}");
        // Emit loop binds the join table's cursor var.
        assert!(text.contains("forelem (j; j ∈ pB.distinct(field))"), "{text}");
    }

    #[test]
    fn three_table_star_lowers_to_nested_forelem() {
        let p = compile_sql(
            "SELECT F.v, D.tag, E.name FROM F JOIN D ON F.d_id = D.id JOIN E ON F.e_id = E.id",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        // Written order: fact outer, each dimension one filtered level
        // deeper, both keyed on the fact cursor (star shape).
        assert!(text.contains("forelem (i; i ∈ pF)"), "{text}");
        assert!(text.contains("forelem (j; j ∈ pD.id[i.d_id])"), "{text}");
        assert!(text.contains("forelem (j2; j2 ∈ pE.id[i.e_id])"), "{text}");
        assert!(text.contains("R = R ∪ (i.v, j.tag, j2.name);"), "{text}");
        assert_eq!(p.relations.len(), 3);
    }

    #[test]
    fn snowflake_aggregate_keys_inner_level_on_join_cursor() {
        let p = compile_sql(
            "SELECT G.name, COUNT(G.name) FROM F JOIN D ON F.d_id = D.id \
             JOIN G ON D.g_id = G.id GROUP BY G.name",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        // The snowflake arm keys on the *join* cursor, not the FROM cursor.
        assert!(text.contains("forelem (j; j ∈ pD.id[i.d_id])"), "{text}");
        assert!(text.contains("forelem (j2; j2 ∈ pG.id[j.g_id])"), "{text}");
        assert!(text.contains("agg1[j2.name]++;"), "{text}");
        // Emit loop binds the owning table's cursor.
        assert!(text.contains("forelem (j2; j2 ∈ pG.distinct(name))"), "{text}");
    }

    #[test]
    fn four_table_chain_lowers_with_written_order_cursors() {
        let p = compile_sql(
            "SELECT F.v FROM F JOIN D ON F.d_id = D.id JOIN E ON F.e_id = E.id \
             JOIN G ON D.g_id = G.id",
            &catalog(),
        )
        .unwrap();
        let text = pretty::program(&p);
        assert!(text.contains("forelem (j; j ∈ pD.id[i.d_id])"), "{text}");
        assert!(text.contains("forelem (j2; j2 ∈ pE.id[i.e_id])"), "{text}");
        assert!(text.contains("forelem (j3; j3 ∈ pG.id[j.g_id])"), "{text}");
    }

    #[test]
    fn disconnected_and_cyclic_join_graphs_are_rejected() {
        let c = catalog();
        // ON never mentions the new table → it would stay disconnected.
        let err = compile_sql(
            "SELECT F.v FROM F JOIN D ON F.d_id = D.id JOIN E ON F.d_id = D.id",
            &c,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("leave `E` disconnected"), "{err}");
        // ON mentions only the new table → a cycle-forming self-edge.
        let err = compile_sql(
            "SELECT F.v FROM F JOIN D ON F.d_id = D.id JOIN E ON E.id = E.id",
            &c,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("self-edge makes the join graph cyclic"), "{err}");
        // ON reaches forward to a table joined later.
        let err = compile_sql(
            "SELECT F.v FROM F JOIN D ON D.g_id = G.id JOIN G ON F.d_id = G.id",
            &c,
        )
        .unwrap_err()
        .to_string();
        assert!(
            err.contains("references `G` before it is joined"),
            "{err}"
        );
        assert!(err.contains("tables in scope so far: F"), "{err}");
        // Repeated table → self-join, unsupported.
        let err = compile_sql("SELECT F.v FROM F JOIN F ON F.d_id = F.e_id", &c)
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate table `F`"), "{err}");
    }

    #[test]
    fn split_filter_lifts_most_selective_equality_by_ndv() {
        let c = catalog();
        let sel = crate::sql::parser::parse(
            "SELECT grade FROM Grades WHERE weight = 2.0 AND studentID = 25",
        )
        .unwrap();
        // Without statistics, written order decides: the first liftable
        // equality (`weight`) becomes the index-set filter.
        let text = pretty::program(&lower(&sel, &c).unwrap());
        assert!(text.contains("i ∈ pGrades.weight["), "{text}");
        assert!(text.contains("i.studentID"), "{text}");
        // With NDV statistics saying studentID is far more selective
        // (1000 distinct students vs 2 distinct weights), the lift flips:
        // studentID filters the index set, weight stays residual.
        let ndv = |table: &str, col: &str| -> Option<u64> {
            match (table, col) {
                ("Grades", "studentID") => Some(1000),
                ("Grades", "weight") => Some(2),
                _ => None,
            }
        };
        let text = pretty::program(&lower_with_stats(&sel, &c, &ndv).unwrap());
        assert!(text.contains("i ∈ pGrades.studentID[25]"), "{text}");
        assert!(text.contains("i.weight"), "{text}");
    }

    #[test]
    fn errors_are_descriptive() {
        let c = catalog();
        assert!(compile_sql("SELECT x FROM nope", &c)
            .unwrap_err()
            .to_string()
            .contains("unknown table"));
        assert!(compile_sql("SELECT nope FROM access", &c)
            .unwrap_err()
            .to_string()
            .contains("not found"));
        assert!(compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url, url",
            &c
        )
        .is_err());
    }

    #[test]
    fn unknown_join_tables_and_columns_name_candidates() {
        let c = catalog();
        // Unknown JOIN table: the message lists the catalog's tables.
        let err = compile_sql("SELECT url FROM access JOIN nope ON access.url = nope.x", &c)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown join table `nope`"), "{err}");
        assert!(err.contains("known tables:"), "{err}");
        assert!(err.contains("access") && err.contains("links"), "{err}");
        // Unknown column in a join: the message names the searched tables.
        let err = compile_sql("SELECT nope FROM A JOIN B ON A.b_id = B.id", &c)
            .unwrap_err()
            .to_string();
        assert!(err.contains("searched A, B"), "{err}");
        // Unknown qualified column: the message lists the table's columns.
        let err = compile_sql("SELECT A.nope FROM A JOIN B ON A.b_id = B.id", &c)
            .unwrap_err()
            .to_string();
        assert!(err.contains("columns: b_id, field"), "{err}");
        // Unknown alias: the message names the tables in scope.
        let err = compile_sql("SELECT Z.field FROM A JOIN B ON A.b_id = B.id", &c)
            .unwrap_err()
            .to_string();
        assert!(err.contains("tables in scope: A, B"), "{err}");
    }

    #[test]
    fn order_by_limit_lowers_to_topk_annotated_emit_loop() {
        use crate::ir::EmitOrder;
        let c = catalog();
        // The paper's flagship form: group-by ending in a bounded emit.
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url ORDER BY count DESC LIMIT 5",
            &c,
        )
        .unwrap();
        let Stmt::Loop(emit) = &p.body[1] else {
            panic!("expected the distinct emit loop")
        };
        assert_eq!(emit.emit, Some(EmitOrder::top_k(1, true, 5)));
        let text = pretty::program(&p);
        assert!(
            text.contains("i ∈ paccess.distinct(url)) topk(#1 desc, k=5)"),
            "{text}"
        );

        // Alias resolution: ORDER BY the aliased aggregate column.
        let p = compile_sql(
            "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n ASC",
            &c,
        )
        .unwrap();
        let Stmt::Loop(emit) = &p.body[1] else {
            panic!("expected the distinct emit loop")
        };
        assert_eq!(emit.emit, Some(EmitOrder::ordered(1, false)));

        // Select-project: the single scan loop carries the annotation.
        let p = compile_sql("SELECT url FROM access LIMIT 10", &c).unwrap();
        let Stmt::Loop(scan) = &p.body[0] else {
            panic!("expected scan loop")
        };
        assert_eq!(scan.emit, Some(EmitOrder::first_k(10)));

        // Join: the outer loop of the nest carries the annotation.
        let p = compile_sql(
            "SELECT A.field, B.field FROM A JOIN B ON A.b_id = B.id ORDER BY field DESC LIMIT 2",
            &c,
        )
        .unwrap();
        let Stmt::Loop(outer) = &p.body[0] else {
            panic!("expected join nest")
        };
        assert_eq!(outer.emit, Some(EmitOrder::top_k(0, true, 2)));
        let [Stmt::Loop(inner)] = outer.body.as_slice() else {
            panic!("outer body must be the inner loop")
        };
        assert!(inner.emit.is_none());
    }

    #[test]
    fn order_by_unknown_column_names_result_columns() {
        let c = catalog();
        let err = compile_sql(
            "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY nope",
            &c,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("ORDER BY unknown column `nope`"), "{err}");
        assert!(err.contains("result columns: url, n"), "{err}");
    }

    #[test]
    fn placeholders_lower_to_late_bound_param_slots() {
        let c = catalog();
        let p = compile_sql("SELECT grade FROM Grades WHERE studentID = ?", &c).unwrap();
        // The placeholder registers as a program parameter...
        assert!(p.params.contains_key("$1"), "{:?}", p.params);
        let text = pretty::program(&p);
        // ...and stays a residual guard, never an index-set lift: one
        // lowered program must serve every binding.
        assert!(text.contains("i ∈ pGrades)"), "{text}");
        assert!(text.contains("$1"), "{text}");

        // Explicit `$n` indices and positional `?` interleave; every
        // mentioned index registers exactly once.
        let p = compile_sql(
            "SELECT grade FROM Grades WHERE studentID = $2 AND grade > ? AND weight < $2",
            &c,
        )
        .unwrap();
        assert_eq!(
            p.params.keys().cloned().collect::<Vec<_>>(),
            vec!["$1".to_string(), "$2".to_string()]
        );
    }

    #[test]
    fn wildcard_select_expands_schema() {
        let p = compile_sql("SELECT * FROM Grades", &catalog()).unwrap();
        assert_eq!(p.results["R"].len(), 3);
    }

    #[test]
    fn join_nest_order_is_the_optimizer_contract() {
        // `opt::optimize` swaps the Figure-1 nest by matching exactly
        // this shape: FROM table outer, JOIN table inner, inner index
        // set filtered on a plain field of the outer cursor. Pin it.
        use crate::ir::Domain;
        for q in [
            "SELECT A.field FROM A JOIN B ON A.b_id = B.id",
            "SELECT A.field, COUNT(A.field) FROM A JOIN B ON A.b_id = B.id GROUP BY A.field",
        ] {
            let p = compile_sql(q, &catalog()).unwrap();
            let Stmt::Loop(outer) = &p.body[0] else {
                panic!("`{q}`: first statement must be the join nest")
            };
            let Domain::IndexSet(ox) = &outer.domain else {
                panic!("`{q}`: outer domain must be an index set")
            };
            assert_eq!(ox.relation, "A", "`{q}`: FROM table is the outer loop");
            assert!(ox.field_filter.is_none());
            let [Stmt::Loop(inner)] = outer.body.as_slice() else {
                panic!("`{q}`: outer body must be exactly the inner loop")
            };
            let Domain::IndexSet(iix) = &inner.domain else {
                panic!("`{q}`: inner domain must be an index set")
            };
            assert_eq!(iix.relation, "B", "`{q}`: JOIN table is the inner loop");
            let Some((field, key)) = &iix.field_filter else {
                panic!("`{q}`: inner loop must be key-filtered")
            };
            assert_eq!(field, "id");
            assert_eq!(
                key,
                &Expr::field(&outer.var, "b_id"),
                "`{q}`: inner filter keys on a plain outer-cursor field"
            );
        }
    }
}
