//! Retail star-schema generator for the N-way join workload suite — a
//! BigBench-flavored miniature: one `sales` fact table with zipfian
//! foreign keys into `customers` / `products` / `stores` dimensions, and
//! a `categories` dimension hanging off `products` (the snowflake hop).
//!
//! Coverage contract: the first `|dim|` fact rows walk each dimension's
//! id space in order, so with `sales ≥ |dim|` every dimension row matches
//! at least one sale — grouped joins then emit no zero-count groups and
//! results read like plain SQL.
//!
//! The `product_domain_factor` knob breaks that contract on purpose for
//! `product_id` only: with factor `k > 1` the fact draws product ids from
//! a domain `k×` wider than the dimension, so only ~`1/k` of sales match
//! any product. That makes `products` a *selective* dimension — the
//! Selinger DP (`opt.join_order`) should pull it to the front of the
//! chain, which is exactly what `benches/star_join.rs` measures.

use crate::ir::{DataType, Multiset, Schema, Value};
use crate::storage::StorageCatalog;
use crate::util::{Rng, Zipf};

use anyhow::Result;

/// Parameters for the retail star schema.
#[derive(Debug, Clone)]
pub struct RetailSpec {
    /// Fact rows in `sales`.
    pub sales: usize,
    /// Rows in the `customers` dimension.
    pub customers: usize,
    /// Rows in the `products` dimension.
    pub products: usize,
    /// Rows in the `stores` dimension.
    pub stores: usize,
    /// Rows in the `categories` dimension (snowflake hop off `products`).
    pub categories: usize,
    /// Fact `product_id` domain width as a multiple of `products`:
    /// 1 = full referential integrity, `k > 1` leaves only ~1/k of the
    /// fact matching a product (selective-dimension shape).
    pub product_domain_factor: usize,
    /// Zipf exponent for the fact's foreign-key popularity.
    pub skew: f64,
    pub seed: u64,
}

impl Default for RetailSpec {
    fn default() -> Self {
        RetailSpec {
            sales: 5_000,
            customers: 50,
            products: 40,
            stores: 10,
            categories: 8,
            product_domain_factor: 1,
            skew: 1.1,
            seed: 7,
        }
    }
}

const SEGMENTS: [&str; 3] = ["consumer", "corporate", "home_office"];
const STATES: [&str; 5] = ["NH", "CA", "TX", "WA", "VT"];

/// `customers(id, segment, region)` — `id` is a dense primary key.
pub fn customers(spec: &RetailSpec) -> Multiset {
    let schema = Schema::new(vec![
        ("id", DataType::Int),
        ("segment", DataType::Str),
        ("region", DataType::Str),
    ]);
    let mut m = Multiset::new(schema);
    for i in 0..spec.customers {
        m.push(vec![
            Value::Int(i as i64),
            Value::str(SEGMENTS[i % SEGMENTS.len()]),
            Value::str(format!("region{}", i % 7)),
        ]);
    }
    m
}

/// `products(id, cat_id, price)` — every category id is covered when
/// `products ≥ categories`.
pub fn products(spec: &RetailSpec) -> Multiset {
    let mut rng = Rng::new(spec.seed ^ 0x70726f64);
    let schema = Schema::new(vec![
        ("id", DataType::Int),
        ("cat_id", DataType::Int),
        ("price", DataType::Int),
    ]);
    let mut m = Multiset::new(schema);
    for i in 0..spec.products {
        m.push(vec![
            Value::Int(i as i64),
            Value::Int((i % spec.categories.max(1)) as i64),
            Value::Int(rng.range(100, 10_000)),
        ]);
    }
    m
}

/// `stores(id, city, state)`.
pub fn stores(spec: &RetailSpec) -> Multiset {
    let schema = Schema::new(vec![
        ("id", DataType::Int),
        ("city", DataType::Str),
        ("state", DataType::Str),
    ]);
    let mut m = Multiset::new(schema);
    for i in 0..spec.stores {
        m.push(vec![
            Value::Int(i as i64),
            Value::str(format!("city{i}")),
            Value::str(STATES[i % STATES.len()]),
        ]);
    }
    m
}

/// `categories(id, name)` — names are distinct per id, so grouping by
/// `name` has exactly `categories` groups.
pub fn categories(spec: &RetailSpec) -> Multiset {
    let schema = Schema::new(vec![("id", DataType::Int), ("name", DataType::Str)]);
    let mut m = Multiset::new(schema);
    for i in 0..spec.categories {
        m.push(vec![Value::Int(i as i64), Value::str(format!("cat{i}"))]);
    }
    m
}

/// `sales(customer_id, product_id, store_id, quantity, revenue)` — the
/// fact table. All measures are integers so grouped sums fold exactly on
/// every tier and under every scheduling policy.
pub fn sales(spec: &RetailSpec) -> Multiset {
    let mut rng = Rng::new(spec.seed);
    let zc = Zipf::new(spec.customers.max(1), spec.skew);
    let zs = Zipf::new(spec.stores.max(1), spec.skew);
    let product_domain = spec.products.max(1) * spec.product_domain_factor.max(1);
    let zp = Zipf::new(product_domain, spec.skew);
    let schema = Schema::new(vec![
        ("customer_id", DataType::Int),
        ("product_id", DataType::Int),
        ("store_id", DataType::Int),
        ("quantity", DataType::Int),
        ("revenue", DataType::Int),
    ]);
    let mut m = Multiset::new(schema);
    for i in 0..spec.sales {
        // Coverage walk first (see module docs), zipf tail after.
        let customer = if i < spec.customers {
            i as i64
        } else {
            zc.sample(&mut rng) as i64
        };
        let store = if i < spec.stores {
            i as i64
        } else {
            zs.sample(&mut rng) as i64
        };
        let product = if spec.product_domain_factor <= 1 && i < spec.products {
            i as i64
        } else {
            zp.sample(&mut rng) as i64
        };
        let quantity = rng.range(1, 9);
        m.push(vec![
            Value::Int(customer),
            Value::Int(product),
            Value::Int(store),
            Value::Int(quantity),
            Value::Int(quantity * rng.range(100, 5_000)),
        ]);
    }
    m
}

/// Generate and register all five retail tables into `catalog`.
pub fn register_retail(catalog: &mut StorageCatalog, spec: &RetailSpec) -> Result<()> {
    catalog.insert_multiset("sales", &sales(spec))?;
    catalog.insert_multiset("customers", &customers(spec))?;
    catalog.insert_multiset("products", &products(spec))?;
    catalog.insert_multiset("stores", &stores(spec))?;
    catalog.insert_multiset("categories", &categories(spec))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_have_dense_primary_keys() {
        let spec = RetailSpec::default();
        for (m, n) in [
            (customers(&spec), spec.customers),
            (products(&spec), spec.products),
            (stores(&spec), spec.stores),
            (categories(&spec), spec.categories),
        ] {
            assert_eq!(m.len(), n);
            for (i, row) in m.rows().iter().enumerate() {
                assert_eq!(row[0], Value::Int(i as i64), "dense pk at {i}");
            }
        }
    }

    #[test]
    fn full_coverage_spec_matches_every_dimension_row() {
        let spec = RetailSpec::default();
        let f = sales(&spec);
        assert_eq!(f.len(), spec.sales);
        for (field, n) in [(0, spec.customers), (1, spec.products), (2, spec.stores)] {
            let mut seen = vec![false; n];
            for row in f.rows() {
                let id = row[field].as_int().unwrap();
                assert!((0..n as i64).contains(&id), "fk {id} within dim");
                seen[id as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "field {field} covers its dim");
        }
    }

    #[test]
    fn selective_product_domain_leaves_most_sales_unmatched() {
        let spec = RetailSpec {
            product_domain_factor: 25,
            ..RetailSpec::default()
        };
        let f = sales(&spec);
        let matched = f
            .rows()
            .iter()
            .filter(|r| r[1].as_int().unwrap() < spec.products as i64)
            .count();
        // Zipf skew concentrates mass on low ranks, so the matched share
        // exceeds 1/25 — but the dimension must still filter hard.
        assert!(
            matched < f.len() / 2,
            "{matched}/{} sales match a product",
            f.len()
        );
    }

    #[test]
    fn generation_is_reproducible() {
        let spec = RetailSpec::default();
        assert!(sales(&spec).bag_eq(&sales(&spec)));
        let other = RetailSpec {
            seed: 99,
            ..RetailSpec::default()
        };
        assert!(!sales(&spec).bag_eq(&sales(&other)));
    }
}
