//! Synthetic generators for the paper's evaluation workloads.
//!
//! The paper's inputs (DAS-4 web logs / crawled link graphs) are not
//! published; these generators produce the standard synthetic equivalents
//! (documented in DESIGN.md §Substitutions): zipf-distributed URL
//! popularity for the access log, preferential-attachment-style in-degree
//! for the link graph, and a uniform grades table for §III-B.

use crate::ir::{DataType, Multiset, Schema, Value};
use crate::util::{Rng, Zipf};

pub mod retail;

pub use retail::{register_retail, RetailSpec};

/// Parameters for the URL access-count workload (§IV example 1).
#[derive(Debug, Clone)]
pub struct AccessLogSpec {
    /// Total log records.
    pub rows: usize,
    /// Distinct URLs.
    pub urls: usize,
    /// Zipf exponent for URL popularity (1.0–1.3 is typical of web logs).
    pub skew: f64,
    /// RNG seed (experiments are reproducible per seed).
    pub seed: u64,
}

impl Default for AccessLogSpec {
    fn default() -> Self {
        AccessLogSpec {
            rows: 2_000_000,
            urls: 100_000,
            skew: 1.1,
            seed: 42,
        }
    }
}

/// Generate the `access(url: str)` table of the paper's first example.
pub fn access_log(spec: &AccessLogSpec) -> Multiset {
    let mut rng = Rng::new(spec.seed);
    let zipf = Zipf::new(spec.urls, spec.skew);
    let schema = Schema::new(vec![("url", DataType::Str)]);
    let mut m = Multiset::new(schema);
    // Pre-render URL strings so popular URLs share one allocation.
    let urls: Vec<Value> = (0..spec.urls).map(|i| Value::str(url_for(i))).collect();
    for _ in 0..spec.rows {
        let rank = zipf.sample(&mut rng);
        m.push(vec![urls[rank].clone()]);
    }
    m
}

/// Wide-schema variant: `access(url, agent, bytes)` with a payload user
/// agent string and a bytes column — exercises dead-field elimination
/// (the paper's "removing unused structure fields" experiment).
pub fn access_log_wide(spec: &AccessLogSpec) -> Multiset {
    let mut rng = Rng::new(spec.seed);
    let zipf = Zipf::new(spec.urls, spec.skew);
    let schema = Schema::new(vec![
        ("url", DataType::Str),
        ("agent", DataType::Str),
        ("bytes", DataType::Int),
    ]);
    let agents: Vec<Value> = [
        "Mozilla/5.0 (X11; Linux x86_64) AppleWebKit/537.36",
        "Mozilla/5.0 (Windows NT 10.0; Win64; x64) Gecko/20100101",
        "Googlebot/2.1 (+http://www.google.com/bot.html)",
        "curl/7.68.0",
    ]
    .iter()
    .map(|s| Value::str(*s))
    .collect();
    let urls: Vec<Value> = (0..spec.urls).map(|i| Value::str(url_for(i))).collect();
    let mut m = Multiset::new(schema);
    for _ in 0..spec.rows {
        let rank = zipf.sample(&mut rng);
        m.push(vec![
            urls[rank].clone(),
            agents[rng.below(agents.len() as u64) as usize].clone(),
            Value::Int(rng.range(200, 100_000)),
        ]);
    }
    m
}

/// Parameters for the reverse web-link graph workload (§IV example 2).
#[derive(Debug, Clone)]
pub struct LinkGraphSpec {
    /// Total (source, target) edges.
    pub edges: usize,
    /// Distinct pages.
    pub pages: usize,
    /// Zipf exponent for target in-degree.
    pub skew: f64,
    pub seed: u64,
}

impl Default for LinkGraphSpec {
    fn default() -> Self {
        LinkGraphSpec {
            edges: 2_000_000,
            pages: 100_000,
            skew: 1.05,
            seed: 43,
        }
    }
}

/// Generate the `links(source: str, target: str)` table.
pub fn link_graph(spec: &LinkGraphSpec) -> Multiset {
    let mut rng = Rng::new(spec.seed);
    let zipf = Zipf::new(spec.pages, spec.skew);
    let schema = Schema::new(vec![("source", DataType::Str), ("target", DataType::Str)]);
    let pages: Vec<Value> = (0..spec.pages).map(|i| Value::str(page_for(i))).collect();
    let mut m = Multiset::new(schema);
    for _ in 0..spec.edges {
        // Sources roughly uniform (every page links out), targets zipfian
        // (popular pages attract links).
        let src = rng.below(spec.pages as u64) as usize;
        let dst = zipf.sample(&mut rng);
        m.push(vec![pages[src].clone(), pages[dst].clone()]);
    }
    m
}

/// `Grades(studentID, grade, weight)` for the §III-B example.
pub fn grades(students: usize, per_student: usize, seed: u64) -> Multiset {
    let mut rng = Rng::new(seed);
    let schema = Schema::new(vec![
        ("studentID", DataType::Int),
        ("grade", DataType::Float),
        ("weight", DataType::Float),
    ]);
    let mut m = Multiset::new(schema);
    for s in 0..students {
        for _ in 0..per_student {
            m.push(vec![
                Value::Int(s as i64),
                Value::Float(1.0 + rng.f64() * 9.0),
                Value::Float(0.1 + rng.f64() * 0.9),
            ]);
        }
    }
    m
}

fn url_for(rank: usize) -> String {
    format!("http://example.org/site{}/page{}.html", rank % 997, rank)
}

fn page_for(rank: usize) -> String {
    format!("http://crawl.example.net/doc/{rank}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn access_log_is_reproducible_and_skewed() {
        let spec = AccessLogSpec {
            rows: 20_000,
            urls: 1000,
            skew: 1.1,
            seed: 7,
        };
        let a = access_log(&spec);
        let b = access_log(&spec);
        assert!(a.bag_eq(&b));
        assert_eq!(a.len(), 20_000);
        // Top URL should dwarf the median URL.
        let mut counts: HashMap<&str, usize> = HashMap::new();
        for r in a.rows() {
            *counts.entry(r[0].as_str().unwrap()).or_default() += 1;
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|x, y| y.cmp(x));
        assert!(freqs[0] > freqs[freqs.len() / 2] * 10);
    }

    #[test]
    fn different_seeds_differ() {
        let a = access_log(&AccessLogSpec { rows: 1000, urls: 100, skew: 1.1, seed: 1 });
        let b = access_log(&AccessLogSpec { rows: 1000, urls: 100, skew: 1.1, seed: 2 });
        assert!(!a.bag_eq(&b));
    }

    #[test]
    fn link_graph_shape() {
        let g = link_graph(&LinkGraphSpec {
            edges: 10_000,
            pages: 500,
            skew: 1.05,
            seed: 3,
        });
        assert_eq!(g.len(), 10_000);
        assert_eq!(g.schema.field(1).name, "target");
    }

    #[test]
    fn wide_log_has_payload_fields() {
        let m = access_log_wide(&AccessLogSpec {
            rows: 100,
            urls: 10,
            skew: 1.0,
            seed: 5,
        });
        assert_eq!(m.schema.len(), 3);
        assert!(m.get(0, 2).as_int().unwrap() >= 200);
    }

    #[test]
    fn grades_rows() {
        let g = grades(10, 5, 1);
        assert_eq!(g.len(), 50);
        for r in g.rows() {
            let grade = r[1].as_float().unwrap();
            assert!((1.0..=10.0).contains(&grade));
        }
    }
}
