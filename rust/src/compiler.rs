//! The end-to-end compiler driver: source → single IR → transformation
//! pipeline → (optional) parallelization + reformatting → execution.
//!
//! `Engine` is the embedder-facing API the examples and the CLI use: it
//! owns the storage catalog, the optional XLA kernel runtime, and the
//! compilation options, and exposes one-call `sql()` / `explain()` /
//! `sql_distributed()` entry points.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::{AggJob, ClusterConfig, JobResult};
use crate::distrib::DistributionPlan;
use crate::exec::{self, Output};
use crate::ir::{pretty, Multiset, Program};
use crate::runtime::Kernels;
use crate::sql;
use crate::storage::StorageCatalog;
use crate::transform::{self, Pass, PassCtx, ReformatPlan, Trace};

/// Reformatting policy (§III-C1's cost gate).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReformatMode {
    /// Never touch the stored data.
    Off,
    /// Apply when amortized over this many expected runs.
    Auto { expected_runs: u64 },
    /// Always apply (the Figure-2 "integer keyed" variants).
    Force,
}

/// Compilation options.
#[derive(Debug, Clone)]
pub struct CompileOptions {
    /// Parallelize to this many processors (1 = sequential).
    pub processors: usize,
    /// Indirect-partitioning field (None → direct blocking).
    pub partition_field: Option<String>,
    pub reformat: ReformatMode,
    /// Run the cost-based optimizer (`crate::opt`) between lowering and
    /// the pass pipeline: join build side, predicate order, index
    /// strategies. On by default; turn off to compare plans.
    pub optimize: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            processors: 1,
            partition_field: None,
            reformat: ReformatMode::Off,
            optimize: true,
        }
    }
}

/// A compiled query with full provenance.
pub struct Compiled {
    pub program: Program,
    pub trace: Trace,
    pub reformat: Option<ReformatPlan>,
    pub distribution: Option<DistributionPlan>,
    /// The cost-based optimizer's report (estimates + decisions), when
    /// `CompileOptions::optimize` was on.
    pub opt: Option<crate::opt::OptReport>,
}

/// One cached plan: the compiled artifact plus the statistics epoch it
/// was optimized under.
struct CacheEntry {
    epoch: u64,
    plan: Arc<Compiled>,
}

/// The engine's plan cache. Keys are the *normalized* query — the parsed
/// AST's canonical debug form, so whitespace/keyword-case variants of the
/// same query share one entry — paired with the compile options (a plan
/// built for 4 processors is not a plan for 1). Entries carry the catalog
/// statistics epoch they were optimized under; an import or reformat
/// bumps the epoch and the stale plan is recompiled on next use.
#[derive(Default)]
struct PlanCache {
    entries: BTreeMap<String, CacheEntry>,
    hits: u64,
    misses: u64,
    invalidations: u64,
}

/// The embedder API.
pub struct Engine {
    pub catalog: StorageCatalog,
    pub kernels: Option<Kernels>,
    pub options: CompileOptions,
    plan_cache: PlanCache,
}

impl Engine {
    pub fn new(catalog: StorageCatalog) -> Self {
        Engine {
            catalog,
            kernels: None,
            options: CompileOptions::default(),
            plan_cache: PlanCache::default(),
        }
    }

    /// Attach the XLA kernel runtime (integer-keyed hot path).
    pub fn with_kernels(mut self, k: Kernels) -> Self {
        self.kernels = Some(k);
        self
    }

    pub fn with_options(mut self, o: CompileOptions) -> Self {
        self.options = o;
        self
    }

    /// Compile a SQL query through the full pipeline. May rewrite the
    /// stored tables when reformatting is enabled. Always compiles fresh;
    /// `plan` is the cached entry point.
    pub fn compile(&mut self, query: &str) -> Result<Compiled> {
        let select = sql::parse(query)?;
        self.compile_select(&select)
    }

    /// Compile through the plan cache: repeat queries (same normalized
    /// AST, same options, same catalog statistics epoch) reuse the cached
    /// plan without recompiling. This is what `sql`, `explain` and the
    /// serving layer (`serve::Server::prepare`) go through.
    pub fn plan(&mut self, query: &str) -> Result<Arc<Compiled>> {
        Ok(self.plan_cached(query)?.0)
    }

    /// `plan`, also reporting whether the cache served the plan (`true` on
    /// a hit). The serving layer uses the flag to tag `serve.cache_hit`.
    pub fn plan_cached(&mut self, query: &str) -> Result<(Arc<Compiled>, bool)> {
        let select = sql::parse(query)?;
        let key = format!("{:?}|{:?}", self.options, select);
        if let Some(entry) = self.plan_cache.entries.get(&key) {
            if entry.epoch == self.catalog.stats_epoch() {
                self.plan_cache.hits += 1;
                return Ok((entry.plan.clone(), true));
            }
            // The catalog changed under the plan: its cardinality
            // estimates and storage-scheme decisions are stale.
            self.plan_cache.entries.remove(&key);
            self.plan_cache.invalidations += 1;
        }
        self.plan_cache.misses += 1;
        let plan = Arc::new(self.compile_select(&select)?);
        // Key on the POST-compile epoch: an enabled reformat pass rewrites
        // stored tables *during* compilation (bumping the epoch), and the
        // plan being cached was optimized against that rewritten layout —
        // storing the pre-compile epoch would self-invalidate every entry.
        let entry = CacheEntry {
            epoch: self.catalog.stats_epoch(),
            plan: plan.clone(),
        };
        self.plan_cache.entries.insert(key, entry);
        Ok((plan, false))
    }

    /// Plan-cache counters: `(hits, misses, invalidations)`. Also
    /// reported by `explain`.
    pub fn plan_cache_stats(&self) -> (u64, u64, u64) {
        let c = &self.plan_cache;
        (c.hits, c.misses, c.invalidations)
    }

    fn compile_select(&mut self, select: &sql::Select) -> Result<Compiled> {
        // ORDER BY / LIMIT lower INTO the IR as an ordered/bounded
        // emission contract (`EmitOrder` on the emit loop) — the whole
        // query, top-k included, is one program every tier executes.
        // Lowering consults live column NDV so WHERE splitting lifts the
        // most selective equality conjunct into the index-set filter.
        let catalog = &self.catalog;
        let ndv = |rel: &str, field: &str| -> Option<u64> {
            let t = catalog.get(rel).ok()?;
            let fid = t.schema.field_id(field)?;
            catalog.column_stats(rel, fid).ok().map(|cs| cs.ndv)
        };
        let mut program = sql::lower_with_stats(select, &self.catalog.schemas(), &ndv)?;

        // Reformat decision happens BEFORE the optimizer and
        // materialization so every strategy cost and cardinality
        // estimate sees the final physical layout (dictionary-encoded
        // columns report exact NDV).
        let reformat = match self.options.reformat {
            ReformatMode::Off => None,
            ReformatMode::Auto { expected_runs } => {
                let plan = transform::plan_reformat(&program);
                let applied = transform::apply_if_profitable(
                    &plan,
                    &mut program,
                    &mut self.catalog,
                    expected_runs,
                )?;
                applied.then_some(plan)
            }
            ReformatMode::Force => {
                let plan = transform::plan_reformat(&program);
                transform::apply_reformat(&plan, &mut program, &mut self.catalog)?;
                Some(plan)
            }
        };

        // Cost-based optimization: the query-optimizer half of the
        // paper's "compiler + query optimization over one IR". It may
        // swap the join nest (build-side choice), reorder guard
        // conjuncts and decide index strategies; the classic pipeline
        // below sees the already-optimized shape (and `Materialize`
        // skips strategies decided here).
        let opt = if self.options.optimize {
            Some(crate::opt::optimize(&mut program, &self.catalog)?)
        } else {
            None
        };

        // Classic pipeline.
        let passes = transform::standard_pipeline();
        let refs: Vec<&dyn Pass> = passes.iter().map(|b| b.as_ref()).collect();
        let ctx = PassCtx::new()
            .with_catalog(&self.catalog)
            .with_processors(self.options.processors);
        let mut trace = transform::run_pipeline(&mut program, &refs, &ctx)?;

        // Parallelization + distribution optimization.
        let distribution = if self.options.processors > 1 {
            match &self.options.partition_field {
                Some(field) => {
                    // Indirect partitioning of the first eligible loop.
                    let pass = transform::IndirectPartition {
                        field: field.clone(),
                    };
                    let changed = pass.run(&mut program, &ctx)?;
                    trace.steps.push(("indirect-partition".into(), changed));
                }
                None => {
                    let changed = transform::DirectPartition.run(&mut program, &ctx)?;
                    trace.steps.push(("direct-partition".into(), changed));
                }
            }
            Some(crate::distrib::optimize(&mut program)?)
        } else {
            None
        };

        crate::ir::validate(&program)?;
        Ok(Compiled {
            program,
            trace,
            reformat,
            distribution,
            opt,
        })
    }

    /// Compile + execute in-process (compiled idioms + kernels when
    /// available). Repeat queries reuse the plan cache.
    pub fn sql(&mut self, query: &str) -> Result<Output> {
        let compiled = self.plan(query)?;
        self.execute(&compiled)
    }

    pub fn execute(&self, compiled: &Compiled) -> Result<Output> {
        // No post-processing: ORDER BY/LIMIT are part of the program (the
        // emit loop's `EmitOrder` contract), executed by whichever tier
        // fires — `vec.topk` on the vectorized tier.
        exec::run_compiled(
            &compiled.program,
            &self.catalog,
            self.kernels
                .as_ref()
                .map(|k| k as &dyn crate::exec::plan::KernelExec),
        )
    }

    /// Compile + execute a recognized aggregate on the simulated cluster.
    ///
    /// Single-table group-by aggregates chunk the table through the
    /// coordinator. A recognized join + GROUP BY nest additionally routes
    /// by the optimizer's shipping decision: `opt.dist_broadcast`
    /// replicates the build side as a shared hash table and chunks the
    /// probe (`JoinProbe`), `opt.dist_shuffle` hash-partitions both sides
    /// across the workers, salting heavy-hitter keys
    /// (`coordinator::run_shuffle_join`).
    pub fn sql_distributed(
        &mut self,
        query: &str,
        cluster: &ClusterConfig,
    ) -> Result<(JobResult, Multiset)> {
        // The coordinator owns parallelization (partitioning + chunked
        // scheduling); compile the sequential idiom form for recognition.
        let saved = self.options.processors;
        self.options.processors = 1;
        let compiled = self.compile(query);
        self.options.processors = saved;
        let compiled = compiled?;
        let (r, result) = if let Some(idiom) = exec::recognize(&compiled.program) {
            let (table_name, key_field, result) = match &idiom {
                exec::Idiom::GroupCount {
                    table,
                    key_field,
                    result,
                } => (table.clone(), key_field.clone(), result.clone()),
                exec::Idiom::GroupSum {
                    table,
                    key_field,
                    result,
                    ..
                } => (table.clone(), key_field.clone(), result.clone()),
            };
            let table = self.catalog.get(&table_name)?.clone();
            let kf = table
                .schema
                .field_id(&key_field)
                .context("key field missing")?;
            let job = match &idiom {
                exec::Idiom::GroupCount { .. } => AggJob::count(table, kf),
                exec::Idiom::GroupSum { val_field, .. } => {
                    let vf = self
                        .catalog
                        .get(&table_name)?
                        .schema
                        .field_id(val_field)
                        .context("val field missing")?;
                    AggJob::sum(self.catalog.get(&table_name)?.clone(), kf, vf)
                }
            };
            (crate::coordinator::run_job(cluster, &job)?, result)
        } else if let Some(join) = recognize_dist_join(&compiled.program) {
            let result = join.result.clone();
            (self.run_dist_join(&join, &compiled, cluster)?, result)
        } else {
            bail!("query does not lower to a distributable aggregate idiom");
        };
        let schema = compiled.program.results[&result].clone();
        let mut m = r.to_multiset(schema);
        // The coordinator computes the aggregate map off-IR; honour the
        // program's ordered/bounded emission contract on the way out.
        if let Some(emit) = compiled.program.emit_bound() {
            emit.apply_rows(m.rows_mut());
        }
        Ok((r, m))
    }

    /// Ship a recognized join by the optimizer's `opt.dist_*` decision.
    /// SUM jobs always broadcast — the shuffle executor computes matched
    /// pair counts.
    fn run_dist_join(
        &mut self,
        join: &DistJoin,
        compiled: &Compiled,
        cluster: &ClusterConfig,
    ) -> Result<JobResult> {
        let probe_t = self.catalog.get(&join.probe)?.clone();
        let build_t = self.catalog.get(&join.build)?.clone();
        let shuffle = join.val_field.is_none()
            && compiled
                .opt
                .as_ref()
                .is_some_and(|o| o.has("opt.dist_shuffle"));
        if shuffle {
            let spec = crate::coordinator::ShuffleJoinSpec {
                probe: (*probe_t).clone(),
                probe_key: join.probe_key.clone(),
                build: (*build_t).clone(),
                build_key: join.build_key.clone(),
                group_by: join.group_by.clone(),
                repartition: true,
            };
            return crate::coordinator::run_shuffle_join(cluster, &spec);
        }
        let bkf = build_t
            .schema
            .field_id(&join.build_key)
            .context("build key missing")?;
        let pkf = probe_t
            .schema
            .field_id(&join.probe_key)
            .context("probe key missing")?;
        let gkf = probe_t
            .schema
            .field_id(&join.group_by)
            .context("group field missing")?;
        let probe = crate::coordinator::JoinProbe::new(&build_t, bkf, pkf);
        let job = match &join.val_field {
            None => AggJob::count_join(probe_t, gkf, probe),
            Some(v) => {
                let vf = probe_t
                    .schema
                    .field_id(v)
                    .context("sum field missing")?;
                AggJob::sum_join(probe_t, gkf, vf, probe)
            }
        };
        let mut r = crate::coordinator::run_job(cluster, &job)?;
        r.metrics.note_tag("dist.broadcast");
        Ok(r)
    }

    /// `explain`, distributed: compile the query, execute it on the
    /// simulated cluster, and report the shipping decision
    /// (`opt.dist_*`), the fault/skew events the run survived (the
    /// `dist.*` runtime tags) and the coordinator's full metrics line.
    pub fn explain_distributed(
        &mut self,
        query: &str,
        cluster: &ClusterConfig,
    ) -> Result<String> {
        let saved = self.options.processors;
        self.options.processors = 1;
        let compiled = self.compile(query);
        self.options.processors = saved;
        let compiled = compiled?;
        let (r, _) = self.sql_distributed(query, cluster)?;
        let mut out = String::new();
        out.push_str("-- distributed plan:");
        if let Some(opt) = &compiled.opt {
            for d in opt.decisions.iter().filter(|d| d.tag.starts_with("opt.")) {
                out.push_str(&format!("\n--   [{}] {}", d.tag, d.detail));
            }
        }
        out.push_str(&format!(
            "\n-- cluster: {} workers, {:?} scheduling",
            cluster.workers, cluster.policy
        ));
        out.push_str(&format!("\n-- run: {}", r.metrics.render()));
        out.push('\n');
        Ok(out)
    }

    /// Human-readable compilation report: the optimized IR, the pass
    /// trace, the optimizer's cost section (estimated rows in/out per
    /// loop and every `opt.*` decision), the physical storage scheme of
    /// every referenced column (`int` / `dict[...]` / `rle[...]` /
    /// `range`), and — explain-analyze style — which execution tier
    /// actually fired with its final `ExecStats.idioms` tags.
    pub fn explain(&mut self, query: &str) -> Result<String> {
        let compiled = self.plan(query)?;
        let executed = self.execute(&compiled)?;
        let mut out = String::new();
        out.push_str(&pretty::program(&compiled.program));
        out.push_str("\n-- passes applied: ");
        out.push_str(&compiled.trace.changed_passes().join(", "));
        if let Some(r) = &compiled.reformat {
            out.push_str(&format!("\n-- reformat: {:?}", r.relations));
        }
        if let Some(d) = &compiled.distribution {
            out.push_str(&format!(
                "\n-- distribution: {:?} redistributions={}",
                d.resident,
                d.redistribution_count()
            ));
        }
        if let Some(opt) = &compiled.opt {
            out.push_str("\n-- optimizer:");
            for d in &opt.decisions {
                out.push_str(&format!("\n--   [{}] {}", d.tag, d.detail));
            }
            for e in &opt.estimates {
                out.push_str(&format!(
                    "\n--   est {}{}: rows in {} -> out {}",
                    "  ".repeat(e.depth),
                    e.describe,
                    e.rows_in,
                    e.rows_out
                ));
            }
        }
        // Physical storage scheme per column, from the live catalog (the
        // import path and the reformat pass both re-encode columns).
        for rel in compiled.program.relations.keys() {
            if let Ok(t) = self.catalog.get(rel) {
                let schemes: Vec<String> = t
                    .schema
                    .fields()
                    .iter()
                    .enumerate()
                    .map(|(i, f)| format!("{}:{}", f.name, t.column(i).scheme()))
                    .collect();
                out.push_str(&format!("\n-- storage: `{rel}` {}", schemes.join(" ")));
            }
        }
        let idioms = &executed.stats.idioms;
        let tier = if idioms.iter().any(|t| t == "group_count" || t == "group_sum") {
            "idiom-kernel"
        } else if idioms.iter().any(|t| t == "vectorized") {
            "vectorized"
        } else {
            "interpreter"
        };
        out.push_str(&format!("\n-- tier: {tier}"));
        out.push_str(&format!("\n-- idioms: {}", idioms.join(", ")));
        let (hits, misses, invalidations) = self.plan_cache_stats();
        out.push_str(&format!(
            "\n-- plan cache: hits={hits} misses={misses} invalidations={invalidations}"
        ));
        out.push('\n');
        Ok(out)
    }

    /// Convenience for tests/examples: register a logical multiset.
    pub fn register(&mut self, name: &str, m: &Multiset) -> Result<()> {
        self.catalog.insert_multiset(name, m)
    }

    /// Shared handle to a stored table.
    pub fn table(&self, name: &str) -> Result<Arc<crate::storage::Table>> {
        Ok(self.catalog.get(name)?.clone())
    }
}

/// The distributable join + GROUP BY shape: the Figure-1 nest
/// accumulating one aggregate into a per-group array, followed by the
/// distinct emit loop. The group key (and, for SUM, the value column)
/// must live on the probe (outer) table — that is the side the
/// coordinator chunks across workers.
struct DistJoin {
    probe: String,
    /// Probe-side field compared against the build key.
    probe_key: String,
    build: String,
    build_key: String,
    /// Probe-side GROUP BY field.
    group_by: String,
    /// Probe-side SUM argument (None = COUNT).
    val_field: Option<String>,
    result: String,
}

/// Match the join counterpart of `exec::recognize`'s aggregate idioms.
/// Shape only — the optimizer has already oriented the nest (build side
/// inner) by the time this runs.
fn recognize_dist_join(p: &Program) -> Option<DistJoin> {
    use crate::ir::{AccumOp, Domain, Expr, Stmt, Value};
    let [Stmt::Loop(outer), Stmt::Loop(emit)] = p.body.as_slice() else {
        return None;
    };
    let Domain::IndexSet(ox) = &outer.domain else {
        return None;
    };
    if ox.field_filter.is_some() || ox.distinct.is_some() || ox.partition.is_some() {
        return None;
    }
    let [Stmt::Loop(inner)] = outer.body.as_slice() else {
        return None;
    };
    let Domain::IndexSet(iix) = &inner.domain else {
        return None;
    };
    if iix.distinct.is_some() || iix.partition.is_some() {
        return None;
    }
    let Some((build_key, key)) = &iix.field_filter else {
        return None;
    };
    let Expr::Field {
        var: kvar,
        field: probe_key,
    } = key
    else {
        return None;
    };
    if kvar != &outer.var || outer.var == inner.var {
        return None;
    }
    // A single additive accumulation, grouped by a probe-side field.
    let [Stmt::Accum {
        array,
        indices,
        op: AccumOp::Add,
        value,
    }] = inner.body.as_slice()
    else {
        return None;
    };
    let [Expr::Field {
        var: gvar,
        field: group_by,
    }] = indices.as_slice()
    else {
        return None;
    };
    if gvar != &outer.var {
        return None;
    }
    let val_field = match value {
        Expr::Const(Value::Int(1)) => None,
        Expr::Field { var, field } if var == &outer.var => Some(field.clone()),
        _ => return None,
    };
    // Emit loop: distinct group keys of the probe table, emitting
    // (key, array[key]).
    let Domain::IndexSet(eix) = &emit.domain else {
        return None;
    };
    if eix.relation != ox.relation || eix.field_filter.is_some() || eix.partition.is_some() {
        return None;
    }
    if eix.distinct.as_deref() != Some(group_by.as_str()) {
        return None;
    }
    let [Stmt::ResultUnion { result, tuple }] = emit.body.as_slice() else {
        return None;
    };
    let [Expr::Field { var: ev1, field: ef1 }, Expr::ArrayRef { array: ea, indices: eidx }] =
        tuple.as_slice()
    else {
        return None;
    };
    if ev1 != &emit.var || ef1 != group_by || ea != array {
        return None;
    }
    let [Expr::Field { var: ev2, field: ef2 }] = eidx.as_slice() else {
        return None;
    };
    if ev2 != &emit.var || ef2 != group_by {
        return None;
    }
    Some(DistJoin {
        probe: ox.relation.clone(),
        probe_key: probe_key.clone(),
        build: iix.relation.clone(),
        build_key: build_key.clone(),
        group_by: group_by.clone(),
        val_field,
        result: result.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Policy;
    use crate::workload::{access_log, AccessLogSpec};

    fn engine(rows: usize) -> Engine {
        let m = access_log(&AccessLogSpec {
            rows,
            urls: 50,
            skew: 1.1,
            seed: 9,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        Engine::new(c)
    }

    const Q: &str = "SELECT url, COUNT(url) FROM access GROUP BY url";

    #[test]
    fn sequential_compile_and_run() {
        let mut e = engine(2000);
        let out = e.sql(Q).unwrap();
        assert_eq!(out.result().unwrap().len(), 50);
    }

    #[test]
    fn forced_reformat_dict_encodes_and_preserves_results() {
        let mut plain = engine(2000);
        let reference = plain.sql(Q).unwrap();

        let mut e = engine(2000);
        e.options.reformat = ReformatMode::Force;
        let out = e.sql(Q).unwrap();
        assert!(out.result().unwrap().bag_eq(reference.result().unwrap()));
        // Catalog now holds an integer-keyed table.
        let t = e.table("access").unwrap();
        assert!(t.column(0).dictionary().is_some());
    }

    #[test]
    fn parallel_compile_produces_forall_and_same_results() {
        let mut seq = engine(2000);
        let reference = seq.sql(Q).unwrap();

        let mut e = engine(2000);
        e.options.processors = 4;
        let compiled = e.compile(Q).unwrap();
        let text = pretty::program(&compiled.program);
        assert!(text.contains("forall"), "{text}");
        let out = exec::run(&compiled.program, &e.catalog).unwrap();
        assert!(out.result().unwrap().bag_eq(reference.result().unwrap()));
    }

    #[test]
    fn distributed_execution_matches_in_process() {
        let mut e = engine(5000);
        e.options.reformat = ReformatMode::Force;
        let reference = e.sql(Q).unwrap();
        let (_r, m) = e
            .sql_distributed(Q, &ClusterConfig::new(4, Policy::Gss))
            .unwrap();
        assert!(m.bag_eq(reference.result().unwrap()), "{m:?}");
    }

    #[test]
    fn explain_mentions_passes() {
        let mut e = engine(500);
        e.options.processors = 2;
        let text = e.explain(Q).unwrap();
        assert!(text.contains("passes applied"), "{text}");
        assert!(text.contains("materialize") || text.contains("direct-partition"), "{text}");
    }

    #[test]
    fn auto_reformat_respects_cost_gate() {
        let mut e = engine(500);
        e.options.reformat = ReformatMode::Auto { expected_runs: 1 };
        let _ = e.sql(Q).unwrap();
        assert!(e.table("access").unwrap().column(0).dictionary().is_none());
        let mut e2 = engine(500);
        e2.options.reformat = ReformatMode::Auto { expected_runs: 1000 };
        let _ = e2.sql(Q).unwrap();
        assert!(e2.table("access").unwrap().column(0).dictionary().is_some());
    }
}

#[cfg(test)]
mod optimizer_tests {
    use super::*;
    use crate::ir::{DataType, Schema, Value};
    use crate::util::Rng;

    /// Small `dim` written FIRST: as lowered, the join nest would hash
    /// the big `fact` table; the optimizer must swap the build side.
    fn join_engine() -> Engine {
        let mut dim = Multiset::new(Schema::new(vec![
            ("id", DataType::Int),
            ("g", DataType::Str),
        ]));
        for i in 0..64i64 {
            dim.push(vec![Value::Int(i), Value::str(format!("g{}", i % 5))]);
        }
        let mut fact = Multiset::new(Schema::new(vec![
            ("a_id", DataType::Int),
            ("w", DataType::Int),
        ]));
        let mut rng = Rng::new(11);
        for _ in 0..6000 {
            fact.push(vec![
                Value::Int(rng.range(0, 256)),
                Value::Int(rng.range(0, 9)),
            ]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("dim", &dim).unwrap();
        c.insert_multiset("fact", &fact).unwrap();
        Engine::new(c)
    }

    const JQ: &str = "SELECT g, COUNT(g) FROM dim JOIN fact ON dim.id = fact.a_id GROUP BY g";

    #[test]
    fn skewed_join_routes_through_optimized_hash_join() {
        let mut e = join_engine();
        let out = e.sql(JQ).unwrap();
        assert!(
            out.stats.idioms.contains(&"vec.hash_join".to_string()),
            "{:?}",
            out.stats.idioms
        );
        assert!(
            out.stats.idioms.contains(&"opt.join_build_side".to_string()),
            "{:?}",
            out.stats.idioms
        );
        // The optimizer-off plan produces identical results and no tag.
        let mut off = join_engine();
        off.options.optimize = false;
        let reference = off.sql(JQ).unwrap();
        assert!(out.result().unwrap().bag_eq(reference.result().unwrap()));
        assert!(!reference.stats.idioms.iter().any(|t| t.starts_with("opt.")));
    }

    #[test]
    fn explain_reports_cost_section_tier_and_idioms() {
        let mut e = join_engine();
        let text = e.explain(JQ).unwrap();
        assert!(text.contains("-- optimizer:"), "{text}");
        assert!(text.contains("[opt.join_build_side]"), "{text}");
        assert!(text.contains("est "), "{text}");
        assert!(text.contains("rows in "), "{text}");
        assert!(text.contains("-- tier: vectorized"), "{text}");
        assert!(text.contains("vec.hash_join"), "{text}");
        assert!(text.contains("-- idioms:"), "{text}");
    }

    #[test]
    fn explain_reports_idiom_kernel_tier_for_plain_group_by() {
        let m = crate::workload::access_log(&crate::workload::AccessLogSpec {
            rows: 1000,
            urls: 20,
            skew: 1.1,
            seed: 2,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        let mut e = Engine::new(c);
        let text = e
            .explain("SELECT url, COUNT(url) FROM access GROUP BY url")
            .unwrap();
        assert!(text.contains("-- tier: idiom-kernel"), "{text}");
        assert!(text.contains("group_count"), "{text}");
    }

    #[test]
    fn explain_shows_per_column_storage_schemes() {
        let mut e = join_engine();
        let text = e.explain(JQ).unwrap();
        assert!(text.contains("-- storage: `dim`"), "{text}");
        assert!(text.contains("-- storage: `fact`"), "{text}");
        assert!(text.contains("a_id:int"), "{text}");
        assert!(text.contains("g:str"), "{text}");
    }

    #[test]
    fn compressed_storage_flows_through_explain_and_idioms() {
        use crate::storage::Table;
        let mut m = Multiset::new(Schema::new(vec![
            ("code", DataType::Int),
            ("n", DataType::Int),
        ]));
        for i in 0..4000i64 {
            m.push(vec![Value::Int(i / 100), Value::Int(i % 13)]);
        }
        let mut t = Table::from_multiset(&m).unwrap();
        assert!(t.compress_int_field(0).unwrap());
        let mut c = StorageCatalog::new();
        c.insert("logs", t);
        let mut e = Engine::new(c);
        let text = e.explain("SELECT n FROM logs WHERE code = 7").unwrap();
        assert!(
            text.contains("-- storage: `logs` code:rle[40 runs] n:int"),
            "{text}"
        );
        assert!(text.contains("[opt.compressed_scan]"), "{text}");
        assert!(text.contains("vec.rle_filter"), "{text}");
    }

    /// Build side a large fraction of the probe side: shuffling both
    /// sides moves fewer rows than replicating the build table.
    fn comparable_join_engine() -> Engine {
        let mut dim = Multiset::new(Schema::new(vec![("id", DataType::Int)]));
        for i in 0..2000i64 {
            dim.push(vec![Value::Int(i % 500)]);
        }
        let mut fact = Multiset::new(Schema::new(vec![
            ("a_id", DataType::Int),
            ("w", DataType::Int),
        ]));
        let mut rng = Rng::new(23);
        for _ in 0..3000 {
            fact.push(vec![
                Value::Int(rng.range(0, 500)),
                Value::Int(rng.range(0, 9)),
            ]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("dim", &dim).unwrap();
        c.insert_multiset("fact", &fact).unwrap();
        Engine::new(c)
    }

    /// Group key on the probe (fact) side — the distributable join shape.
    const DJQ: &str = "SELECT w, COUNT(w) FROM fact JOIN dim ON fact.a_id = dim.id GROUP BY w";

    #[test]
    fn distributed_join_broadcasts_a_small_build_side() {
        let mut e = join_engine();
        let reference = e.sql(DJQ).unwrap();
        let cluster = ClusterConfig::new(4, crate::sched::Policy::Gss);
        let (r, m) = e.sql_distributed(DJQ, &cluster).unwrap();
        assert!(m.bag_eq(reference.result().unwrap()), "{m:?}");
        assert!(
            r.metrics.tags.iter().any(|t| t == "dist.broadcast"),
            "{:?}",
            r.metrics.tags
        );
        let compiled = e.compile(DJQ).unwrap();
        assert!(compiled.opt.unwrap().has("opt.dist_broadcast"));
    }

    #[test]
    fn distributed_join_shuffles_comparable_sides() {
        let mut e = comparable_join_engine();
        let reference = e.sql(DJQ).unwrap();
        let cluster = ClusterConfig::new(4, crate::sched::Policy::FixedChunk(128));
        let (r, m) = e.sql_distributed(DJQ, &cluster).unwrap();
        assert!(m.bag_eq(reference.result().unwrap()), "{m:?}");
        assert!(
            r.metrics.tags.iter().any(|t| t == "dist.shuffle"),
            "{:?}",
            r.metrics.tags
        );
        let compiled = e.compile(DJQ).unwrap();
        assert!(compiled.opt.unwrap().has("opt.dist_shuffle"));
    }

    #[test]
    fn explain_distributed_surfaces_decision_and_metrics() {
        let mut e = join_engine();
        let cluster = ClusterConfig::new(3, crate::sched::Policy::Gss);
        let text = e.explain_distributed(DJQ, &cluster).unwrap();
        assert!(text.contains("[opt.dist_broadcast]"), "{text}");
        assert!(text.contains("3 workers"), "{text}");
        assert!(text.contains("chunks="), "{text}");
        assert!(text.contains("dist.broadcast"), "{text}");
    }

    #[test]
    fn optimizer_report_is_attached_to_compiled_queries() {
        let mut e = join_engine();
        let compiled = e.compile(JQ).unwrap();
        let report = compiled.opt.expect("optimizer on by default");
        assert!(report.has("opt.join_build_side"), "{report:?}");
        assert!(!report.estimates.is_empty());
        let mut off = join_engine();
        off.options.optimize = false;
        assert!(off.compile(JQ).unwrap().opt.is_none());
    }
}

#[cfg(test)]
mod order_limit_tests {
    use super::*;
    use crate::ir::Value;
    use crate::sched::Policy;
    use crate::workload::{access_log, AccessLogSpec};

    fn engine() -> Engine {
        let m = access_log(&AccessLogSpec {
            rows: 5_000,
            urls: 40,
            skew: 1.2,
            seed: 4,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        Engine::new(c)
    }

    #[test]
    fn top_k_urls_by_count() {
        let mut e = engine();
        let out = e
            .sql("SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n DESC LIMIT 5")
            .unwrap();
        let r = out.result().unwrap();
        assert_eq!(r.len(), 5);
        // Rows are non-increasing in count, and the first is the maximum.
        let counts: Vec<i64> = r.rows().iter().map(|row| row[1].as_int().unwrap()).collect();
        assert!(counts.windows(2).all(|w| w[0] >= w[1]), "{counts:?}");
        let full = e
            .sql("SELECT url, COUNT(url) AS n FROM access GROUP BY url")
            .unwrap();
        let max = full
            .result()
            .unwrap()
            .rows()
            .iter()
            .map(|row| row[1].as_int().unwrap())
            .max()
            .unwrap();
        assert_eq!(counts[0], max);
    }

    #[test]
    fn order_by_key_ascending() {
        let mut e = engine();
        let out = e
            .sql("SELECT url, COUNT(url) FROM access GROUP BY url ORDER BY url ASC")
            .unwrap();
        let keys: Vec<String> = out
            .result()
            .unwrap()
            .rows()
            .iter()
            .map(|r| r[0].to_string())
            .collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }

    #[test]
    fn limit_without_order() {
        let mut e = engine();
        let out = e.sql("SELECT url FROM access LIMIT 7").unwrap();
        assert_eq!(out.result().unwrap().len(), 7);
    }

    #[test]
    fn order_limit_applies_to_distributed_results() {
        let mut e = engine();
        let (_, m) = e
            .sql_distributed(
                "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n DESC LIMIT 3",
                &ClusterConfig::new(4, Policy::Gss),
            )
            .unwrap();
        assert_eq!(m.len(), 3);
        let counts: Vec<Value> = m.rows().iter().map(|r| r[1].clone()).collect();
        assert!(counts.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn unknown_order_column_errors() {
        let mut e = engine();
        assert!(e
            .sql("SELECT url FROM access ORDER BY nope")
            .unwrap_err()
            .to_string()
            .contains("unknown column"));
    }
}

#[cfg(test)]
mod plan_cache_tests {
    use super::*;
    use crate::workload::{access_log, AccessLogSpec};

    fn engine(rows: usize) -> Engine {
        let m = access_log(&AccessLogSpec {
            rows,
            urls: 50,
            skew: 1.1,
            seed: 9,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        Engine::new(c)
    }

    const Q: &str = "SELECT url, COUNT(url) FROM access GROUP BY url";

    #[test]
    fn repeat_queries_hit_the_plan_cache() {
        let mut e = engine(1000);
        let first = e.sql(Q).unwrap();
        assert_eq!(e.plan_cache_stats(), (0, 1, 0));
        let second = e.sql(Q).unwrap();
        assert_eq!(e.plan_cache_stats(), (1, 1, 0));
        assert!(second.result().unwrap().bag_eq(first.result().unwrap()));
        // The key is the parsed AST, not the query text: whitespace
        // variants normalize to the same entry.
        let _ = e
            .sql("SELECT url,  COUNT(url)   FROM access GROUP BY url")
            .unwrap();
        assert_eq!(e.plan_cache_stats(), (2, 1, 0));
    }

    #[test]
    fn options_partition_the_cache() {
        let mut e = engine(1000);
        e.sql(Q).unwrap();
        e.options.processors = 4;
        // A plan parallelized for 4 processors is a different artifact.
        e.sql(Q).unwrap();
        assert_eq!(e.plan_cache_stats(), (0, 2, 0));
    }

    #[test]
    fn catalog_changes_invalidate_cached_plans() {
        let mut e = engine(1000);
        e.sql(Q).unwrap();
        e.sql(Q).unwrap();
        assert_eq!(e.plan_cache_stats(), (1, 1, 0));
        // Re-importing the table bumps the statistics epoch: the cached
        // plan was optimized against stale statistics.
        let m = access_log(&AccessLogSpec {
            rows: 2000,
            urls: 50,
            skew: 1.1,
            seed: 10,
        });
        e.register("access", &m).unwrap();
        let out = e.sql(Q).unwrap();
        assert_eq!(out.result().unwrap().len(), 50);
        assert_eq!(e.plan_cache_stats(), (1, 2, 1));
    }

    #[test]
    fn forced_reformat_caches_the_post_reformat_plan() {
        let mut e = engine(1000);
        e.options.reformat = ReformatMode::Force;
        e.sql(Q).unwrap();
        // The reformat pass rewrote the stored table *during* the first
        // compile (bumping the epoch); the entry is keyed on the
        // post-compile epoch, so the repeat run still hits.
        e.sql(Q).unwrap();
        assert_eq!(e.plan_cache_stats(), (1, 1, 0));
        assert!(e.table("access").unwrap().column(0).dictionary().is_some());
    }

    #[test]
    fn explain_reports_cache_counters() {
        let mut e = engine(500);
        let text = e.explain(Q).unwrap();
        assert!(
            text.contains("-- plan cache: hits=0 misses=1 invalidations=0"),
            "{text}"
        );
        let text = e.explain(Q).unwrap();
        assert!(
            text.contains("-- plan cache: hits=1 misses=1 invalidations=0"),
            "{text}"
        );
    }

    #[test]
    fn prepared_placeholder_queries_share_one_cached_plan() {
        let mut e = engine(1000);
        let q = "SELECT url, COUNT(url) FROM access WHERE bytes > ? GROUP BY url";
        // `engine` tables lack `bytes`; use the wide log instead.
        let m = crate::workload::access_log_wide(&AccessLogSpec {
            rows: 1000,
            urls: 20,
            skew: 1.1,
            seed: 3,
        });
        e.register("access", &m).unwrap();
        let p1 = e.plan(q).unwrap();
        let p2 = e.plan(q).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "second plan must be the cached Arc");
        let (hits, misses, _) = e.plan_cache_stats();
        assert_eq!((hits, misses), (1, 1));
    }
}

#[cfg(test)]
mod topk_contract_tests {
    use super::*;
    use crate::workload::{access_log, AccessLogSpec};

    fn engine() -> Engine {
        let m = access_log(&AccessLogSpec {
            rows: 5_000,
            urls: 40,
            skew: 1.2,
            seed: 4,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        Engine::new(c)
    }

    #[test]
    fn top_k_compiles_to_one_program_and_fires_the_topk_kernel() {
        // The acceptance workload: a single IR program (no Engine-side
        // clause stripping), the `vec.topk` bounded-heap kernel on the
        // vectorized tier, and the optimizer's heap decision.
        let mut e = engine();
        let q = "SELECT url, COUNT(url) FROM access GROUP BY url ORDER BY count DESC LIMIT 5";
        let compiled = e.compile(q).unwrap();
        let emit = compiled.program.emit_bound().expect("ORDER BY/LIMIT in the IR");
        assert_eq!(emit.key, Some(1));
        assert!(emit.descending);
        assert_eq!(emit.limit, Some(5));
        assert_eq!(emit.strategy, crate::ir::TopKStrategy::Heap);
        let text = pretty::program(&compiled.program);
        assert!(text.contains("topk(#1 desc, k=5)"), "{text}");

        let out = e.execute(&compiled).unwrap();
        assert_eq!(out.result().unwrap().len(), 5);
        for tag in ["vectorized", "vec.topk", "opt.topk_heap"] {
            assert!(
                out.stats.idioms.contains(&tag.to_string()),
                "missing {tag}: {:?}",
                out.stats.idioms
            );
        }
    }

    #[test]
    fn explain_shows_the_topk_decision_and_kernel() {
        let mut e = engine();
        let text = e
            .explain("SELECT url, COUNT(url) FROM access GROUP BY url ORDER BY count DESC LIMIT 5")
            .unwrap();
        assert!(text.contains("[opt.topk_heap]"), "{text}");
        assert!(text.contains("topk(#1 desc, k=5)"), "{text}");
        assert!(text.contains("-- tier: vectorized"), "{text}");
        assert!(text.contains("vec.topk"), "{text}");
        // No LIMIT → the optimizer picks the full sort.
        let text = e
            .explain("SELECT url, COUNT(url) FROM access GROUP BY url ORDER BY url ASC")
            .unwrap();
        assert!(text.contains("[opt.topk_sort]"), "{text}");
    }

    #[test]
    fn top_k_matches_the_post_sorted_full_aggregate() {
        // The lowered top-k emission must equal sorting the full
        // aggregate and truncating — the exact contract the deleted
        // Engine post-sort used to provide.
        let mut e = engine();
        let top = e
            .sql("SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n DESC LIMIT 7")
            .unwrap();
        let full = e
            .sql("SELECT url, COUNT(url) AS n FROM access GROUP BY url")
            .unwrap();
        let mut rows = full.result().unwrap().rows().to_vec();
        rows.sort_by(|a, b| b[1].cmp(&a[1]));
        rows.truncate(7);
        // Counts agree position-by-position; URLs agree as a set per
        // count (ties broken by emission order in both paths).
        let got: Vec<i64> = top
            .result()
            .unwrap()
            .rows()
            .iter()
            .map(|r| r[1].as_int().unwrap())
            .collect();
        let want: Vec<i64> = rows.iter().map(|r| r[1].as_int().unwrap()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn parallel_top_k_matches_sequential() {
        let mut seq = engine();
        let q = "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n DESC LIMIT 5";
        let reference = seq.sql(q).unwrap();
        let mut par = engine();
        par.options.processors = 4;
        let compiled = par.compile(q).unwrap();
        let out = exec::run_parallel(&compiled.program, &par.catalog, 4).unwrap();
        assert_eq!(
            out.result().unwrap().rows(),
            reference.result().unwrap().rows(),
            "parallel top-k must equal the sequential emission row-for-row"
        );
    }
}
