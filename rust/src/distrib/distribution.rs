//! The data-distribution optimizer (§III-A4).
//!
//! "At this stage, all parallel loops in the application are considered to
//! choose the actual distribution of the data. Different loops in the
//! application might be accessing the same data according to a different
//! partitioning ... in optimizing the final data distribution, this
//! communication should be minimized as much as possible."
//!
//! The optimizer:
//! 1. collects, per relation, the partitioning each parallel loop wants
//!    (the field of its indirect partitioning, or Direct for blocked
//!    loops);
//! 2. where two consecutive loops want *different* partitionings of the
//!    same relation, first tries Loop Fusion (via the transform pass) to
//!    make them share one — the paper's example;
//! 3. otherwise picks the majority partitioning as the resident
//!    distribution and records explicit `Redistribute` steps whose byte
//!    cost the channel model will account.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::ir::{Domain, Program, Stmt};
use crate::transform::{LoopFusion, Pass, PassCtx};

use super::partition::Partitioning;

/// What one parallel loop wants of one relation.
#[derive(Debug, Clone, PartialEq)]
pub struct LoopDemand {
    /// Index of the top-level statement.
    pub stmt_idx: usize,
    pub relation: String,
    pub partitioning: Partitioning,
}

/// The optimizer's decision.
#[derive(Debug, Clone, Default)]
pub struct DistributionPlan {
    /// Resident distribution per relation.
    pub resident: BTreeMap<String, Partitioning>,
    /// Redistribution steps that remain necessary:
    /// (before stmt idx, relation, from, to).
    pub redistributions: Vec<(usize, String, Partitioning, Partitioning)>,
    /// Whether fusion was applied while optimizing.
    pub fused: bool,
}

impl DistributionPlan {
    /// Total redistribution count — the § III-A4 metric.
    pub fn redistribution_count(&self) -> usize {
        self.redistributions.len()
    }
}

/// Collect the partitioning demand of every top-level parallel loop.
pub fn collect_demands(p: &Program) -> Vec<LoopDemand> {
    let mut out = Vec::new();
    for (idx, s) in p.body.iter().enumerate() {
        let Stmt::Loop(l) = s else { continue };
        if l.kind != crate::ir::LoopKind::Forall {
            continue;
        }
        // Collect EVERY partitioned iteration inside the forall: a fused
        // forall can carry several (the §III-A4 case where field1 ≠
        // field2 — fusion aligns the outer loops but the second access
        // pattern still demands a different distribution).
        let mut found: Vec<(String, Partitioning)> = Vec::new();
        s.walk(&mut |sub| {
            if let Stmt::Loop(inner) = sub {
                match &inner.domain {
                    Domain::ValuePartition {
                        relation, field, ..
                    } => {
                        found.push((relation.clone(), Partitioning::RangeKey(field.clone())));
                    }
                    Domain::IndexSet(ix) if ix.partition.is_some() => {
                        found.push((ix.relation.clone(), Partitioning::Direct));
                    }
                    _ => {}
                }
            }
        });
        found.dedup();
        for (relation, partitioning) in found {
            out.push(LoopDemand {
                stmt_idx: idx,
                relation,
                partitioning,
            });
        }
    }
    out
}

/// Optimize the distribution for a program: fuse where possible, then pick
/// resident distributions and list the redistributions that remain.
pub fn optimize(p: &mut Program) -> Result<DistributionPlan> {
    let before = collect_demands(p);
    let conflicted = has_conflict(&before);

    let mut plan = DistributionPlan::default();
    if conflicted {
        // Try the paper's move: reorder + fuse so conflicting loops share
        // one traversal (and hence one partitioning).
        plan.fused = LoopFusion.run(p, &PassCtx::new())?;
    }
    let demands = collect_demands(p);

    // Majority vote per relation for the resident distribution.
    let mut votes: BTreeMap<String, BTreeMap<String, usize>> = BTreeMap::new();
    for d in &demands {
        *votes
            .entry(d.relation.clone())
            .or_default()
            .entry(part_key(&d.partitioning))
            .or_default() += 1;
    }
    for (rel, tally) in &votes {
        let winner = tally
            .iter()
            .max_by_key(|(_, n)| **n)
            .map(|(k, _)| k.clone())
            .unwrap();
        let part = demands
            .iter()
            .find(|d| &d.relation == rel && part_key(&d.partitioning) == winner)
            .unwrap()
            .partitioning
            .clone();
        plan.resident.insert(rel.clone(), part);
    }

    // Any demand that differs from the resident distribution requires a
    // redistribution before that loop.
    for d in &demands {
        let resident = &plan.resident[&d.relation];
        if &d.partitioning != resident {
            plan.redistributions.push((
                d.stmt_idx,
                d.relation.clone(),
                resident.clone(),
                d.partitioning.clone(),
            ));
        }
    }
    Ok(plan)
}

fn has_conflict(demands: &[LoopDemand]) -> bool {
    for a in demands {
        for b in demands {
            if a.relation == b.relation && a.partitioning != b.partitioning {
                return true;
            }
        }
    }
    false
}

fn part_key(p: &Partitioning) -> String {
    format!("{p:?}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArrayDecl, DataType, Expr, IndexSet, Loop, LoopKind, Schema, Stmt, Value};
    use crate::transform::parallelize_indirect;

    /// The §III-A4 program: two aggregations over `Table`, partitioned on
    /// different fields.
    fn conflicted_program() -> Program {
        let schema = Schema::new(vec![
            ("field1", DataType::Int),
            ("field2", DataType::Int),
        ]);
        let count = |arr: &str, f: &str| {
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::all("Table"),
                vec![Stmt::increment(arr, vec![Expr::field("i", f)])],
            ))
        };
        let mut p = Program::new("conflict")
            .with_relation("Table", schema)
            .with_array("count1", ArrayDecl::counter())
            .with_array("count2", ArrayDecl::counter())
            .with_result("R1", Schema::new(vec![("v", DataType::Int), ("n", DataType::Int)]))
            .with_result("R2", Schema::new(vec![("v", DataType::Int), ("n", DataType::Int)]));
        p.body = vec![count("count1", "field1"), count("count2", "field2")];
        // Keep results alive so DCE-style reasoning doesn't matter here.
        p.body.push(Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::distinct_of("Table", "field1"),
            vec![Stmt::result_union(
                "R1",
                vec![
                    Expr::field("i", "field1"),
                    Expr::array("count1", vec![Expr::field("i", "field1")]),
                ],
            )],
        )));
        p.body.push(Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::distinct_of("Table", "field2"),
            vec![Stmt::result_union(
                "R2",
                vec![
                    Expr::field("i", "field2"),
                    Expr::array("count2", vec![Expr::field("i", "field2")]),
                ],
            )],
        )));
        p
    }

    #[test]
    fn detects_demands_after_parallelization() {
        let mut p = conflicted_program();
        parallelize_indirect(&mut p, 0, "field1", 4).unwrap();
        parallelize_indirect(&mut p, 1, "field2", 4).unwrap();
        let demands = collect_demands(&p);
        assert_eq!(demands.len(), 2);
        assert_eq!(demands[0].partitioning, Partitioning::RangeKey("field1".into()));
        assert_eq!(demands[1].partitioning, Partitioning::RangeKey("field2".into()));
    }

    #[test]
    fn conflicting_partitionings_force_redistribution_without_fusion() {
        let mut p = conflicted_program();
        parallelize_indirect(&mut p, 0, "field1", 4).unwrap();
        parallelize_indirect(&mut p, 1, "field2", 4).unwrap();
        // Parallelized loops cannot fuse (different domains) — the
        // optimizer must schedule one redistribution.
        let plan = optimize(&mut p).unwrap();
        assert_eq!(plan.redistribution_count(), 1);
    }

    #[test]
    fn fusion_before_parallelization_avoids_redistribution() {
        // The paper's resolution: fuse FIRST (while the counting loops
        // still share a domain), then parallelize the fused loop once.
        let mut p = conflicted_program();
        let plan0 = optimize(&mut p).unwrap(); // triggers fusion path (no parallel loops yet → no conflict)
        assert_eq!(plan0.redistribution_count(), 0);
        crate::transform::LoopFusion
            .run(&mut p, &crate::transform::PassCtx::new())
            .unwrap();
        // One fused counting loop remains; parallelize it on field1.
        parallelize_indirect(&mut p, 0, "field1", 4).unwrap();
        let plan = optimize(&mut p).unwrap();
        assert_eq!(plan.redistribution_count(), 0);
        assert_eq!(
            plan.resident["Table"],
            Partitioning::RangeKey("field1".into())
        );
    }

    #[test]
    fn direct_blocking_demand_is_direct() {
        let mut p = conflicted_program();
        let _ = LoopKind::Forall;
        let _ = Value::Int(0);
        crate::transform::parallelize_direct(&mut p, 0, 4).unwrap();
        let demands = collect_demands(&p);
        assert_eq!(demands[0].partitioning, Partitioning::Direct);
    }
}
