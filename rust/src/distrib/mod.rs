//! The simulated cluster substrate: data partitioning, cost-accounted
//! communication, redistribution, and the data-distribution optimizer
//! (§III-A). Substitutes for the paper's DAS-4/MPI testbed per DESIGN.md.

pub mod comm;
pub mod distribution;
pub mod partition;
pub mod redistribute;

pub use comm::{channel, CommStats, LinkModel, Tx};
pub use distribution::{collect_demands, optimize, DistributionPlan, LoopDemand};
pub use partition::{
    hash_value, shard_bytes, split, split_direct, split_hash, split_range, tuple_bytes,
    Partitioning,
};
pub use redistribute::{estimated_cost_bytes, redistribute};
