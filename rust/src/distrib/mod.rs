//! The simulated cluster substrate: data partitioning, cost-accounted
//! communication, redistribution, and the data-distribution optimizer
//! (§III-A). Substitutes for the paper's DAS-4/MPI testbed per DESIGN.md.

pub mod comm;
pub mod distribution;
pub mod fault;
pub mod partition;
pub mod redistribute;

pub use comm::{channel, CommStats, LinkModel, Tx};
pub use distribution::{collect_demands, optimize, DistributionPlan, LoopDemand};
pub use fault::{Crash, FaultPlan, LostFlush, SlowWorker};
pub use partition::{
    hash_value, shard_bytes, split, split_direct, split_hash, split_range, tuple_bytes,
    Partitioning,
};
pub use redistribute::{
    detect_heavy_hitters, estimated_cost_bytes, redistribute, redistribute_skew, SkewPlan,
};
