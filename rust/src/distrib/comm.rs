//! Cost-accounted inter-node communication.
//!
//! The simulated cluster exchanges messages over in-process channels; this
//! module wraps them with byte/message accounting and a configurable
//! bandwidth/latency model so redistribution costs (§III-A4) show up in
//! measured time, not just in counters. (DAS-4's real interconnect is
//! substituted per DESIGN.md §Substitutions.)

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::Arc;
use std::time::Duration;

/// Global-ish communication statistics, shared by all channels of a run.
#[derive(Debug, Default)]
pub struct CommStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

impl CommStats {
    pub fn new() -> Arc<Self> {
        Arc::new(CommStats::default())
    }

    pub fn record(&self, bytes: usize) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn total_messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }
}

/// Network model: per-message latency + bandwidth delay, imposed by
/// busy-sleeping the *sender* (the simple, deterministic choice).
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    pub latency: Duration,
    /// Bytes per second; u64::MAX disables the bandwidth delay.
    pub bytes_per_sec: u64,
}

impl Default for LinkModel {
    fn default() -> Self {
        // Loosely GbE-flavoured: 50µs latency, ~1 GiB/s.
        LinkModel {
            latency: Duration::from_micros(50),
            bytes_per_sec: 1 << 30,
        }
    }
}

impl LinkModel {
    /// Instantaneous (no delay) — for unit tests.
    pub fn instant() -> Self {
        LinkModel {
            latency: Duration::ZERO,
            bytes_per_sec: u64::MAX,
        }
    }

    pub fn delay_for(&self, bytes: usize) -> Duration {
        if self.bytes_per_sec == u64::MAX {
            return self.latency;
        }
        self.latency + Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec as f64)
    }
}

/// A sending endpoint with accounting + delay model.
pub struct Tx<T> {
    inner: SyncSender<T>,
    stats: Arc<CommStats>,
    model: LinkModel,
}

impl<T> Clone for Tx<T> {
    fn clone(&self) -> Self {
        Tx {
            inner: self.inner.clone(),
            stats: self.stats.clone(),
            model: self.model,
        }
    }
}

impl<T> Tx<T> {
    /// Send `msg`, charging `bytes` to the accounting + delay model.
    /// Returns false if the receiver hung up.
    pub fn send(&self, msg: T, bytes: usize) -> bool {
        self.stats.record(bytes);
        let d = self.model.delay_for(bytes);
        if !d.is_zero() {
            std::thread::sleep(d);
        }
        self.inner.send(msg).is_ok()
    }
}

/// Create an accounted bounded channel (bounded = backpressure: a slow
/// consumer stalls producers, exactly like a full TCP window).
pub fn channel<T>(
    capacity: usize,
    stats: Arc<CommStats>,
    model: LinkModel,
) -> (Tx<T>, Receiver<T>) {
    let (tx, rx) = std::sync::mpsc::sync_channel(capacity);
    (
        Tx {
            inner: tx,
            stats,
            model,
        },
        rx,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_accumulates() {
        let stats = CommStats::new();
        let (tx, rx) = channel::<u32>(8, stats.clone(), LinkModel::instant());
        assert!(tx.send(1, 100));
        assert!(tx.send(2, 250));
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(stats.total_bytes(), 350);
        assert_eq!(stats.total_messages(), 2);
    }

    #[test]
    fn send_reports_disconnect() {
        let stats = CommStats::new();
        let (tx, rx) = channel::<u32>(1, stats, LinkModel::instant());
        drop(rx);
        assert!(!tx.send(1, 10));
    }

    #[test]
    fn bandwidth_model_delays() {
        let m = LinkModel {
            latency: Duration::from_millis(1),
            bytes_per_sec: 1_000_000,
        };
        let d = m.delay_for(500_000);
        assert!(d >= Duration::from_millis(500));
    }

    #[test]
    fn bounded_channel_applies_backpressure() {
        let stats = CommStats::new();
        let (tx, rx) = channel::<u32>(2, stats, LinkModel::instant());
        assert!(tx.send(1, 1));
        assert!(tx.send(2, 1));
        // Third send would block; verify via try-style workaround: consume
        // one, then the next send proceeds.
        let h = std::thread::spawn(move || tx.send(3, 1));
        assert_eq!(rx.recv().unwrap(), 1);
        assert!(h.join().unwrap());
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv().unwrap(), 3);
    }
}
