//! Data partitioning of physical tables (§III-A1).
//!
//! * direct: contiguous row blocks (`pA = p_1A ∪ ... ∪ p_NA`);
//! * by key: tuples routed by a field's value (hash or sorted-range) —
//!   the physical counterpart of indirect partitioning, where processor
//!   `P_k` owns the tuples whose field value falls in its segment.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

use anyhow::Result;

use crate::exec::block_bounds;
use crate::ir::{Multiset, Value};
use crate::storage::Table;

/// How a relation is distributed over nodes.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Partitioning {
    /// Not distributed (replicated or leader-resident).
    None,
    /// Contiguous row blocks.
    Direct,
    /// By hash of a field.
    HashKey(String),
    /// By sorted value-range segments of a field.
    RangeKey(String),
}

/// Split a table into `n` contiguous row-block shards (direct).
pub fn split_direct(t: &Table, n: usize) -> Vec<Table> {
    let m = t.to_multiset();
    let mut out = Vec::with_capacity(n);
    for k in 0..n {
        let (lo, hi) = block_bounds(t.len(), n, k);
        let mut part = Multiset::new(t.schema.clone());
        for row in lo..hi {
            part.push(m.rows()[row].clone());
        }
        out.push(Table::from_multiset(&part).expect("schema invariant"));
    }
    out
}

/// Split a table into `n` shards by hash of `field`.
pub fn split_hash(t: &Table, field: usize, n: usize) -> Vec<Table> {
    let mut parts: Vec<Multiset> = (0..n).map(|_| Multiset::new(t.schema.clone())).collect();
    for row in 0..t.len() {
        let v = t.value(row, field);
        let k = hash_value(&v) as usize % n;
        parts[k].push(t.tuple(row));
    }
    parts
        .iter()
        .map(|m| Table::from_multiset(m).expect("schema invariant"))
        .collect()
}

/// Split by sorted value-range segments of `field` (the X_k partitioning).
pub fn split_range(t: &Table, field: usize, n: usize) -> Result<Vec<Table>> {
    // Sort the distinct values, chunk them, route rows by segment.
    let mut distinct: Vec<Value> = {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for row in 0..t.len() {
            let v = t.value(row, field);
            if seen.insert(v.clone()) {
                out.push(v);
            }
        }
        out
    };
    distinct.sort();
    let mut seg_of = std::collections::HashMap::new();
    for k in 0..n {
        let (lo, hi) = block_bounds(distinct.len(), n, k);
        for v in &distinct[lo..hi] {
            seg_of.insert(v.clone(), k);
        }
    }
    let mut parts: Vec<Multiset> = (0..n).map(|_| Multiset::new(t.schema.clone())).collect();
    for row in 0..t.len() {
        let v = t.value(row, field);
        parts[seg_of[&v]].push(t.tuple(row));
    }
    Ok(parts
        .iter()
        .map(|m| Table::from_multiset(m).expect("schema invariant"))
        .collect())
}

/// Apply a `Partitioning` to a table.
pub fn split(t: &Table, p: &Partitioning, n: usize) -> Result<Vec<Table>> {
    Ok(match p {
        Partitioning::None => {
            // Replicate the full table on every node.
            (0..n).map(|_| t.clone()).collect()
        }
        Partitioning::Direct => split_direct(t, n),
        Partitioning::HashKey(f) => {
            let fid = t
                .schema
                .field_id(f)
                .ok_or_else(|| anyhow::anyhow!("no field `{f}`"))?;
            split_hash(t, fid, n)
        }
        Partitioning::RangeKey(f) => {
            let fid = t
                .schema
                .field_id(f)
                .ok_or_else(|| anyhow::anyhow!("no field `{f}`"))?;
            split_range(t, fid, n)?
        }
    })
}

/// Stable hash of a value (used for hash partitioning and shuffles).
pub fn hash_value(v: &Value) -> u64 {
    let mut h = DefaultHasher::new();
    v.hash(&mut h);
    h.finish()
}

/// Approximate wire size of one tuple (comm cost accounting).
pub fn tuple_bytes(t: &[Value]) -> usize {
    t.iter()
        .map(|v| match v {
            Value::Int(_) | Value::Float(_) => 9,
            Value::Str(s) => 5 + s.len(),
            Value::Bool(_) => 2,
            Value::Null => 1,
        })
        .sum()
}

/// Approximate wire size of a whole shard.
pub fn shard_bytes(t: &Table) -> usize {
    (0..t.len()).map(|r| tuple_bytes(&t.tuple(r))).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Schema};
    use std::sync::Arc as StdArc;

    fn table(n: usize, keys: usize) -> Table {
        let schema = Schema::new(vec![("k", DataType::Int), ("v", DataType::Int)]);
        let mut m = Multiset::new(schema);
        for i in 0..n {
            m.push(vec![Value::Int((i % keys) as i64), Value::Int(i as i64)]);
        }
        Table::from_multiset(&m).unwrap()
    }

    fn total_rows(parts: &[Table]) -> usize {
        parts.iter().map(|t| t.len()).sum()
    }

    #[test]
    fn direct_split_is_contiguous_and_complete() {
        let t = table(103, 10);
        let parts = split_direct(&t, 4);
        assert_eq!(parts.len(), 4);
        assert_eq!(total_rows(&parts), 103);
        // First block gets the remainder rows.
        assert_eq!(parts[0].len(), 26);
    }

    #[test]
    fn hash_split_keeps_same_key_together() {
        let t = table(1000, 16);
        let parts = split_hash(&t, 0, 4);
        assert_eq!(total_rows(&parts), 1000);
        // Every key must appear in exactly one shard.
        let mut owner: std::collections::HashMap<i64, usize> = Default::default();
        for (s, p) in parts.iter().enumerate() {
            for row in 0..p.len() {
                let k = p.value(row, 0).as_int().unwrap();
                if let Some(prev) = owner.insert(k, s) {
                    assert_eq!(prev, s, "key {k} split across shards");
                }
            }
        }
    }

    #[test]
    fn range_split_orders_segments() {
        let t = table(1000, 100);
        let parts = split_range(&t, 0, 4).unwrap();
        assert_eq!(total_rows(&parts), 1000);
        // Max key of shard s < min key of shard s+1.
        let bounds: Vec<(i64, i64)> = parts
            .iter()
            .map(|p| {
                let ks: Vec<i64> = (0..p.len()).map(|r| p.value(r, 0).as_int().unwrap()).collect();
                (*ks.iter().min().unwrap(), *ks.iter().max().unwrap())
            })
            .collect();
        for w in bounds.windows(2) {
            assert!(w[0].1 < w[1].0, "{bounds:?}");
        }
    }

    #[test]
    fn replicate_copies_everything() {
        let t = table(10, 3);
        let parts = split(&t, &Partitioning::None, 3).unwrap();
        assert!(parts.iter().all(|p| p.len() == 10));
    }

    #[test]
    fn tuple_bytes_scales_with_strings() {
        let small = tuple_bytes(&[Value::Int(1)]);
        let big = tuple_bytes(&[Value::str("x".repeat(100))]);
        assert!(big > small * 5);
    }

    #[test]
    fn hash_value_consistent_with_eq() {
        assert_eq!(hash_value(&Value::Int(3)), hash_value(&Value::Float(3.0)));
        let _ = StdArc::new(()); // silence unused-import lint paranoia
    }
}
