//! Physical redistribution (shuffle) between partitionings, with its
//! communication cost accounted — the "expensive data re-distribution"
//! §III-A4 teaches the compiler to avoid.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::exec::block_bounds;
use crate::ir::{Multiset, Value};
use crate::storage::Table;

use super::comm::CommStats;
use super::partition::{hash_value, shard_bytes, tuple_bytes, Partitioning};

/// Redistribute shards to the `target` partitioning, charging every tuple
/// that crosses nodes to `stats`. Tuples already resident on their target
/// node are not charged (they never touch the network).
pub fn redistribute(
    shards: &[Table],
    target: &Partitioning,
    stats: &Arc<CommStats>,
) -> Result<Vec<Table>> {
    let n = shards.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let schema = shards[0].schema.clone();
    let total_rows: usize = shards.iter().map(|t| t.len()).sum();

    // Routing function: tuple + global position → target node.
    let field_id = |f: &str| -> Result<usize> {
        schema
            .field_id(f)
            .ok_or_else(|| anyhow::anyhow!("no field `{f}`"))
    };
    enum Router {
        Direct,
        Hash(usize),
        Range(usize, HashMap<Value, usize>),
        Replicate,
    }
    let router = match target {
        Partitioning::None => Router::Replicate,
        Partitioning::Direct => Router::Direct,
        Partitioning::HashKey(f) => Router::Hash(field_id(f)?),
        Partitioning::RangeKey(f) => {
            let fid = field_id(f)?;
            // Global sorted distinct values → segment map.
            let mut distinct: Vec<Value> = {
                let mut seen = std::collections::HashSet::new();
                let mut out = Vec::new();
                for t in shards {
                    for row in 0..t.len() {
                        let v = t.value(row, fid);
                        if seen.insert(v.clone()) {
                            out.push(v);
                        }
                    }
                }
                out
            };
            distinct.sort();
            let mut seg = HashMap::new();
            for k in 0..n {
                let (lo, hi) = block_bounds(distinct.len(), n, k);
                for v in &distinct[lo..hi] {
                    seg.insert(v.clone(), k);
                }
            }
            Router::Range(fid, seg)
        }
    };

    if let Router::Replicate = router {
        // Everything crosses to every other node.
        let total: usize = shards.iter().map(shard_bytes).sum();
        stats.record(total * (n - 1));
        let mut union = Multiset::new(schema.clone());
        for t in shards {
            for row in 0..t.len() {
                union.push(t.tuple(row));
            }
        }
        let full = Table::from_multiset(&union)?;
        return Ok((0..n).map(|_| full.clone()).collect());
    }

    let mut parts: Vec<Multiset> = (0..n).map(|_| Multiset::new(schema.clone())).collect();
    let mut moved = 0usize;
    let mut global = 0usize;
    for (src, t) in shards.iter().enumerate() {
        for row in 0..t.len() {
            let tuple = t.tuple(row);
            let dst = match &router {
                Router::Direct => {
                    // Target: contiguous blocks of the concatenated order.
                    let mut node = n - 1;
                    for k in 0..n {
                        let (lo, hi) = block_bounds(total_rows, n, k);
                        if global >= lo && global < hi {
                            node = k;
                            break;
                        }
                    }
                    node
                }
                Router::Hash(fid) => (hash_value(&tuple[*fid]) % n as u64) as usize,
                Router::Range(fid, seg) => *seg
                    .get(&tuple[*fid])
                    .ok_or_else(|| anyhow::anyhow!("value missing from segment map"))?,
                Router::Replicate => unreachable!(),
            };
            if dst != src {
                moved += tuple_bytes(&tuple);
            }
            parts[dst].push(tuple);
            global += 1;
        }
    }
    stats.record(moved);
    parts
        .iter()
        .map(|m| Table::from_multiset(m))
        .collect::<Result<Vec<_>>>()
}

/// The up-front cost estimate the distribution optimizer compares against
/// recompute: full shard volume minus the expected resident fraction.
pub fn estimated_cost_bytes(shards: &[Table]) -> usize {
    let total: usize = shards.iter().map(shard_bytes).sum();
    if shards.is_empty() {
        return 0;
    }
    total - total / shards.len()
}

/// Sanity check used by tests and the fusion bench.
pub fn total_rows(shards: &[Table]) -> usize {
    shards.iter().map(|t| t.len()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distrib::partition::{split_direct, split_range};
    use crate::ir::{DataType, Schema};

    fn shards() -> Vec<Table> {
        let schema = Schema::new(vec![("k", DataType::Int), ("j", DataType::Int)]);
        let mut m = Multiset::new(schema);
        for i in 0..100i64 {
            m.push(vec![Value::Int(i % 10), Value::Int((i * 7) % 10)]);
        }
        let t = Table::from_multiset(&m).unwrap();
        split_direct(&t, 4)
    }

    #[test]
    fn redistribution_preserves_all_tuples_and_colocates_keys() {
        let stats = CommStats::new();
        let out = redistribute(&shards(), &Partitioning::HashKey("k".into()), &stats).unwrap();
        assert_eq!(total_rows(&out), 100);
        let mut owner: std::collections::HashMap<i64, usize> = Default::default();
        for (s, t) in out.iter().enumerate() {
            for row in 0..t.len() {
                let k = t.value(row, 0).as_int().unwrap();
                if let Some(prev) = owner.insert(k, s) {
                    assert_eq!(prev, s, "key {k} split across shards");
                }
            }
        }
    }

    #[test]
    fn conflicting_repartition_charges_most_tuples() {
        // Resident on range(k); moving to range(j) must move ~(n-1)/n of
        // the data — the §III-A4 "expensive redistribution".
        let base = {
            let merged = shards();
            let mut union = Multiset::new(merged[0].schema.clone());
            for t in &merged {
                for r in 0..t.len() {
                    union.push(t.tuple(r));
                }
            }
            Table::from_multiset(&union).unwrap()
        };
        let resident = split_range(&base, 0, 4).unwrap();
        let stats = CommStats::new();
        let _ = redistribute(&resident, &Partitioning::RangeKey("j".into()), &stats).unwrap();
        let total: usize = resident.iter().map(shard_bytes).sum();
        let moved = stats.total_bytes() as usize;
        assert!(
            moved > total / 2,
            "expected most bytes to move: {moved} of {total}"
        );
    }

    #[test]
    fn same_partitioning_is_nearly_free() {
        let base = {
            let merged = shards();
            let mut union = Multiset::new(merged[0].schema.clone());
            for t in &merged {
                for r in 0..t.len() {
                    union.push(t.tuple(r));
                }
            }
            Table::from_multiset(&union).unwrap()
        };
        let resident = split_range(&base, 0, 4).unwrap();
        let stats = CommStats::new();
        let out = redistribute(&resident, &Partitioning::RangeKey("k".into()), &stats).unwrap();
        assert_eq!(total_rows(&out), 100);
        assert_eq!(stats.total_bytes(), 0, "no tuple should move");
    }

    #[test]
    fn replicate_charges_full_broadcast() {
        let stats = CommStats::new();
        let out = redistribute(&shards(), &Partitioning::None, &stats).unwrap();
        assert!(out.iter().all(|t| t.len() == 100));
        assert!(stats.total_bytes() > 0);
    }

    #[test]
    fn estimate_is_positive_and_below_total() {
        let s = shards();
        let est = estimated_cost_bytes(&s);
        let total: usize = s.iter().map(shard_bytes).sum();
        assert!(est > 0 && est < total);
    }
}
