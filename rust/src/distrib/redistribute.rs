//! Physical redistribution (shuffle) between partitionings, with its
//! communication cost accounted — the "expensive data re-distribution"
//! §III-A4 teaches the compiler to avoid.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use anyhow::Result;

use crate::exec::block_bounds;
use crate::ir::{Multiset, Value};
use crate::storage::{ColumnStats, Table};

use super::comm::CommStats;
use super::partition::{hash_value, shard_bytes, tuple_bytes, Partitioning};

/// The heavy hitters of one key column: values whose row count exceeds a
/// fair-share threshold, i.e. keys a plain hash partitioning would pile
/// onto one node. Produced by [`detect_heavy_hitters`], consumed by
/// [`redistribute_skew`] and the coordinator's shuffle join.
#[derive(Debug, Clone, Default)]
pub struct SkewPlan {
    /// The key field the plan describes.
    pub field: String,
    /// Hot `(value, row_count)` pairs, heaviest first.
    pub hot: Vec<(Value, u64)>,
    /// The row-count bar a key had to clear to be listed.
    pub threshold: u64,
}

impl SkewPlan {
    pub fn is_empty(&self) -> bool {
        self.hot.is_empty()
    }

    pub fn is_hot(&self, v: &Value) -> bool {
        self.hot.iter().any(|(h, _)| h == v)
    }

    /// Short human-readable summary for `Engine::explain` details.
    pub fn render(&self) -> String {
        let keys: Vec<String> = self
            .hot
            .iter()
            .map(|(v, n)| format!("{v:?}×{n}"))
            .collect();
        format!("threshold={} hot=[{}]", self.threshold, keys.join(", "))
    }
}

/// Detect heavy-hitter values of `table.field` using the column's
/// statistics to keep the scan cheap: a value's count can never exceed
/// its histogram bucket's count, so buckets below the threshold are
/// pruned before any exact counting; low-NDV columns (the usual join-key
/// shape — dictionary NDV is exact) fall back to a full count pass; a
/// high-NDV column with no histogram cannot concentrate mass and reports
/// no skew.
///
/// The threshold is half a node's fair share, `rows / (2·nodes)`: a key
/// above it visibly unbalances a hash partitioning over `nodes`.
pub fn detect_heavy_hitters(
    table: &Table,
    field: &str,
    stats: &ColumnStats,
    nodes: usize,
) -> Result<SkewPlan> {
    let fid = table
        .schema
        .field_id(field)
        .ok_or_else(|| anyhow::anyhow!("no field `{field}`"))?;
    let rows = table.len() as u64;
    let mut plan = SkewPlan {
        field: field.to_string(),
        hot: Vec::new(),
        threshold: (rows / (2 * nodes.max(1) as u64)).max(2),
    };
    if rows == 0 || nodes < 2 {
        return Ok(plan);
    }

    // Which rows are worth counting exactly?
    enum Scan {
        /// Count every value (low NDV: the count map stays small).
        Full,
        /// Count only values landing in histogram buckets that could
        /// hold a heavy hitter.
        Buckets { lo: f64, width: f64, hot: Vec<bool> },
        /// No concentration possible.
        Skip,
    }
    let scan = if stats.ndv <= nodes as u64 * 64 {
        Scan::Full
    } else if let Some(h) = &stats.histogram {
        let hot: Vec<bool> = h.counts.iter().map(|&c| c >= plan.threshold).collect();
        if hot.iter().any(|&b| b) {
            let width = (h.hi - h.lo) / h.counts.len() as f64;
            Scan::Buckets { lo: h.lo, width, hot }
        } else {
            Scan::Skip
        }
    } else {
        Scan::Skip
    };

    let mut counts: HashMap<Value, u64> = HashMap::new();
    match scan {
        Scan::Skip => return Ok(plan),
        Scan::Full => {
            for row in 0..table.len() {
                *counts.entry(table.value(row, fid)).or_insert(0) += 1;
            }
        }
        Scan::Buckets { lo, width, hot } => {
            for row in 0..table.len() {
                let v = table.value(row, fid);
                let x = match &v {
                    Value::Int(i) => *i as f64,
                    Value::Float(f) => *f,
                    _ => continue,
                };
                let idx = (((x - lo) / width) as usize).min(hot.len() - 1);
                if hot[idx] {
                    *counts.entry(v).or_insert(0) += 1;
                }
            }
        }
    }
    plan.hot = counts
        .into_iter()
        .filter(|&(_, n)| n >= plan.threshold)
        .collect();
    plan.hot.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    Ok(plan)
}

/// Hash-redistribute `shards` on `field`, except that rows carrying a
/// hot key from `plan` are *salted*: dealt round-robin across all nodes
/// instead of hashed, splitting each hot partition into per-node
/// sub-shards (the coordinator merges the sub-aggregates, so correctness
/// is unaffected). Moved tuples are charged to `stats` exactly like
/// [`redistribute`].
pub fn redistribute_skew(
    shards: &[Table],
    field: &str,
    plan: &SkewPlan,
    stats: &Arc<CommStats>,
) -> Result<Vec<Table>> {
    let n = shards.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let schema = shards[0].schema.clone();
    let fid = schema
        .field_id(field)
        .ok_or_else(|| anyhow::anyhow!("no field `{field}`"))?;
    let hot: HashSet<&Value> = plan.hot.iter().map(|(v, _)| v).collect();
    let mut parts: Vec<Multiset> = (0..n).map(|_| Multiset::new(schema.clone())).collect();
    let mut moved = 0usize;
    let mut salt = 0usize;
    for (src, t) in shards.iter().enumerate() {
        for row in 0..t.len() {
            let tuple = t.tuple(row);
            let dst = if hot.contains(&tuple[fid]) {
                salt += 1;
                (salt - 1) % n
            } else {
                (hash_value(&tuple[fid]) % n as u64) as usize
            };
            if dst != src {
                moved += tuple_bytes(&tuple);
            }
            parts[dst].push(tuple);
        }
    }
    stats.record(moved);
    parts
        .iter()
        .map(|m| Table::from_multiset(m))
        .collect::<Result<Vec<_>>>()
}

/// Redistribute shards to the `target` partitioning, charging every tuple
/// that crosses nodes to `stats`. Tuples already resident on their target
/// node are not charged (they never touch the network).
pub fn redistribute(
    shards: &[Table],
    target: &Partitioning,
    stats: &Arc<CommStats>,
) -> Result<Vec<Table>> {
    let n = shards.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let schema = shards[0].schema.clone();
    let total_rows: usize = shards.iter().map(|t| t.len()).sum();

    // Routing function: tuple + global position → target node.
    let field_id = |f: &str| -> Result<usize> {
        schema
            .field_id(f)
            .ok_or_else(|| anyhow::anyhow!("no field `{f}`"))
    };
    enum Router {
        Direct,
        Hash(usize),
        Range(usize, HashMap<Value, usize>),
        Replicate,
    }
    let router = match target {
        Partitioning::None => Router::Replicate,
        Partitioning::Direct => Router::Direct,
        Partitioning::HashKey(f) => Router::Hash(field_id(f)?),
        Partitioning::RangeKey(f) => {
            let fid = field_id(f)?;
            // Global sorted distinct values → segment map.
            let mut distinct: Vec<Value> = {
                let mut seen = std::collections::HashSet::new();
                let mut out = Vec::new();
                for t in shards {
                    for row in 0..t.len() {
                        let v = t.value(row, fid);
                        if seen.insert(v.clone()) {
                            out.push(v);
                        }
                    }
                }
                out
            };
            distinct.sort();
            let mut seg = HashMap::new();
            for k in 0..n {
                let (lo, hi) = block_bounds(distinct.len(), n, k);
                for v in &distinct[lo..hi] {
                    seg.insert(v.clone(), k);
                }
            }
            Router::Range(fid, seg)
        }
    };

    if let Router::Replicate = router {
        // Everything crosses to every other node.
        let total: usize = shards.iter().map(shard_bytes).sum();
        stats.record(total * (n - 1));
        let mut union = Multiset::new(schema.clone());
        for t in shards {
            for row in 0..t.len() {
                union.push(t.tuple(row));
            }
        }
        let full = Table::from_multiset(&union)?;
        return Ok((0..n).map(|_| full.clone()).collect());
    }

    let mut parts: Vec<Multiset> = (0..n).map(|_| Multiset::new(schema.clone())).collect();
    let mut moved = 0usize;
    let mut global = 0usize;
    for (src, t) in shards.iter().enumerate() {
        for row in 0..t.len() {
            let tuple = t.tuple(row);
            let dst = match &router {
                Router::Direct => {
                    // Target: contiguous blocks of the concatenated order.
                    let mut node = n - 1;
                    for k in 0..n {
                        let (lo, hi) = block_bounds(total_rows, n, k);
                        if global >= lo && global < hi {
                            node = k;
                            break;
                        }
                    }
                    node
                }
                Router::Hash(fid) => (hash_value(&tuple[*fid]) % n as u64) as usize,
                Router::Range(fid, seg) => *seg
                    .get(&tuple[*fid])
                    .ok_or_else(|| anyhow::anyhow!("value missing from segment map"))?,
                Router::Replicate => unreachable!(),
            };
            if dst != src {
                moved += tuple_bytes(&tuple);
            }
            parts[dst].push(tuple);
            global += 1;
        }
    }
    stats.record(moved);
    parts
        .iter()
        .map(|m| Table::from_multiset(m))
        .collect::<Result<Vec<_>>>()
}

/// The up-front cost estimate the distribution optimizer compares against
/// recompute: full shard volume minus the expected resident fraction.
pub fn estimated_cost_bytes(shards: &[Table]) -> usize {
    let total: usize = shards.iter().map(shard_bytes).sum();
    if shards.is_empty() {
        return 0;
    }
    total - total / shards.len()
}

/// Sanity check used by tests and the fusion bench.
pub fn total_rows(shards: &[Table]) -> usize {
    shards.iter().map(|t| t.len()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distrib::partition::{split_direct, split_range};
    use crate::ir::{DataType, Schema};

    fn shards() -> Vec<Table> {
        let schema = Schema::new(vec![("k", DataType::Int), ("j", DataType::Int)]);
        let mut m = Multiset::new(schema);
        for i in 0..100i64 {
            m.push(vec![Value::Int(i % 10), Value::Int((i * 7) % 10)]);
        }
        let t = Table::from_multiset(&m).unwrap();
        split_direct(&t, 4)
    }

    #[test]
    fn redistribution_preserves_all_tuples_and_colocates_keys() {
        let stats = CommStats::new();
        let out = redistribute(&shards(), &Partitioning::HashKey("k".into()), &stats).unwrap();
        assert_eq!(total_rows(&out), 100);
        let mut owner: std::collections::HashMap<i64, usize> = Default::default();
        for (s, t) in out.iter().enumerate() {
            for row in 0..t.len() {
                let k = t.value(row, 0).as_int().unwrap();
                if let Some(prev) = owner.insert(k, s) {
                    assert_eq!(prev, s, "key {k} split across shards");
                }
            }
        }
    }

    #[test]
    fn conflicting_repartition_charges_most_tuples() {
        // Resident on range(k); moving to range(j) must move ~(n-1)/n of
        // the data — the §III-A4 "expensive redistribution".
        let base = {
            let merged = shards();
            let mut union = Multiset::new(merged[0].schema.clone());
            for t in &merged {
                for r in 0..t.len() {
                    union.push(t.tuple(r));
                }
            }
            Table::from_multiset(&union).unwrap()
        };
        let resident = split_range(&base, 0, 4).unwrap();
        let stats = CommStats::new();
        let _ = redistribute(&resident, &Partitioning::RangeKey("j".into()), &stats).unwrap();
        let total: usize = resident.iter().map(shard_bytes).sum();
        let moved = stats.total_bytes() as usize;
        assert!(
            moved > total / 2,
            "expected most bytes to move: {moved} of {total}"
        );
    }

    #[test]
    fn same_partitioning_is_nearly_free() {
        let base = {
            let merged = shards();
            let mut union = Multiset::new(merged[0].schema.clone());
            for t in &merged {
                for r in 0..t.len() {
                    union.push(t.tuple(r));
                }
            }
            Table::from_multiset(&union).unwrap()
        };
        let resident = split_range(&base, 0, 4).unwrap();
        let stats = CommStats::new();
        let out = redistribute(&resident, &Partitioning::RangeKey("k".into()), &stats).unwrap();
        assert_eq!(total_rows(&out), 100);
        assert_eq!(stats.total_bytes(), 0, "no tuple should move");
    }

    #[test]
    fn replicate_charges_full_broadcast() {
        let stats = CommStats::new();
        let out = redistribute(&shards(), &Partitioning::None, &stats).unwrap();
        assert!(out.iter().all(|t| t.len() == 100));
        assert!(stats.total_bytes() > 0);
    }

    #[test]
    fn estimate_is_positive_and_below_total() {
        let s = shards();
        let est = estimated_cost_bytes(&s);
        let total: usize = s.iter().map(shard_bytes).sum();
        assert!(est > 0 && est < total);
    }

    /// One key holds `hot_frac` of the rows; the rest spread uniformly.
    fn skewed_table(n: usize, hot_frac: f64, cold_keys: i64) -> Table {
        let schema = Schema::new(vec![("k", DataType::Int), ("v", DataType::Int)]);
        let mut m = Multiset::new(schema);
        let hot_rows = (n as f64 * hot_frac) as usize;
        for i in 0..n {
            let k = if i < hot_rows {
                0
            } else {
                1 + (i as i64 % cold_keys)
            };
            m.push(vec![Value::Int(k), Value::Int(i as i64)]);
        }
        Table::from_multiset(&m).unwrap()
    }

    #[test]
    fn heavy_hitters_found_on_skew_and_absent_on_uniform() {
        use crate::storage::ColumnStats;
        let skewed = skewed_table(4000, 0.5, 100);
        let stats = ColumnStats::collect(&skewed, 0);
        let plan = detect_heavy_hitters(&skewed, "k", &stats, 4).unwrap();
        assert!(!plan.is_empty());
        assert_eq!(plan.hot[0].0, Value::Int(0), "{plan:?}");
        assert!(plan.hot[0].1 >= 2000);
        assert!(plan.is_hot(&Value::Int(0)) && !plan.is_hot(&Value::Int(7)));

        // Uniform keys: nothing clears half a node's fair share.
        let uniform = skewed_table(4000, 0.0, 100);
        let stats = ColumnStats::collect(&uniform, 0);
        let plan = detect_heavy_hitters(&uniform, "k", &stats, 4).unwrap();
        assert!(plan.is_empty(), "{plan:?}");
    }

    #[test]
    fn histogram_pruning_skips_high_ndv_uniform_columns() {
        use crate::storage::ColumnStats;
        // NDV far above nodes×64 and no bucket concentration: the
        // detector must bail without building a count map.
        let schema = Schema::new(vec![("k", DataType::Int)]);
        let mut m = Multiset::new(schema);
        for i in 0..20_000i64 {
            m.push(vec![Value::Int(i)]);
        }
        let t = Table::from_multiset(&m).unwrap();
        let stats = ColumnStats::collect(&t, 0);
        assert!(stats.ndv > 4 * 64);
        let plan = detect_heavy_hitters(&t, "k", &stats, 4).unwrap();
        assert!(plan.is_empty());
    }

    #[test]
    fn salted_redistribution_balances_hot_keys() {
        use crate::storage::ColumnStats;
        let base = skewed_table(4000, 0.6, 100);
        let stats_col = ColumnStats::collect(&base, 0);
        let plan = detect_heavy_hitters(&base, "k", &stats_col, 4).unwrap();
        assert!(!plan.is_empty());
        let resident = split_direct(&base, 4);

        // Plain hash routing piles the hot key onto one node…
        let comm = CommStats::new();
        let hashed = redistribute(&resident, &Partitioning::HashKey("k".into()), &comm).unwrap();
        let hashed_max = hashed.iter().map(|t| t.len()).max().unwrap();
        assert!(hashed_max >= 2400, "hot key must dominate one shard");

        // …salting deals it round-robin: near-perfect balance.
        let comm = CommStats::new();
        let salted = redistribute_skew(&resident, "k", &plan, &comm).unwrap();
        assert_eq!(total_rows(&salted), 4000);
        let salted_max = salted.iter().map(|t| t.len()).max().unwrap();
        assert!(
            salted_max < hashed_max / 2,
            "salting must at least halve the hottest shard: {salted_max} vs {hashed_max}"
        );
        assert!(comm.total_bytes() > 0, "moved tuples must be charged");

        // Cold keys stay colocated (only hot keys are salted).
        let mut owner: std::collections::HashMap<i64, usize> = Default::default();
        for (s, t) in salted.iter().enumerate() {
            for row in 0..t.len() {
                let k = t.value(row, 0).as_int().unwrap();
                if k == 0 {
                    continue;
                }
                if let Some(prev) = owner.insert(k, s) {
                    assert_eq!(prev, s, "cold key {k} split across shards");
                }
            }
        }
        // The hot key lands on every shard.
        let hot_shards = salted
            .iter()
            .filter(|t| (0..t.len()).any(|r| t.value(r, 0) == Value::Int(0)))
            .count();
        assert_eq!(hot_shards, 4);
    }
}
