//! Deterministic fault and latency injection for the simulated cluster.
//!
//! A [`FaultPlan`] is the *entire* failure schedule of one distributed
//! run, fixed up front: which workers crash (and after how many chunks),
//! which workers run slow (latency multipliers), and which flushed
//! partials are dropped in transit. The coordinator and the Hadoop
//! simulator both consume the plan, so every resilience path — per-chunk
//! retry, straggler speculation, lost-result recovery, whole-job restart
//! — is reproducible from a seed: the same plan always exercises the
//! same recovery code and yields the same counters.
//!
//! Latency multipliers double as the straggler-detection signal: workers
//! report virtual cost units (`chunk rows × multiplier`) alongside wall
//! time, so detection thresholds compare exact injected ratios instead
//! of noisy wall-clock measurements. Tests stay deterministic; the wall
//! clock still slows down (the worker sleeps the extra time) so benches
//! see the real effect.

use crate::util::Rng;

/// A worker crash: the node dies when handed its next chunk after
/// completing `after_chunks`, taking its in-flight chunk and any
/// unflushed local partials with it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Crash {
    pub worker: usize,
    pub after_chunks: usize,
}

/// A slow worker: every chunk takes `multiplier ×` its normal time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlowWorker {
    pub worker: usize,
    pub multiplier: f64,
}

/// A lost result: the `nth_flush`-th (0-based) partial a worker flushes
/// is dropped in transit — the worker believes it delivered, the leader
/// never merges it and must re-queue the covered chunks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LostFlush {
    pub worker: usize,
    pub nth_flush: usize,
}

/// The full seeded failure schedule of one distributed run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub crashes: Vec<Crash>,
    pub slow: Vec<SlowWorker>,
    pub lost_flushes: Vec<LostFlush>,
}

impl FaultPlan {
    /// The empty plan: no faults.
    pub fn none() -> Self {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty() && self.slow.is_empty() && self.lost_flushes.is_empty()
    }

    /// Add a crash of `worker` after it completes `after_chunks` chunks.
    pub fn crash(mut self, worker: usize, after_chunks: usize) -> Self {
        self.crashes.push(Crash {
            worker,
            after_chunks,
        });
        self
    }

    /// Add a latency multiplier (`>= 1.0`) for `worker`.
    pub fn slow(mut self, worker: usize, multiplier: f64) -> Self {
        self.slow.push(SlowWorker {
            worker,
            multiplier: multiplier.max(1.0),
        });
        self
    }

    /// Drop `worker`'s `nth_flush`-th flushed partial in transit.
    pub fn lose_flush(mut self, worker: usize, nth_flush: usize) -> Self {
        self.lost_flushes.push(LostFlush { worker, nth_flush });
        self
    }

    /// The crash scheduled for `worker`, if any (first match wins).
    pub fn crash_of(&self, worker: usize) -> Option<Crash> {
        self.crashes.iter().copied().find(|c| c.worker == worker)
    }

    /// The latency multiplier for `worker` (1.0 = full speed).
    pub fn multiplier_of(&self, worker: usize) -> f64 {
        self.slow
            .iter()
            .filter(|s| s.worker == worker)
            .map(|s| s.multiplier)
            .fold(1.0, f64::max)
    }

    /// True when `worker`'s `nth`-th flush (0-based) must be dropped.
    pub fn loses_flush(&self, worker: usize, nth: usize) -> bool {
        self.lost_flushes
            .iter()
            .any(|l| l.worker == worker && l.nth_flush == nth)
    }

    /// A seeded random plan over `workers` nodes: independently maybe one
    /// crash, one straggler, one lost flush — the property-test driver.
    /// With a single worker the plan is empty (there is nobody left to
    /// recover on).
    pub fn random(rng: &mut Rng, workers: usize) -> FaultPlan {
        let mut plan = FaultPlan::default();
        if workers < 2 {
            return plan;
        }
        if rng.below(2) == 1 {
            plan = plan.crash(
                rng.below(workers as u64) as usize,
                rng.below(4) as usize,
            );
        }
        if rng.below(2) == 1 {
            plan = plan.slow(
                rng.below(workers as u64) as usize,
                6.0 + rng.f64() * 10.0,
            );
        }
        if rng.below(2) == 1 {
            plan = plan.lose_flush(rng.below(workers as u64) as usize, 0);
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_accumulate_and_query() {
        let p = FaultPlan::none()
            .crash(2, 3)
            .slow(1, 8.0)
            .slow(1, 4.0)
            .lose_flush(0, 1);
        assert!(!p.is_empty());
        assert_eq!(p.crash_of(2), Some(Crash { worker: 2, after_chunks: 3 }));
        assert_eq!(p.crash_of(0), None);
        // Multiple slow entries: the worst multiplier wins.
        assert_eq!(p.multiplier_of(1), 8.0);
        assert_eq!(p.multiplier_of(5), 1.0);
        assert!(p.loses_flush(0, 1));
        assert!(!p.loses_flush(0, 0));
    }

    #[test]
    fn multipliers_clamp_to_full_speed() {
        let p = FaultPlan::none().slow(0, 0.25);
        assert_eq!(p.multiplier_of(0), 1.0);
    }

    #[test]
    fn random_plans_are_seed_deterministic_and_in_range() {
        for seed in 0..20u64 {
            let a = FaultPlan::random(&mut Rng::new(seed), 6);
            let b = FaultPlan::random(&mut Rng::new(seed), 6);
            assert_eq!(a, b, "seed {seed} not reproducible");
            for c in &a.crashes {
                assert!(c.worker < 6 && c.after_chunks < 4);
            }
            for s in &a.slow {
                assert!(s.worker < 6 && s.multiplier >= 1.0);
            }
            for l in &a.lost_flushes {
                assert!(l.worker < 6);
            }
        }
        assert!(FaultPlan::random(&mut Rng::new(3), 1).is_empty());
    }
}
