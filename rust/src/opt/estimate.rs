//! Cardinality and selectivity estimation over the IR.
//!
//! The estimator answers, for the decision pass ([`super::decide`]) and
//! for `Engine::explain`, the classic Selinger questions: how many rows
//! does a loop see, how many survive its filters and guards, how many
//! matches does a join key produce per probe. It reads the per-column
//! [`ColumnStats`](crate::storage::ColumnStats) the storage catalog
//! caches and degrades gracefully — anything it cannot analyze falls
//! back to [`DEFAULT_SELECTIVITY`], never to an error, because a wrong
//! estimate only costs performance while a refused compile costs a
//! query.
//!
//! This module *extends* `analysis::cost::TableStats` (via
//! [`TableStats::from_column`]) instead of replacing it: the existing
//! scan/hash/tree cost functions keep their rows+NDV inputs, and the
//! richer min/max/histogram data feeds the new selectivity math here.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::analysis::TableStats;
use crate::ir::{BinOp, Domain, Expr, Program, Stmt};
use crate::storage::{ColumnStats, StorageCatalog};

/// Selectivity assumed for predicates the estimator cannot analyze
/// (System R's classic 1/3 guess).
pub const DEFAULT_SELECTIVITY: f64 = 0.33;

/// Flatten a conjunction (`a && b && c`) into its conjuncts; a non-`And`
/// expression is its own single conjunct.
pub fn conjuncts(e: &Expr) -> Vec<&Expr> {
    fn go<'e>(e: &'e Expr, out: &mut Vec<&'e Expr>) {
        if let Expr::Binary {
            op: BinOp::And,
            lhs,
            rhs,
        } = e
        {
            go(lhs, out);
            go(rhs, out);
        } else {
            out.push(e);
        }
    }
    let mut v = Vec::new();
    go(e, &mut v);
    v
}

/// Per-loop cardinality estimate, reported by `Engine::explain`.
#[derive(Debug, Clone)]
pub struct LoopEstimate {
    /// Nesting depth (0 = top level).
    pub depth: usize,
    /// Rendered loop header, e.g. `forelem i ∈ pA`.
    pub describe: String,
    /// Estimated iterations entering the loop body (across all entries
    /// of the enclosing nest).
    pub rows_in: u64,
    /// Estimated iterations surviving an immediate guard, if any.
    pub rows_out: u64,
}

/// Statistics-backed estimator over one storage catalog.
pub struct Estimator<'a> {
    catalog: &'a StorageCatalog,
}

impl<'a> Estimator<'a> {
    pub fn new(catalog: &'a StorageCatalog) -> Self {
        Estimator { catalog }
    }

    /// Rows of a relation (0 when unknown — callers treat missing tables
    /// as "do not optimize").
    pub fn table_rows(&self, rel: &str) -> u64 {
        self.catalog.get(rel).map(|t| t.len() as u64).unwrap_or(0)
    }

    /// True when `rel.field` resolves against the stored schema.
    pub fn field_exists(&self, rel: &str, field: &str) -> bool {
        self.catalog
            .get(rel)
            .ok()
            .and_then(|t| t.schema.field_id(field))
            .is_some()
    }

    fn field_stats(&self, rel: &str, field: &str) -> Option<Arc<ColumnStats>> {
        let t = self.catalog.get(rel).ok()?;
        let fid = t.schema.field_id(field)?;
        self.catalog.column_stats(rel, fid).ok()
    }

    /// rows + NDV for the legacy cost functions.
    pub fn table_stats(&self, rel: &str, field: &str) -> TableStats {
        match self.field_stats(rel, field) {
            Some(cs) => TableStats::from_column(&cs),
            None => TableStats::new(self.table_rows(rel).max(1), 32),
        }
    }

    /// Selectivity of an equality filter on `rel.field` (1/NDV).
    pub fn eq_selectivity(&self, rel: &str, field: &str) -> f64 {
        match self.field_stats(rel, field) {
            Some(cs) => cs.eq_selectivity(),
            None => DEFAULT_SELECTIVITY,
        }
    }

    /// Selectivity of one guard conjunct under `scopes` (cursor var →
    /// relation). Analyzes `field cmp literal` in either orientation:
    /// equality via 1/NDV, ranges via the column histogram.
    pub fn conjunct_selectivity(&self, scopes: &BTreeMap<String, String>, e: &Expr) -> f64 {
        let Expr::Binary { op, lhs, rhs } = e else {
            return DEFAULT_SELECTIVITY;
        };
        if !op.is_comparison() {
            return DEFAULT_SELECTIVITY;
        }
        let (var, field, lit, op) = match (lhs.as_ref(), rhs.as_ref()) {
            (Expr::Field { var, field }, Expr::Const(v)) => (var, field, v, *op),
            (Expr::Const(v), Expr::Field { var, field }) => (var, field, v, flip(*op)),
            _ => return DEFAULT_SELECTIVITY,
        };
        let Some(rel) = scopes.get(var) else {
            return DEFAULT_SELECTIVITY;
        };
        let Some(cs) = self.field_stats(rel, field) else {
            return DEFAULT_SELECTIVITY;
        };
        let eq = cs.eq_selectivity();
        match op {
            BinOp::Eq => eq,
            BinOp::Ne => (1.0 - eq).max(0.0),
            BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge => {
                let (Some(x), Some(h)) = (lit.as_float(), &cs.histogram) else {
                    return DEFAULT_SELECTIVITY;
                };
                let below = h.fraction_below(x);
                match op {
                    BinOp::Lt => below,
                    BinOp::Le => (below + eq).min(1.0),
                    BinOp::Gt => (1.0 - below - eq).clamp(0.0, 1.0),
                    BinOp::Ge => (1.0 - below).clamp(0.0, 1.0),
                    _ => unreachable!(),
                }
            }
            _ => DEFAULT_SELECTIVITY,
        }
    }

    /// Combined selectivity of a conjunction (independence assumption).
    pub fn guard_selectivity(&self, scopes: &BTreeMap<String, String>, cond: &Expr) -> f64 {
        conjuncts(cond)
            .into_iter()
            .map(|c| self.conjunct_selectivity(scopes, c))
            .product()
    }

    /// Estimated rows in/out for every loop of the program (pre-order).
    pub fn loop_estimates(&self, p: &Program) -> Vec<LoopEstimate> {
        let mut out = Vec::new();
        let mut scopes = BTreeMap::new();
        for s in &p.body {
            self.walk(s, 1.0, 0, &mut scopes, &mut out);
        }
        out
    }

    fn walk(
        &self,
        s: &Stmt,
        entries: f64,
        depth: usize,
        scopes: &mut BTreeMap<String, String>,
        out: &mut Vec<LoopEstimate>,
    ) {
        match s {
            Stmt::Loop(l) => {
                let (per_entry, relation) = match &l.domain {
                    Domain::IndexSet(ix) => {
                        let total = self.table_rows(&ix.relation) as f64;
                        let per = match (&ix.field_filter, &ix.distinct) {
                            (Some((field, _)), _) => {
                                total * self.eq_selectivity(&ix.relation, field)
                            }
                            (None, Some(field)) => {
                                self.table_stats(&ix.relation, field).distinct_keys as f64
                            }
                            (None, None) => total,
                        };
                        (per, Some(ix.relation.clone()))
                    }
                    // Range bounds are expressions (often params); assume
                    // a modest fan-out like the materialization pass.
                    Domain::Range { .. } => (8.0, None),
                    Domain::ValuePartition { relation, field, .. } => (
                        (self.table_stats(relation, field).distinct_keys as f64 / 8.0).max(1.0),
                        Some(relation.clone()),
                    ),
                    Domain::DistinctValues { relation, field } => (
                        self.table_stats(relation, field).distinct_keys as f64,
                        Some(relation.clone()),
                    ),
                };
                if let Some(rel) = &relation {
                    scopes.insert(l.var.clone(), rel.clone());
                }
                let rows_in = entries * per_entry;
                let guard_sel = match l.body.as_slice() {
                    [Stmt::If { cond, els, .. }] if els.is_empty() => {
                        self.guard_selectivity(scopes, cond)
                    }
                    _ => 1.0,
                };
                let rows_out = rows_in * guard_sel;
                let domain = match &l.domain {
                    Domain::IndexSet(ix) => ix.to_string(),
                    Domain::Range { lo, hi } => format!("{lo}..{hi}"),
                    Domain::ValuePartition { relation, field, .. } => {
                        format!("partition({relation}.{field})")
                    }
                    Domain::DistinctValues { relation, field } => {
                        format!("distinct({relation}.{field})")
                    }
                };
                out.push(LoopEstimate {
                    depth,
                    describe: format!("{} {} ∈ {}", l.kind, l.var, domain),
                    rows_in: rows_in.round() as u64,
                    rows_out: rows_out.round() as u64,
                });
                for b in &l.body {
                    self.walk(b, rows_in, depth + 1, scopes, out);
                }
                scopes.remove(&l.var);
            }
            Stmt::If { then, els, .. } => {
                for b in then.iter().chain(els) {
                    self.walk(b, entries, depth, scopes, out);
                }
            }
            _ => {}
        }
    }
}

fn flip(op: BinOp) -> BinOp {
    match op {
        BinOp::Lt => BinOp::Gt,
        BinOp::Le => BinOp::Ge,
        BinOp::Gt => BinOp::Lt,
        BinOp::Ge => BinOp::Le,
        other => other,
    }
}

/// True when the expression reads no accumulator state (directly or via
/// a cross-partition sum): its value depends only on cursors, scalars
/// and constants, so re-evaluating it in a different visit order is
/// safe.
pub fn expr_pure(e: &Expr) -> bool {
    let mut pure = true;
    e.walk(&mut |x| {
        if matches!(x, Expr::ArrayRef { .. } | Expr::SumOverParts { .. }) {
            pure = false;
        }
    });
    pure
}

/// True when `lit` is compared against a field — the only conjunct shape
/// the reorderer moves (pure, total for type-correct programs).
pub fn reorderable_conjunct(scopes: &BTreeMap<String, String>, e: &Expr) -> bool {
    let Expr::Binary { op, lhs, rhs } = e else {
        return false;
    };
    if !op.is_comparison() {
        return false;
    }
    match (lhs.as_ref(), rhs.as_ref()) {
        (Expr::Field { var, .. }, Expr::Const(_)) | (Expr::Const(_), Expr::Field { var, .. }) => {
            scopes.contains_key(var)
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Multiset, Schema, Value};
    use crate::sql::compile_sql;

    fn catalog() -> StorageCatalog {
        let mut t = Multiset::new(Schema::new(vec![
            ("k", DataType::Str),
            ("n", DataType::Int),
        ]));
        for i in 0..1000i64 {
            t.push(vec![Value::str(format!("k{}", i % 20)), Value::Int(i)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("t", &t).unwrap();
        c
    }

    #[test]
    fn eq_selectivity_is_one_over_ndv() {
        let c = catalog();
        let est = Estimator::new(&c);
        let sel = est.eq_selectivity("t", "k");
        assert!((sel - 1.0 / 20.0).abs() < 1e-9, "got {sel}");
        assert_eq!(est.table_rows("t"), 1000);
        assert_eq!(est.table_rows("missing"), 0);
    }

    #[test]
    fn range_conjuncts_use_the_histogram() {
        let c = catalog();
        let est = Estimator::new(&c);
        let mut scopes = BTreeMap::new();
        scopes.insert("i".to_string(), "t".to_string());
        // n is uniform over 0..1000: `n < 250` ≈ 0.25.
        let pred = Expr::bin(BinOp::Lt, Expr::field("i", "n"), Expr::int(250));
        let sel = est.conjunct_selectivity(&scopes, &pred);
        assert!((sel - 0.25).abs() < 0.05, "got {sel}");
        // Flipped orientation: `250 > n` is the same predicate.
        let flipped = Expr::bin(BinOp::Gt, Expr::int(250), Expr::field("i", "n"));
        let fsel = est.conjunct_selectivity(&scopes, &flipped);
        assert!((sel - fsel).abs() < 1e-9);
    }

    #[test]
    fn unanalyzable_conjuncts_fall_back_to_the_default() {
        let c = catalog();
        let est = Estimator::new(&c);
        let scopes = BTreeMap::new();
        // Unknown cursor var.
        let pred = Expr::bin(BinOp::Eq, Expr::field("z", "k"), Expr::str("k0"));
        assert_eq!(est.conjunct_selectivity(&scopes, &pred), DEFAULT_SELECTIVITY);
        // Non-comparison.
        let arith = Expr::add(Expr::int(1), Expr::int(2));
        assert_eq!(est.conjunct_selectivity(&scopes, &arith), DEFAULT_SELECTIVITY);
    }

    #[test]
    fn loop_estimates_report_filters_and_guards() {
        let c = catalog();
        let est = Estimator::new(&c);
        let q = "SELECT k FROM t WHERE k = 'k0' AND n < 250";
        let p = compile_sql(q, &c.schemas()).unwrap();
        let es = est.loop_estimates(&p);
        assert_eq!(es.len(), 1, "{es:?}");
        // Index filter k = 'k0': 1000/20 = 50 rows in; guard n < 250
        // keeps about a quarter.
        assert!((40..=60).contains(&es[0].rows_in), "{es:?}");
        assert!(es[0].rows_out < es[0].rows_in, "{es:?}");
    }

    #[test]
    fn conjuncts_flattens_nested_ands() {
        let e = Expr::bin(
            BinOp::And,
            Expr::bin(BinOp::And, Expr::var("a"), Expr::var("b")),
            Expr::var("c"),
        );
        assert_eq!(conjuncts(&e).len(), 3);
        assert_eq!(conjuncts(&Expr::var("a")).len(), 1);
    }

    #[test]
    fn purity_rejects_accumulator_reads() {
        assert!(expr_pure(&Expr::field("i", "k")));
        assert!(!expr_pure(&Expr::array("count", vec![Expr::field("i", "k")])));
    }
}
