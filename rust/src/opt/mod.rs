//! The cost-based query optimizer (the paper's "integration of compiler
//! optimization and query optimization" over one IR).
//!
//! Runs between SQL/MapReduce lowering and the execution tiers. Three
//! layers:
//!
//! * **statistics** — per-column [`ColumnStats`](crate::storage::ColumnStats)
//!   (rows, NDV, min/max, null count, equi-width histograms) collected
//!   and cached by the storage catalog;
//! * **estimation** — [`estimate::Estimator`], a cardinality/selectivity
//!   estimator over `forelem` filters, guards and join keys that extends
//!   `analysis::cost::TableStats` rather than replacing it;
//! * **planning** — [`decide::optimize`], the decision pass that rewrites
//!   and annotates the program: hash-join build side by estimated
//!   cardinality (swapping the Figure-1 nest when the written order
//!   would hash the larger table), conjunctive guards reordered
//!   most-selective-first, scan-vs-materialize strategies via the
//!   existing cost model, heap-vs-sort for ordered/bounded (`topk`)
//!   emissions, and the morsel fan-out gate below.
//!
//! Every decision pushes a dot-namespaced `opt.<decision>` tag into
//! `Program::opt_tags`; executors merge those into `ExecStats.idioms`
//! (registry in `docs/ARCHITECTURE.md`). `Engine::explain` renders the
//! full [`decide::OptReport`] — estimated rows in/out per loop plus every
//! decision — alongside the tier that actually fired.

pub mod decide;
pub mod estimate;

pub use decide::{optimize, Decision, OptReport};
pub use estimate::{Estimator, LoopEstimate, DEFAULT_SELECTIVITY};

use crate::analysis::cost::PARALLEL_SPINUP_ROWS;

/// The morsel fan-out gate: parallel workers only pay off once the
/// iteration space amortizes thread spin-up and state merging
/// ([`PARALLEL_SPINUP_ROWS`], four `exec::BATCH` morsels). `exec::parallel`
/// consults this for every eligible scan and join probe; a rejected
/// fan-out runs sequentially on the master state and tags
/// `opt.small_scan_seq` / `opt.small_join_seq`.
pub fn should_fan_out(rows: usize, threads: usize) -> bool {
    threads > 1 && rows as u64 > PARALLEL_SPINUP_ROWS
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fan_out_gate_needs_threads_and_rows() {
        assert!(!should_fan_out(1_000_000, 1));
        assert!(!should_fan_out(0, 8));
        assert!(!should_fan_out(PARALLEL_SPINUP_ROWS as usize, 8));
        assert!(should_fan_out(PARALLEL_SPINUP_ROWS as usize + 1, 2));
    }

    #[test]
    fn spinup_constant_tracks_the_morsel_batch_size() {
        // The gate is documented as "four BATCH morsels" — the SIMD-shaped
        // kernels made sequential scans fast enough that fan-out only pays
        // past several batches. Keep the constant an exact BATCH multiple
        // so the two never drift silently.
        assert_eq!(PARALLEL_SPINUP_ROWS, 4 * crate::exec::BATCH as u64);
        // The gate still holds tiny tables sequential and releases big ones.
        assert!(!should_fan_out(100, 8));
        assert!(should_fan_out(100_000, 2));
    }
}
