//! The optimizer's decision pass: consume estimates, rewrite the IR.
//!
//! Six executable decisions, each recorded as a [`Decision`] whose
//! dot-namespaced tag lands in `Program::opt_tags` (and from there in
//! `ExecStats.idioms`):
//!
//! * **`opt.join_order`** — for a 3+-deep equi-join chain (star or
//!   snowflake), run a Selinger-style bottom-up DP over the connected
//!   left-deep orders of the join tree: `|R ⋈ S| = |R|·|S| /
//!   max(V(R,a), V(S,b))` with NDVs from `ColumnStats`, cost = Σ
//!   intermediate cardinalities + 2× each build side's rows (the
//!   vectorized tier hashes every non-outer level once). The chain is
//!   rewritten to the cheapest order; the decision is recorded even when
//!   the written order already wins, so plans are assertable either way.
//!   Gated on the same order-insensitivity check as the build-side swap
//!   (reordering revisits the matched tuples in a different sequence).
//! * **`opt.join_build_side`** — for the two-table Figure-1 nest, choose
//!   which side the vectorized tier hashes. `exec::compile` always
//!   builds over the *inner* loop's table, so when the outer (probe)
//!   relation is estimated smaller the nest is swapped — the body is
//!   untouched; only the loop order (and therefore the build side)
//!   changes. Swapping reorders the visit sequence of the matched
//!   pairs, so it is gated on an order-insensitivity check of the body
//!   (commutative accumulations and result appends only). Note that a
//!   float `+=` accumulation is reassociated by the swap — standard
//!   optimizer behaviour, and every execution tier still agrees on the
//!   *rewritten* program.
//! * **`opt.filter_reorder`** — conjunctive guards are reordered
//!   most-selective-first so the short-circuit `&&` chain rejects rows
//!   as early as possible. Only pure `field cmp literal` conjuncts move.
//! * **`opt.strategy.<scan|hash|tree>`** — filtered index sets still
//!   `Unspecified` get their scan-vs-materialize strategy from the
//!   existing cost model (`analysis::cost::choose_strategy`), fed by the
//!   statistics-backed estimator instead of the materialization pass's
//!   fallback guesses. The later `Materialize` pass leaves decided
//!   strategies untouched.
//! * **`opt.topk_heap` / `opt.topk_sort`** — ordered/bounded emissions
//!   (`ORDER BY`/`LIMIT` lowered to `EmitOrder`) pick the vectorized
//!   tier's bounded-heap `vec.topk` kernel when `k` is below the
//!   estimated emitted-row count (NDV of the distinct field for
//!   group-by emit loops), and the materialize+sort strategy otherwise
//!   (no `LIMIT`, or `k` covers the whole domain).
//! * **`opt.compressed_scan`** — a filtered scan or fused aggregation
//!   whose key column is stored compressed (RLE/range integers) or
//!   dictionary-encoded executes in the compressed domain: equality
//!   filters compare codes or whole runs, fused aggregations multiply by
//!   run lengths (`vec.dict_filter` / `vec.rle_filter` / `vec.rle_agg`).
//!   The choice is statistics-driven: run-domain kernels win when
//!   [`ColumnStats::run_count`] is materially below the row count (each
//!   run costs one comparison/accumulator probe instead of one per row);
//!   a degenerate layout with runs ≈ rows gets no tag — decoding up
//!   front would do as well, and the typed per-run kernels are never
//!   worse, so no program rewrite is needed either way.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::analysis::choose_strategy;
use crate::ir::{
    AccumOp, BinOp, Domain, Expr, IndexSet, Loop, LoopKind, Program, Stmt, Strategy, TopKStrategy,
};
use crate::storage::{Column, StorageCatalog};

use super::estimate::{conjuncts, expr_pure, reorderable_conjunct, Estimator, LoopEstimate};

/// One optimizer decision.
#[derive(Debug, Clone)]
pub struct Decision {
    /// Dot-namespaced tag (`opt.join_build_side`, ...).
    pub tag: String,
    /// Human-readable detail for `Engine::explain`.
    pub detail: String,
}

/// Everything the optimizer did to one program.
#[derive(Debug, Clone, Default)]
pub struct OptReport {
    pub decisions: Vec<Decision>,
    /// Estimated rows in/out per loop, computed on the *optimized*
    /// program (what actually executes).
    pub estimates: Vec<LoopEstimate>,
}

impl OptReport {
    /// Deduplicated decision tags, in first-decision order.
    pub fn tags(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for d in &self.decisions {
            if !out.contains(&d.tag) {
                out.push(d.tag.clone());
            }
        }
        out
    }

    /// True when a decision with this tag was recorded.
    pub fn has(&self, tag: &str) -> bool {
        self.decisions.iter().any(|d| d.tag == tag)
    }
}

/// Run the cost-based optimizer over a lowered program. Rewrites the
/// program in place (join nest order, guard conjunct order, index-set
/// strategies, top-k emission strategy), records every decision in the
/// report and in `Program::opt_tags`, and re-validates the result.
///
/// # Examples
///
/// The top-k decision on the paper's URL-count workload: `LIMIT 3` over
/// ~10 groups picks the bounded heap.
///
/// ```
/// use forelem::ir::{DataType, Multiset, Schema, TopKStrategy, Value};
/// use forelem::storage::StorageCatalog;
///
/// let mut t = Multiset::new(Schema::new(vec![("k", DataType::Str)]));
/// for i in 0..100i64 {
///     t.push(vec![Value::str(format!("k{}", i % 10))]);
/// }
/// let mut c = StorageCatalog::new();
/// c.insert_multiset("t", &t).unwrap();
/// let mut p = forelem::sql::compile_sql(
///     "SELECT k, COUNT(k) FROM t GROUP BY k ORDER BY count DESC LIMIT 3",
///     &c.schemas(),
/// )
/// .unwrap();
/// let report = forelem::opt::optimize(&mut p, &c).unwrap();
/// assert!(report.has("opt.topk_heap"));
/// assert_eq!(p.emit_bound().unwrap().strategy, TopKStrategy::Heap);
/// ```
pub fn optimize(p: &mut Program, catalog: &StorageCatalog) -> Result<OptReport> {
    let est = Estimator::new(catalog);
    let mut report = OptReport::default();
    for s in &mut p.body {
        choose_join_order(s, &est, &mut report);
    }
    for s in &mut p.body {
        choose_join_build_side(s, &est, &mut report);
    }
    for s in &p.body {
        choose_dist_strategy(s, &est, &mut report);
    }
    let mut scopes = BTreeMap::new();
    for s in &mut p.body {
        reorder_guards(s, &est, &mut scopes, &mut report);
    }
    for s in &mut p.body {
        choose_strategies(s, 1, &est, &mut report);
    }
    for s in &mut p.body {
        choose_topk_strategy(s, &est, &mut report);
    }
    for s in &p.body {
        choose_compressed_scan(s, catalog, &mut report);
    }
    report.estimates = est.loop_estimates(p);
    for tag in report.tags() {
        if !p.opt_tags.contains(&tag) {
            p.opt_tags.push(tag);
        }
    }
    crate::ir::validate(p)?;
    Ok(report)
}

/// True when executing `body` once per matched pair in *any* order
/// produces identical observable state: only commutative accumulations
/// and result appends (bag semantics), guarded by pure conditions.
fn order_insensitive(body: &[Stmt]) -> bool {
    body.iter().all(|s| match s {
        Stmt::ResultUnion { tuple, .. } => tuple.iter().all(expr_pure),
        Stmt::Accum {
            indices, op, value, ..
        } => {
            matches!(op, AccumOp::Add | AccumOp::Min | AccumOp::Max)
                && indices.iter().all(expr_pure)
                && expr_pure(value)
        }
        Stmt::If { cond, then, els } => {
            expr_pure(cond) && order_insensitive(then) && order_insensitive(els)
        }
        _ => false,
    })
}

/// A matched 3+-deep equi-join chain: one cursor/relation per nest
/// level (written order) plus the tree edge that keys each non-outer
/// level on an enclosing level's cursor.
struct JoinChain {
    /// (cursor var, relation) per level, outermost first.
    nodes: Vec<(String, String)>,
    /// `edges[k]` describes level `k + 1`: (key field on that level,
    /// index of the parent level, field on the parent).
    edges: Vec<(String, usize, String)>,
    /// The innermost loop's (order-insensitive) body.
    innermost: Vec<Stmt>,
}

/// Match the N-way generalization of the Figure-1 nest: a forelem chain
/// where every level's body is exactly the next loop, every non-outer
/// level is key-filtered on an *enclosing* cursor's plain field (star or
/// snowflake), nothing is annotated (no distinct/partition/emit/outer
/// filter), and the innermost body is order-insensitive. Two-deep nests
/// return `None` — they belong to `choose_join_build_side`.
fn match_join_chain(outer: &Loop) -> Option<JoinChain> {
    if outer.kind != LoopKind::Forelem || outer.emit.is_some() {
        return None;
    }
    let Domain::IndexSet(ox) = &outer.domain else {
        return None;
    };
    if ox.field_filter.is_some() || ox.distinct.is_some() || ox.partition.is_some() {
        return None;
    }
    let mut nodes = vec![(outer.var.clone(), ox.relation.clone())];
    let mut edges = Vec::new();
    let mut cur: &Loop = outer;
    loop {
        let [Stmt::Loop(inner)] = cur.body.as_slice() else {
            break;
        };
        if inner.kind != LoopKind::Forelem || inner.emit.is_some() {
            return None;
        }
        let Domain::IndexSet(ix) = &inner.domain else {
            return None;
        };
        if ix.distinct.is_some() || ix.partition.is_some() {
            return None;
        }
        let Some((field, key)) = &ix.field_filter else {
            return None;
        };
        let Expr::Field {
            var: pvar,
            field: pfield,
        } = key
        else {
            return None;
        };
        let parent = nodes.iter().position(|(v, _)| v == pvar)?;
        if nodes.iter().any(|(v, _)| v == &inner.var)
            || nodes.iter().any(|(_, r)| r == &ix.relation)
        {
            return None;
        }
        nodes.push((inner.var.clone(), ix.relation.clone()));
        edges.push((field.clone(), parent, pfield.clone()));
        cur = inner;
    }
    if nodes.len() < 3 || !order_insensitive(&cur.body) {
        return None;
    }
    Some(JoinChain {
        nodes,
        edges,
        innermost: cur.body.clone(),
    })
}

/// Selinger-style bottom-up join-order search over a matched chain:
/// enumerate the connected left-deep orders of the join tree by dynamic
/// programming over subsets, cost each with the classic
/// `|R ⋈ S| = |R|·|S| / max(V(R,a), V(S,b))` cardinality model, and
/// rewrite the nest to the cheapest order. The decision is recorded even
/// when the written order wins, so every multi-join plan is assertable.
fn choose_join_order(s: &mut Stmt, est: &Estimator, report: &mut OptReport) {
    let Stmt::Loop(outer) = s else { return };
    let Some(chain) = match_join_chain(outer) else {
        return;
    };
    let n = chain.nodes.len();
    if n > 12 {
        return; // 2^n subsets — far beyond any lowered query anyway
    }
    // Statistics gate: every relation sized, every join field resolvable
    // (missing tables report 0 rows — "do not optimize").
    let rows: Vec<f64> = chain
        .nodes
        .iter()
        .map(|(_, r)| est.table_rows(r) as f64)
        .collect();
    if rows.iter().any(|&r| r == 0.0) {
        return;
    }
    for (k, (cfield, p, pfield)) in chain.edges.iter().enumerate() {
        if !est.field_exists(&chain.nodes[k + 1].1, cfield)
            || !est.field_exists(&chain.nodes[*p].1, pfield)
        {
            return;
        }
    }
    // Undirected adjacency of the join tree:
    // adj[i] = (neighbor, key field on i, key field on the neighbor).
    let mut adj: Vec<Vec<(usize, String, String)>> = vec![Vec::new(); n];
    for (k, (cfield, p, pfield)) in chain.edges.iter().enumerate() {
        adj[k + 1].push((*p, cfield.clone(), pfield.clone()));
        adj[*p].push((k + 1, pfield.clone(), cfield.clone()));
    }
    let ndv = |i: usize, field: &str| {
        est.table_stats(&chain.nodes[i].1, field).distinct_keys.max(1) as f64
    };
    // Cost of one left-deep order: Σ intermediate cardinalities + 2× each
    // build side's rows (every non-outer level is hashed once).
    let order_cost = |order: &[usize]| -> f64 {
        let mut placed = 1u32 << order[0];
        let mut card = rows[order[0]];
        let mut cost = card;
        for &t in &order[1..] {
            let (o, tf, of) = edge_into(&adj, placed, t).expect("connected join tree");
            card *= rows[t] / ndv(t, tf).max(ndv(o, of));
            cost += card + 2.0 * rows[t];
            placed |= 1 << t;
        }
        cost
    };
    // DP over connected subsets; masks grow numerically as bits are
    // added, so increasing mask order is a valid bottom-up schedule.
    let mut dp: BTreeMap<u32, (f64, f64, Vec<usize>)> = BTreeMap::new();
    for i in 0..n {
        dp.insert(1 << i, (rows[i], rows[i], vec![i]));
    }
    for mask in 1u32..(1 << n) {
        let Some((cost, card, order)) = dp.get(&mask).cloned() else {
            continue;
        };
        for t in 0..n {
            if mask & (1 << t) != 0 {
                continue;
            }
            let Some((o, tf, of)) = edge_into(&adj, mask, t) else {
                continue;
            };
            let new_card = card * rows[t] / ndv(t, tf).max(ndv(o, of));
            let new_cost = cost + new_card + 2.0 * rows[t];
            let key = mask | (1 << t);
            let better = match dp.get(&key) {
                Some((c, _, _)) => new_cost < *c,
                None => true,
            };
            if better {
                let mut ord = order.clone();
                ord.push(t);
                dp.insert(key, (new_cost, new_card, ord));
            }
        }
    }
    let full = (1u32 << n) - 1;
    let Some((best_cost, _, best_order)) = dp.get(&full).cloned() else {
        return; // unreachable for a lowered (connected) chain
    };
    let names = |order: &[usize]| {
        order
            .iter()
            .map(|&i| chain.nodes[i].1.as_str())
            .collect::<Vec<_>>()
            .join(" ⋈ ")
    };
    let written: Vec<usize> = (0..n).collect();
    if best_order == written {
        report.decisions.push(Decision {
            tag: "opt.join_order".into(),
            detail: format!(
                "{} — as written (est cost {:.0})",
                names(&written),
                best_cost
            ),
        });
        return;
    }
    let detail = format!(
        "{} — reordered from {} (est cost {:.0} vs {:.0})",
        names(&best_order),
        names(&written),
        best_cost,
        order_cost(&written)
    );
    // Rebuild the nest in the chosen order: each non-outer level keys on
    // its unique tree edge into the already-placed prefix.
    let mut body = chain.innermost.clone();
    for (pos, &t) in best_order.iter().enumerate().skip(1).rev() {
        let placed: u32 = best_order[..pos].iter().fold(0, |m, &i| m | (1 << i));
        let (o, tf, of) = edge_into(&adj, placed, t).expect("connected join tree");
        let ix = IndexSet::filtered(
            &chain.nodes[t].1,
            tf,
            Expr::field(&chain.nodes[o].0, of),
        );
        body = vec![Stmt::Loop(Loop::forelem(&chain.nodes[t].0, ix, body))];
    }
    let first = best_order[0];
    let new_outer = Loop::forelem(
        &chain.nodes[first].0,
        IndexSet::all(&chain.nodes[first].1),
        body,
    );
    report.decisions.push(Decision {
        tag: "opt.join_order".into(),
        detail,
    });
    *s = Stmt::Loop(new_outer);
}

/// The unique edge (tree property) through which table `t` touches the
/// `placed` set: (placed neighbor, key field on `t`, field on neighbor).
fn edge_into(
    adj: &[Vec<(usize, String, String)>],
    placed: u32,
    t: usize,
) -> Option<(usize, &str, &str)> {
    adj[t]
        .iter()
        .find(|(o, _, _)| placed & (1 << *o) != 0)
        .map(|(o, tf, of)| (*o, tf.as_str(), of.as_str()))
}

/// Detect the Figure-1 nest and pick the hash-join build side by
/// estimated cardinality, swapping the nest when the written order would
/// make `exec::compile` hash the larger table.
fn choose_join_build_side(s: &mut Stmt, est: &Estimator, report: &mut OptReport) {
    let Stmt::Loop(outer) = s else { return };
    if outer.kind != LoopKind::Forelem {
        return;
    }
    let Domain::IndexSet(ox) = &outer.domain else {
        return;
    };
    // Only the plain Figure-1 shape: no outer filter (a WHERE equality on
    // the probe side must stay on the probe side), no distinct, no
    // partition on either loop. An ordered/bounded emission pins the
    // nest too: the emit contract's tie-breaking observes the emission
    // sequence a swap would reorder.
    if ox.field_filter.is_some() || ox.distinct.is_some() || ox.partition.is_some() {
        return;
    }
    if outer.emit.is_some() {
        return;
    }
    let [Stmt::Loop(inner)] = outer.body.as_slice() else {
        return;
    };
    if inner.kind != LoopKind::Forelem {
        return;
    }
    let Domain::IndexSet(iix) = &inner.domain else {
        return;
    };
    if iix.distinct.is_some() || iix.partition.is_some() {
        return;
    }
    let Some((inner_field, key)) = &iix.field_filter else {
        return;
    };
    // The inner filter must be keyed directly on an outer-cursor field
    // (`pB.id[i.b_id]`) for the swap to be expressible.
    let Expr::Field {
        var: kvar,
        field: outer_field,
    } = key
    else {
        return;
    };
    if kvar != &outer.var || outer.var == inner.var {
        return;
    }
    if !est.field_exists(&ox.relation, outer_field)
        || !est.field_exists(&iix.relation, inner_field)
    {
        return;
    }
    if !order_insensitive(&inner.body) {
        return;
    }
    let probe_rows = est.table_rows(&ox.relation);
    let build_rows = est.table_rows(&iix.relation);
    if probe_rows >= build_rows {
        // The written nest already hashes the smaller (or equal) side.
        report.decisions.push(Decision {
            tag: "opt.join_build_side".into(),
            detail: format!(
                "build on `{}` ({build_rows} rows), probe `{}` ({probe_rows} rows) — as written",
                iix.relation, ox.relation
            ),
        });
        return;
    }
    // Swap: the (larger) written-second relation becomes the probe side;
    // the hash table is built over the (smaller) written-first relation.
    let detail = format!(
        "build on `{}` ({probe_rows} rows) instead of `{}` ({build_rows} rows) — nest swapped",
        ox.relation, iix.relation
    );
    let new_inner = Loop::forelem(
        &outer.var,
        IndexSet::filtered(
            &ox.relation,
            outer_field,
            Expr::field(&inner.var, inner_field),
        ),
        inner.body.clone(),
    );
    let swapped = Loop::forelem(
        &inner.var,
        IndexSet::all(&iix.relation),
        vec![Stmt::Loop(new_inner)],
    );
    report.decisions.push(Decision {
        tag: "opt.join_build_side".into(),
        detail,
    });
    *s = Stmt::Loop(swapped);
}

/// Nominal cluster width for the distributed-shipping decision. The
/// decision is recorded at plan time, before any concrete
/// `ClusterConfig` exists; 8 workers matches the simulated cluster's
/// default scale (the paper's testbed order of magnitude).
const DIST_NOMINAL_WORKERS: u64 = 8;

/// Record how a Figure-1 join nest should ship when executed on the
/// simulated cluster: broadcast the build side to every worker (moves
/// `build_rows × (W-1)` rows, probe rows stay put) or hash-shuffle both
/// sides so every row travels to its key's owning node (moves
/// `(probe + build) × (W-1)/W` rows). Runs after
/// `choose_join_build_side`, so the nest is already oriented
/// probe-outer / build-inner. Record-only: `Engine::sql_distributed`
/// reads the tag to pick between the shared-hash-table broadcast path
/// and the repartitioning shuffle executor.
fn choose_dist_strategy(s: &Stmt, est: &Estimator, report: &mut OptReport) {
    let Stmt::Loop(outer) = s else { return };
    if outer.kind != LoopKind::Forelem || outer.emit.is_some() {
        return;
    }
    let Domain::IndexSet(ox) = &outer.domain else {
        return;
    };
    if ox.field_filter.is_some() || ox.distinct.is_some() || ox.partition.is_some() {
        return;
    }
    let [Stmt::Loop(inner)] = outer.body.as_slice() else {
        return;
    };
    if inner.kind != LoopKind::Forelem {
        return;
    }
    let Domain::IndexSet(iix) = &inner.domain else {
        return;
    };
    if iix.distinct.is_some() || iix.partition.is_some() {
        return;
    }
    let Some((_, key)) = &iix.field_filter else {
        return;
    };
    let Expr::Field { var: kvar, .. } = key else {
        return;
    };
    if kvar != &outer.var {
        return;
    }
    // Deeper chains (the inner body being yet another filtered loop)
    // belong to the N-way order pass; the shipping decision covers the
    // two-table nest `sql_distributed` executes.
    if matches!(inner.body.as_slice(), [Stmt::Loop(_)]) {
        return;
    }
    let probe_rows = est.table_rows(&ox.relation);
    let build_rows = est.table_rows(&iix.relation);
    let w = DIST_NOMINAL_WORKERS;
    let broadcast_cost = build_rows.saturating_mul(w - 1);
    let shuffle_cost = (probe_rows + build_rows) / w * (w - 1);
    let (tag, verdict) = if broadcast_cost <= shuffle_cost {
        ("opt.dist_broadcast", "replicate the build side")
    } else {
        ("opt.dist_shuffle", "hash-partition both sides")
    };
    report.decisions.push(Decision {
        tag: tag.into(),
        detail: format!(
            "{verdict}: probe `{}` ({probe_rows} rows), build `{}` ({build_rows} rows); \
             broadcast moves {broadcast_cost} rows vs shuffle {shuffle_cost} (W={w})",
            ox.relation, iix.relation
        ),
    });
}

/// Reorder conjunctive guards most-selective-first (short-circuit `&&`
/// rejects rows at the cheapest conjunct). Only pure `field cmp literal`
/// conjuncts are moved; anything else leaves the guard untouched.
fn reorder_guards(
    s: &mut Stmt,
    est: &Estimator,
    scopes: &mut BTreeMap<String, String>,
    report: &mut OptReport,
) {
    match s {
        Stmt::Loop(l) => {
            let bound = match &l.domain {
                Domain::IndexSet(ix) => {
                    scopes.insert(l.var.clone(), ix.relation.clone());
                    true
                }
                _ => false,
            };
            for b in &mut l.body {
                reorder_guards(b, est, scopes, report);
            }
            if bound {
                scopes.remove(&l.var);
            }
        }
        Stmt::If { cond, then, els } => {
            reorder_cond(cond, est, scopes, report);
            for b in then.iter_mut().chain(els.iter_mut()) {
                reorder_guards(b, est, scopes, report);
            }
        }
        _ => {}
    }
}

fn reorder_cond(
    cond: &mut Expr,
    est: &Estimator,
    scopes: &BTreeMap<String, String>,
    report: &mut OptReport,
) {
    let parts: Vec<Expr> = conjuncts(cond).into_iter().cloned().collect();
    if parts.len() < 2 {
        return;
    }
    if !parts.iter().all(|c| reorderable_conjunct(scopes, c)) {
        return;
    }
    let mut ranked: Vec<(f64, usize)> = parts
        .iter()
        .enumerate()
        .map(|(i, c)| (est.conjunct_selectivity(scopes, c), i))
        .collect();
    // Stable: ties keep the written order.
    ranked.sort_by(|a, b| {
        a.0.partial_cmp(&b.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.1.cmp(&b.1))
    });
    if ranked.iter().map(|&(_, i)| i).eq(0..parts.len()) {
        return; // already most-selective-first
    }
    let mut it = ranked.iter().map(|&(_, i)| parts[i].clone());
    let first = it.next().expect("len >= 2");
    *cond = it.fold(first, |acc, c| Expr::bin(BinOp::And, acc, c));
    report.decisions.push(Decision {
        tag: "opt.filter_reorder".into(),
        detail: format!(
            "{} guard conjuncts reordered most-selective-first",
            parts.len()
        ),
    });
}

/// Heap-vs-sort for ordered/bounded emissions (`ORDER BY`/`LIMIT`
/// lowered to `EmitOrder`): a bounded emission whose `k` is smaller than
/// the estimated emitted-row count runs the vectorized tier's bounded
/// heap (`vec.topk`, O(n log k)); an unbounded ORDER BY — or a LIMIT
/// that covers the whole domain anyway — materializes and sorts. The
/// emitted-row count comes from the same column statistics the other
/// decisions use: NDV of the distinct field for group-by emit loops,
/// table row count for plain scans and join probes.
fn choose_topk_strategy(s: &mut Stmt, est: &Estimator, report: &mut OptReport) {
    let Stmt::Loop(l) = s else { return };
    for b in &mut l.body {
        choose_topk_strategy(b, est, report);
    }
    let Some(e) = &mut l.emit else { return };
    if e.strategy != TopKStrategy::Unspecified {
        return;
    }
    let est_out = match &l.domain {
        Domain::IndexSet(ix) => match &ix.distinct {
            Some(field) => est.table_stats(&ix.relation, field).distinct_keys,
            None => est.table_rows(&ix.relation),
        },
        _ => 0,
    };
    let (strategy, tag, detail) = match e.limit {
        None => (
            TopKStrategy::Sort,
            "opt.topk_sort",
            format!("ordered emission of ~{est_out} rows — full sort (no LIMIT)"),
        ),
        Some(k) if est_out > 0 && k as u64 >= est_out => (
            TopKStrategy::Sort,
            "opt.topk_sort",
            format!("LIMIT {k} covers ~{est_out} emitted rows — full sort"),
        ),
        Some(k) => (
            TopKStrategy::Heap,
            "opt.topk_heap",
            format!("top-{k} of ~{est_out} emitted rows — bounded heap, O(n log k)"),
        ),
    };
    e.strategy = strategy;
    report.decisions.push(Decision {
        tag: tag.into(),
        detail,
    });
}

/// Scan-vs-materialize via the existing cost model, with probe counts
/// from the estimator. Mirrors `transform::Materialize`'s recursion but
/// records each choice; `Materialize` later skips anything already
/// decided here.
fn choose_strategies(s: &mut Stmt, probes: u64, est: &Estimator, report: &mut OptReport) {
    let Stmt::Loop(l) = s else { return };
    let mut inner_probes = probes;
    if let Domain::IndexSet(ix) = &mut l.domain {
        if let Some(field) = ix.field_filter.as_ref().map(|(f, _)| f.clone()) {
            let stats = est.table_stats(&ix.relation, &field);
            if ix.strategy == Strategy::Unspecified {
                let chosen = choose_strategy(stats, probes, false);
                ix.strategy = chosen;
                report.decisions.push(Decision {
                    tag: format!("opt.strategy.{chosen}"),
                    detail: format!(
                        "`{}`.{field}: {chosen} ({} rows / {} keys, ~{probes} probes)",
                        ix.relation, stats.rows, stats.distinct_keys
                    ),
                });
            }
            inner_probes = probes.saturating_mul((stats.rows / stats.distinct_keys).max(1));
        } else if let Some(field) = &ix.distinct {
            inner_probes =
                probes.saturating_mul(est.table_stats(&ix.relation, field).distinct_keys.max(1));
        } else {
            inner_probes = probes.saturating_mul(est.table_rows(&ix.relation).max(1));
        }
    } else if let Domain::Range { .. } = &l.domain {
        inner_probes = probes.saturating_mul(8);
    }
    for b in &mut l.body {
        choose_strategies(b, inner_probes, est, report);
    }
}

/// Code-domain vs decode-up-front for scans over compressed columns.
/// Inspects the two positions where the vectorized tier has compressed
/// kernels — the index-set equality filter's field and the key field of
/// a fused-aggregation body — and records `opt.compressed_scan` when
/// column statistics say the compressed layout pays off in place:
/// dictionary codes always do (one `Dictionary::lookup`, then u32
/// compares), enumerated ranges solve filters arithmetically, and RLE
/// wins whenever runs are materially fewer than rows. This pass only
/// records the choice — the kernels themselves are never worse than the
/// decoded path, so no rewrite is needed when the stats say "decode".
fn choose_compressed_scan(s: &Stmt, catalog: &StorageCatalog, report: &mut OptReport) {
    let Stmt::Loop(l) = s else { return };
    for b in &l.body {
        choose_compressed_scan(b, catalog, report);
    }
    let Domain::IndexSet(ix) = &l.domain else {
        return;
    };
    let Ok(table) = catalog.get(&ix.relation) else {
        return;
    };
    // Fields in a kernel position: the equality filter's field, plus the
    // key of a single-accumulation (fused group-by) body.
    let mut fields: Vec<&String> = Vec::new();
    if let Some((f, _)) = &ix.field_filter {
        fields.push(f);
    }
    if let [Stmt::Accum { indices, op, .. }] = l.body.as_slice() {
        if let (AccumOp::Add, [Expr::Field { var, field }]) = (op, indices.as_slice()) {
            if var == &l.var && !fields.contains(&field) {
                fields.push(field);
            }
        }
    }
    for field in fields {
        let Some(fid) = table.schema.field_id(field) else {
            continue;
        };
        match table.column(fid) {
            Column::CompressedInts(c) => {
                let Ok(cs) = catalog.column_stats(&ix.relation, fid) else {
                    continue;
                };
                let runs = cs.run_count.unwrap_or(cs.rows);
                // Enumerated ranges are closed-form either way; RLE must
                // clear a 2x run advantage to beat decoding up front.
                if c.runs().is_some() && runs.saturating_mul(2) > cs.rows.max(1) {
                    continue;
                }
                report.decisions.push(Decision {
                    tag: "opt.compressed_scan".into(),
                    detail: format!(
                        "`{}`.{field}: code-domain {} — {runs} runs / {} rows, ndv {}",
                        ix.relation,
                        c.scheme(),
                        cs.rows,
                        cs.ndv
                    ),
                });
            }
            Column::DictStrs { dict, .. } => {
                // Only the filter position: a string equality resolved
                // once against the dictionary, then compared as u32.
                if ix.field_filter.as_ref().is_some_and(|(f, _)| f == field) {
                    report.decisions.push(Decision {
                        tag: "opt.compressed_scan".into(),
                        detail: format!(
                            "`{}`.{field}: dict-code filter — {} keys / {} rows",
                            ix.relation,
                            dict.len(),
                            table.len()
                        ),
                    });
                }
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Multiset, Schema, Value};
    use crate::sql::compile_sql;

    /// `small` (written first) has far fewer rows than `big`.
    fn join_catalog(small_rows: usize, big_rows: usize) -> StorageCatalog {
        let mut small = Multiset::new(Schema::new(vec![
            ("id", DataType::Int),
            ("g", DataType::Str),
        ]));
        for i in 0..small_rows {
            small.push(vec![
                Value::Int(i as i64),
                Value::str(format!("g{}", i % 7)),
            ]);
        }
        let mut big = Multiset::new(Schema::new(vec![
            ("a_id", DataType::Int),
            ("w", DataType::Int),
        ]));
        for i in 0..big_rows {
            big.push(vec![
                Value::Int((i % (small_rows * 4).max(1)) as i64),
                Value::Int((i % 13) as i64),
            ]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("small", &small).unwrap();
        c.insert_multiset("big", &big).unwrap();
        c
    }

    fn nest_relations(p: &Program) -> (String, String) {
        let Stmt::Loop(outer) = &p.body[0] else {
            panic!("expected loop")
        };
        let Domain::IndexSet(ox) = &outer.domain else {
            panic!("expected index set")
        };
        let Stmt::Loop(inner) = &outer.body[0] else {
            panic!("expected inner loop")
        };
        let Domain::IndexSet(iix) = &inner.domain else {
            panic!("expected index set")
        };
        (ox.relation.clone(), iix.relation.clone())
    }

    #[test]
    fn skewed_join_swaps_the_build_side() {
        let c = join_catalog(50, 5000);
        let mut p = compile_sql(
            "SELECT g, COUNT(g) FROM small JOIN big ON small.id = big.a_id GROUP BY g",
            &c.schemas(),
        )
        .unwrap();
        // As lowered: probe = small (outer), build = big (inner) — wrong.
        assert_eq!(nest_relations(&p), ("small".into(), "big".into()));
        let report = optimize(&mut p, &c).unwrap();
        assert!(report.has("opt.join_build_side"), "{report:?}");
        assert!(p.opt_tags.contains(&"opt.join_build_side".to_string()));
        // After: probe = big, build = small.
        assert_eq!(nest_relations(&p), ("big".into(), "small".into()));
        // The swapped program still validates and runs identically.
        let reference = crate::exec::run(&p, &c).unwrap();
        assert_eq!(reference.result().unwrap().len(), 7);
    }

    #[test]
    fn well_ordered_join_is_kept_and_still_tagged() {
        let c = join_catalog(50, 5000);
        let mut p = compile_sql(
            "SELECT w, COUNT(w) FROM big JOIN small ON big.a_id = small.id GROUP BY w",
            &c.schemas(),
        )
        .unwrap();
        assert_eq!(nest_relations(&p), ("big".into(), "small".into()));
        let report = optimize(&mut p, &c).unwrap();
        assert!(report.has("opt.join_build_side"));
        // Already builds on the small side: unchanged.
        assert_eq!(nest_relations(&p), ("big".into(), "small".into()));
    }

    #[test]
    fn swap_preserves_interpreter_semantics() {
        let c = join_catalog(30, 3000);
        for q in [
            "SELECT small.g, big.w FROM small JOIN big ON small.id = big.a_id",
            "SELECT g, COUNT(g) FROM small JOIN big ON small.id = big.a_id GROUP BY g",
            "SELECT g, SUM(w) FROM small JOIN big ON small.id = big.a_id GROUP BY g",
        ] {
            let p0 = compile_sql(q, &c.schemas()).unwrap();
            let mut p1 = p0.clone();
            let report = optimize(&mut p1, &c).unwrap();
            assert!(report.has("opt.join_build_side"), "`{q}`");
            let a = crate::exec::run(&p0, &c).unwrap();
            let b = crate::exec::run(&p1, &c).unwrap();
            assert!(
                a.result().unwrap().bag_eq(b.result().unwrap()),
                "`{q}` changed results"
            );
        }
    }

    #[test]
    fn dist_strategy_broadcasts_a_small_build_side() {
        let c = join_catalog(50, 5000);
        let mut p = compile_sql(
            "SELECT w, COUNT(w) FROM big JOIN small ON big.a_id = small.id GROUP BY w",
            &c.schemas(),
        )
        .unwrap();
        let report = optimize(&mut p, &c).unwrap();
        // Replicating 50 dimension rows beats moving ~7/8 of 5050 rows.
        assert!(report.has("opt.dist_broadcast"), "{report:?}");
        assert!(!report.has("opt.dist_shuffle"));
        assert!(p.opt_tags.contains(&"opt.dist_broadcast".to_string()));
    }

    #[test]
    fn dist_strategy_shuffles_comparable_sides() {
        let c = join_catalog(3000, 4000);
        let mut p = compile_sql(
            "SELECT w, COUNT(w) FROM big JOIN small ON big.a_id = small.id GROUP BY w",
            &c.schemas(),
        )
        .unwrap();
        let report = optimize(&mut p, &c).unwrap();
        // Replicating 3000 build rows to 7 peers costs more than moving
        // ~7/8 of the 7000 total rows to their hash owners.
        assert!(report.has("opt.dist_shuffle"), "{report:?}");
        assert!(!report.has("opt.dist_broadcast"));
        assert!(p.opt_tags.contains(&"opt.dist_shuffle".to_string()));
    }

    #[test]
    fn order_sensitive_join_bodies_are_not_swapped() {
        let c = join_catalog(10, 1000);
        // A print in the join body is order-sensitive: no swap.
        let mut p = Program::new("printer")
            .with_relation("small", c.schemas()["small"].clone())
            .with_relation("big", c.schemas()["big"].clone());
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("small"),
            vec![Stmt::Loop(Loop::forelem(
                "j",
                IndexSet::filtered("big", "a_id", Expr::field("i", "id")),
                vec![Stmt::Print {
                    format: "{}".into(),
                    args: vec![Expr::field("j", "w")],
                }],
            ))],
        ))];
        let report = optimize(&mut p, &c).unwrap();
        assert!(!report.has("opt.join_build_side"));
        // Strategy decisions may annotate index sets, but the nest order
        // is untouched.
        let (o, i) = nest_relations(&p);
        assert_eq!((o.as_str(), i.as_str()), ("small", "big"));
    }

    #[test]
    fn guards_are_reordered_most_selective_first() {
        let mut t = Multiset::new(Schema::new(vec![
            ("a", DataType::Int),
            ("b", DataType::Int),
        ]));
        for i in 0..2000i64 {
            t.push(vec![Value::Int(i), Value::Int(i % 4)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("t", &t).unwrap();
        // Neither conjunct is an equality, so both stay in the guard
        // (split_filter only lifts equalities into the index filter).
        // `a >= 0` keeps every row (selectivity 1.0); `b < 2` keeps about
        // half — the optimizer must evaluate `b < 2` first.
        let mut p = compile_sql("SELECT a FROM t WHERE a >= 0 AND b < 2", &c.schemas()).unwrap();
        let p0 = p.clone();
        let report = optimize(&mut p, &c).unwrap();
        assert!(report.has("opt.filter_reorder"), "{report:?}");
        assert!(p.opt_tags.contains(&"opt.filter_reorder".to_string()));
        // The most selective conjunct now leads the chain.
        let Stmt::Loop(l) = &p.body[0] else { panic!("expected loop") };
        let [Stmt::If { cond, .. }] = l.body.as_slice() else {
            panic!("expected guard, got {:?}", l.body)
        };
        let parts = conjuncts(cond);
        let first = format!("{:?}", parts[0]);
        assert!(first.contains("\"b\""), "first conjunct should test b: {first}");
        // Semantics preserved.
        let a = crate::exec::run(&p0, &c).unwrap();
        let b = crate::exec::run(&p, &c).unwrap();
        assert!(a.result().unwrap().bag_eq(b.result().unwrap()));
        assert_eq!(a.result().unwrap().len(), 1000);
    }

    #[test]
    fn strategies_are_decided_and_tagged() {
        let c = join_catalog(100, 8000);
        let mut p = compile_sql(
            "SELECT small.g, big.w FROM big JOIN small ON big.a_id = small.id",
            &c.schemas(),
        )
        .unwrap();
        let report = optimize(&mut p, &c).unwrap();
        // The inner filtered loop is probed once per big row: hash wins.
        assert!(
            report.decisions.iter().any(|d| d.tag.starts_with("opt.strategy.")),
            "{report:?}"
        );
        assert!(p.opt_tags.iter().any(|t| t.starts_with("opt.strategy.")));
    }

    #[test]
    fn topk_strategy_heap_vs_sort_follows_the_group_estimate() {
        use crate::ir::TopKStrategy;
        let c = join_catalog(50, 5000);
        let emit_strategy = |p: &Program| {
            let Stmt::Loop(l) = &p.body[1] else {
                panic!("expected emit loop")
            };
            l.emit.as_ref().expect("emit annotation").strategy
        };
        // `small.g` has 7 distinct groups: k=3 < 7 → bounded heap.
        let mut p = compile_sql(
            "SELECT g, COUNT(g) FROM small GROUP BY g ORDER BY count DESC LIMIT 3",
            &c.schemas(),
        )
        .unwrap();
        let report = optimize(&mut p, &c).unwrap();
        assert!(report.has("opt.topk_heap"), "{report:?}");
        assert_eq!(emit_strategy(&p), TopKStrategy::Heap);
        assert!(p.opt_tags.contains(&"opt.topk_heap".to_string()));

        // k covering the whole domain → sort.
        let mut p = compile_sql(
            "SELECT g, COUNT(g) FROM small GROUP BY g ORDER BY count DESC LIMIT 500",
            &c.schemas(),
        )
        .unwrap();
        let report = optimize(&mut p, &c).unwrap();
        assert!(report.has("opt.topk_sort"), "{report:?}");
        assert_eq!(emit_strategy(&p), TopKStrategy::Sort);

        // No LIMIT → sort.
        let mut p = compile_sql(
            "SELECT g, COUNT(g) FROM small GROUP BY g ORDER BY g ASC",
            &c.schemas(),
        )
        .unwrap();
        let report = optimize(&mut p, &c).unwrap();
        assert!(report.has("opt.topk_sort"), "{report:?}");
        assert_eq!(emit_strategy(&p), TopKStrategy::Sort);

        // No ORDER BY/LIMIT → no top-k decision at all.
        let mut p = compile_sql("SELECT g, COUNT(g) FROM small GROUP BY g", &c.schemas()).unwrap();
        let report = optimize(&mut p, &c).unwrap();
        assert!(!report.has("opt.topk_heap") && !report.has("opt.topk_sort"));
    }

    #[test]
    fn ordered_join_nests_are_not_swapped() {
        // The emission contract's tie-breaking observes probe order:
        // the build-side swap must leave annotated nests alone.
        let c = join_catalog(50, 5000);
        let mut p = compile_sql(
            "SELECT small.g, big.w FROM small JOIN big ON small.id = big.a_id \
             ORDER BY w DESC LIMIT 4",
            &c.schemas(),
        )
        .unwrap();
        let report = optimize(&mut p, &c).unwrap();
        assert!(!report.has("opt.join_build_side"), "{report:?}");
        assert_eq!(nest_relations(&p), ("small".into(), "big".into()));
        // The top-k decision still fires.
        assert!(report.has("opt.topk_heap"), "{report:?}");
    }

    /// `logs(code rle-int, url dict-str, n int)` with compressed storage.
    fn compressed_catalog() -> StorageCatalog {
        use crate::storage::Table;
        let mut m = Multiset::new(Schema::new(vec![
            ("code", DataType::Int),
            ("url", DataType::Str),
            ("n", DataType::Int),
        ]));
        for i in 0..4000i64 {
            m.push(vec![
                Value::Int(i / 100),
                Value::str(format!("/u{}", i % 7)),
                Value::Int(i % 13),
            ]);
        }
        let mut t = Table::from_multiset(&m).unwrap();
        assert!(t.compress_int_field(0).unwrap());
        t.dict_encode_field(1).unwrap();
        let mut c = StorageCatalog::new();
        c.insert("logs", t);
        c
    }

    #[test]
    fn compressed_scans_are_tagged_from_column_stats() {
        let c = compressed_catalog();
        // Equality filter on the RLE column: run-domain filter.
        let mut p = compile_sql("SELECT n FROM logs WHERE code = 7", &c.schemas()).unwrap();
        let report = optimize(&mut p, &c).unwrap();
        assert!(report.has("opt.compressed_scan"), "{report:?}");
        assert!(p.opt_tags.contains(&"opt.compressed_scan".to_string()));
        let d = report
            .decisions
            .iter()
            .find(|d| d.tag == "opt.compressed_scan")
            .unwrap();
        assert!(d.detail.contains("40 runs / 4000 rows"), "{}", d.detail);

        // Fused group-by over the RLE key: run-domain aggregation.
        let mut p = compile_sql(
            "SELECT code, COUNT(code) FROM logs GROUP BY code",
            &c.schemas(),
        )
        .unwrap();
        let report = optimize(&mut p, &c).unwrap();
        assert!(report.has("opt.compressed_scan"), "{report:?}");

        // String equality on the dict column: one lookup, u32 compares.
        let mut p = compile_sql("SELECT n FROM logs WHERE url = '/u3'", &c.schemas()).unwrap();
        let report = optimize(&mut p, &c).unwrap();
        assert!(report.has("opt.compressed_scan"), "{report:?}");
        let d = report
            .decisions
            .iter()
            .find(|d| d.tag == "opt.compressed_scan")
            .unwrap();
        assert!(d.detail.contains("dict-code filter"), "{}", d.detail);
    }

    #[test]
    fn raw_columns_get_no_compressed_scan_tag() {
        let c = join_catalog(50, 5000);
        for q in [
            "SELECT w FROM big WHERE a_id = 3",
            "SELECT w, COUNT(w) FROM big GROUP BY w",
        ] {
            let mut p = compile_sql(q, &c.schemas()).unwrap();
            let report = optimize(&mut p, &c).unwrap();
            assert!(!report.has("opt.compressed_scan"), "`{q}`: {report:?}");
        }
    }

    /// Star fixtures: `fact` (20k rows, two dimension keys over 1000
    /// distinct values each), `dimd` tiny and *selective* (20 ids — 98%
    /// of fact rows match nothing), `dime` large (1000 ids × 2 rows).
    fn star_catalog() -> StorageCatalog {
        let mut fact = Multiset::new(Schema::new(vec![
            ("d_id", DataType::Int),
            ("e_id", DataType::Int),
            ("v", DataType::Int),
        ]));
        for i in 0..20_000i64 {
            fact.push(vec![
                Value::Int(i % 1000),
                Value::Int((i * 7) % 1000),
                Value::Int(i % 5),
            ]);
        }
        let mut dimd = Multiset::new(Schema::new(vec![
            ("id", DataType::Int),
            ("tag", DataType::Str),
        ]));
        for i in 0..20i64 {
            dimd.push(vec![Value::Int(i), Value::str(format!("t{}", i % 3))]);
        }
        let mut dime = Multiset::new(Schema::new(vec![
            ("id", DataType::Int),
            ("name", DataType::Str),
        ]));
        for i in 0..2000i64 {
            dime.push(vec![Value::Int(i % 1000), Value::str(format!("e{}", i % 11))]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("fact", &fact).unwrap();
        c.insert_multiset("dimd", &dimd).unwrap();
        c.insert_multiset("dime", &dime).unwrap();
        c
    }

    /// Relations down a join chain, outermost first.
    fn chain_relations(p: &Program) -> Vec<String> {
        let Stmt::Loop(outer) = &p.body[0] else {
            panic!("expected join nest")
        };
        let mut out = Vec::new();
        let mut cur = outer;
        loop {
            let Domain::IndexSet(ix) = &cur.domain else {
                panic!("expected index set")
            };
            out.push(ix.relation.clone());
            match cur.body.as_slice() {
                [Stmt::Loop(inner)] => cur = inner,
                _ => break,
            }
        }
        out
    }

    #[test]
    fn selinger_dp_reorders_a_three_table_star() {
        let c = star_catalog();
        // Written badly: the big unselective dimension joins first.
        let p0 = compile_sql(
            "SELECT tag, COUNT(tag) FROM fact \
             JOIN dime ON fact.e_id = dime.id \
             JOIN dimd ON fact.d_id = dimd.id GROUP BY tag",
            &c.schemas(),
        )
        .unwrap();
        assert_eq!(
            chain_relations(&p0),
            vec!["fact", "dime", "dimd"],
            "lowering preserves written order"
        );
        let mut p1 = p0.clone();
        let report = optimize(&mut p1, &c).unwrap();
        assert!(report.has("opt.join_order"), "{report:?}");
        assert!(p1.opt_tags.contains(&"opt.join_order".to_string()));
        // The selective dimension now probes first, pruning the stream.
        assert_eq!(chain_relations(&p1), vec!["fact", "dimd", "dime"]);
        let d = report
            .decisions
            .iter()
            .find(|d| d.tag == "opt.join_order")
            .unwrap();
        assert!(d.detail.contains("reordered from"), "{}", d.detail);
        // The two-table swap stays out of deeper chains.
        assert!(!report.has("opt.join_build_side"), "{report:?}");
        // Semantics preserved against the reference interpreter.
        let a = crate::exec::run(&p0, &c).unwrap();
        let b = crate::exec::run(&p1, &c).unwrap();
        assert!(a.result().unwrap().bag_eq(b.result().unwrap()));
    }

    #[test]
    fn well_written_star_is_kept_and_still_tagged() {
        let c = star_catalog();
        let mut p = compile_sql(
            "SELECT tag, COUNT(tag) FROM fact \
             JOIN dimd ON fact.d_id = dimd.id \
             JOIN dime ON fact.e_id = dime.id GROUP BY tag",
            &c.schemas(),
        )
        .unwrap();
        let report = optimize(&mut p, &c).unwrap();
        assert!(report.has("opt.join_order"), "{report:?}");
        assert_eq!(chain_relations(&p), vec!["fact", "dimd", "dime"]);
        let d = report
            .decisions
            .iter()
            .find(|d| d.tag == "opt.join_order")
            .unwrap();
        assert!(d.detail.contains("as written"), "{}", d.detail);
    }

    #[test]
    fn snowflake_reorder_keeps_edge_orientation_and_semantics() {
        // dimg hangs off dimd (snowflake): reordering must re-orient each
        // level's key filter along its unique tree edge.
        let mut c = star_catalog();
        let mut dimg = Multiset::new(Schema::new(vec![
            ("id", DataType::Int),
            ("label", DataType::Str),
        ]));
        for i in 0..3i64 {
            dimg.push(vec![Value::Int(i), Value::str(format!("g{i}"))]);
        }
        c.insert_multiset("dimg", &dimg).unwrap();
        let p0 = compile_sql(
            "SELECT label, COUNT(label) FROM fact \
             JOIN dime ON fact.e_id = dime.id \
             JOIN dimd ON fact.d_id = dimd.id \
             JOIN dimg ON dimd.id = dimg.id GROUP BY label",
            &c.schemas(),
        )
        .unwrap();
        let mut p1 = p0.clone();
        let report = optimize(&mut p1, &c).unwrap();
        assert!(report.has("opt.join_order"), "{report:?}");
        let order = chain_relations(&p1);
        assert_eq!(order.len(), 4);
        assert_eq!(order[0], "fact", "{order:?}");
        // dimg can only enter after its tree neighbor dimd.
        let dpos = order.iter().position(|r| r == "dimd").unwrap();
        let gpos = order.iter().position(|r| r == "dimg").unwrap();
        assert!(dpos < gpos, "{order:?}");
        let a = crate::exec::run(&p0, &c).unwrap();
        let b = crate::exec::run(&p1, &c).unwrap();
        assert!(a.result().unwrap().bag_eq(b.result().unwrap()));
    }

    #[test]
    fn ordered_or_filtered_chains_are_not_reordered() {
        let c = star_catalog();
        // An ORDER BY/LIMIT emission pins the nest (tie-breaking observes
        // emission order), exactly like the two-table swap.
        let mut p = compile_sql(
            "SELECT fact.v, dimd.tag, dime.name FROM fact \
             JOIN dime ON fact.e_id = dime.id \
             JOIN dimd ON fact.d_id = dimd.id ORDER BY v DESC LIMIT 3",
            &c.schemas(),
        )
        .unwrap();
        let report = optimize(&mut p, &c).unwrap();
        assert!(!report.has("opt.join_order"), "{report:?}");
        assert_eq!(chain_relations(&p), vec!["fact", "dime", "dimd"]);
        // A WHERE equality lifted onto the outer index set pins it too.
        let mut p = compile_sql(
            "SELECT tag, COUNT(tag) FROM fact \
             JOIN dime ON fact.e_id = dime.id \
             JOIN dimd ON fact.d_id = dimd.id \
             WHERE fact.v = 3 GROUP BY tag",
            &c.schemas(),
        )
        .unwrap();
        let report = optimize(&mut p, &c).unwrap();
        assert!(!report.has("opt.join_order"), "{report:?}");
    }

    #[test]
    fn estimates_cover_the_optimized_loops() {
        let c = join_catalog(50, 5000);
        let mut p = compile_sql(
            "SELECT g, COUNT(g) FROM small JOIN big ON small.id = big.a_id GROUP BY g",
            &c.schemas(),
        )
        .unwrap();
        let report = optimize(&mut p, &c).unwrap();
        // Join nest (2 loops) + distinct emit loop.
        assert!(report.estimates.len() >= 3, "{:?}", report.estimates);
        assert!(report.estimates[0].rows_in > 0);
    }
}
