//! Loop interchange (§III-B): "the loop interchange transformation is used
//! to push any conditions on data to outer loops to decrease the amount of
//! data that needs to be read as much as possible."
//!
//! For a perfect nest `forelem i ∈ pA { forelem j ∈ pB.f[c] { ... } }`
//! where the inner filter value `c` does NOT depend on the outer cursor,
//! the filtered loop can move outward, so the filter is evaluated once
//! instead of |A| times. Legal when the body is reduction-style
//! (order-free appends/accumulations).

use anyhow::Result;

use crate::ir::{Domain, Loop, LoopKind, Program, Stmt};

use super::pass::{Pass, PassCtx};

pub struct LoopInterchange;

impl Pass for LoopInterchange {
    fn name(&self) -> &'static str {
        "loop-interchange"
    }

    fn run(&self, p: &mut Program, _ctx: &PassCtx) -> Result<bool> {
        let mut changed = false;
        for s in &mut p.body {
            changed |= interchange_stmt(s);
        }
        Ok(changed)
    }
}

fn interchange_stmt(s: &mut Stmt) -> bool {
    let Stmt::Loop(outer) = s else { return false };
    let mut changed = false;
    // Recurse first (innermost-out canonicalization).
    for b in &mut outer.body {
        changed |= interchange_stmt(b);
    }
    if should_swap(outer) {
        swap_nest(outer);
        changed = true;
    }
    changed
}

/// Swap when: perfect 2-nest, outer is an UNfiltered forelem, inner is a
/// FILTERED forelem whose filter value doesn't reference the outer var,
/// and the body is order-free.
fn should_swap(outer: &Loop) -> bool {
    if outer.kind != LoopKind::Forelem {
        return false;
    }
    // An ordered/bounded emission pins the nest: interchange reorders the
    // emission sequence, which the emit contract's tie-breaking observes.
    if outer.emit.is_some() {
        return false;
    }
    let Domain::IndexSet(oix) = &outer.domain else {
        return false;
    };
    if oix.field_filter.is_some() || oix.distinct.is_some() || oix.partition.is_some() {
        return false;
    }
    let [Stmt::Loop(inner)] = outer.body.as_slice() else {
        return false;
    };
    if inner.kind != LoopKind::Forelem || inner.emit.is_some() {
        return false;
    }
    let Domain::IndexSet(iix) = &inner.domain else {
        return false;
    };
    let Some((_, filter_value)) = &iix.field_filter else {
        return false;
    };
    // Filter must be outer-invariant.
    if filter_value.used_vars().contains(&outer.var) {
        return false;
    }
    // Body must be order-free (reductions/appends only).
    let body_ok = inner.body.iter().all(|s| {
        let mut ok = true;
        s.walk(&mut |sub| match sub {
            Stmt::Assign { .. } => ok = false,
            Stmt::Accum { op, .. } if *op == crate::ir::AccumOp::Set => ok = false,
            _ => {}
        });
        ok
    });
    body_ok
}

fn swap_nest(outer: &mut Loop) {
    let Stmt::Loop(inner) = outer.body.pop().unwrap() else {
        unreachable!()
    };
    // outer { inner { B } }  →  inner { outer { B } }
    let new_inner = Loop {
        kind: outer.kind,
        var: outer.var.clone(),
        domain: outer.domain.clone(),
        body: inner.body, // B moves under the (old) outer header
        emit: None,       // should_swap rejects annotated nests
    };
    outer.kind = inner.kind;
    outer.var = inner.var;
    outer.domain = inner.domain;
    outer.body = vec![Stmt::Loop(new_inner)];
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec;
    use crate::ir::{
        pretty, DataType, Expr, IndexSet, Multiset, Schema, Value,
    };
    use crate::storage::StorageCatalog;

    fn setup() -> (Program, StorageCatalog) {
        let a = Schema::new(vec![("x", DataType::Int)]);
        let b = Schema::new(vec![("id", DataType::Int), ("y", DataType::Int)]);
        let mut c = StorageCatalog::new();
        let mut ma = Multiset::new(a.clone());
        for i in 0..10 {
            ma.push(vec![Value::Int(i)]);
        }
        let mut mb = Multiset::new(b.clone());
        for i in 0..10 {
            mb.push(vec![Value::Int(i % 3), Value::Int(100 + i)]);
        }
        c.insert_multiset("A", &ma).unwrap();
        c.insert_multiset("B", &mb).unwrap();

        // forelem i∈pA { forelem j∈pB.id[1] { R ∪= (i.x, j.y) } }
        // The inner filter is constant → interchange should hoist it.
        let mut p = Program::new("nest")
            .with_relation("A", a)
            .with_relation("B", b)
            .with_result(
                "R",
                Schema::new(vec![("x", DataType::Int), ("y", DataType::Int)]),
            );
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("A"),
            vec![Stmt::Loop(Loop::forelem(
                "j",
                IndexSet::filtered("B", "id", Expr::int(1)),
                vec![Stmt::result_union(
                    "R",
                    vec![Expr::field("i", "x"), Expr::field("j", "y")],
                )],
            ))],
        ))];
        (p, c)
    }

    #[test]
    fn hoists_constant_filter_outward() {
        let (mut p, _c) = setup();
        assert!(LoopInterchange.run(&mut p, &PassCtx::new()).unwrap());
        let text = pretty::program(&p);
        // The filtered loop over B is now outermost.
        let first_loop_line = text.lines().find(|l| l.contains("forelem")).unwrap();
        assert!(first_loop_line.contains("pB.id[1]"), "{text}");
    }

    #[test]
    fn interchange_preserves_semantics() {
        let (base, c) = setup();
        let reference = exec::run(&base, &c).unwrap();
        let mut p = base.clone();
        LoopInterchange.run(&mut p, &PassCtx::new()).unwrap();
        crate::ir::validate(&p).unwrap();
        let out = exec::run(&p, &c).unwrap();
        assert!(out.result().unwrap().bag_eq(reference.result().unwrap()));
    }

    #[test]
    fn interchange_reduces_rows_visited() {
        let (base, c) = setup();
        let before = exec::run(&base, &c).unwrap().stats.rows_visited;
        let mut p = base.clone();
        LoopInterchange.run(&mut p, &PassCtx::new()).unwrap();
        let after = exec::run(&p, &c).unwrap().stats.rows_visited;
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn correlated_filter_is_not_interchanged() {
        let (mut p, _c) = setup();
        // Make the filter depend on the outer cursor (a real join).
        if let Stmt::Loop(outer) = &mut p.body[0] {
            if let Stmt::Loop(inner) = &mut outer.body[0] {
                inner.index_set_mut().unwrap().field_filter =
                    Some(("id".into(), Expr::field("i", "x")));
            }
        }
        assert!(!LoopInterchange.run(&mut p, &PassCtx::new()).unwrap());
    }
}
