//! Orthogonalization → indirect data partitioning (§III-A1).
//!
//! Instead of blocking the iterated index set, the loop is blocked on the
//! *value range* of one of the accessed fields. `forelem (i; i ∈ pA) SEQ`
//! becomes
//!
//! ```text
//! forall (k = 1; k <= N; k++)
//!   for (l ∈ X_k)                  // X = A.field1, X = X_1 ∪ ... ∪ X_N
//!     forelem (i; i ∈ pA.field1[l]) SEQ'
//! ```
//!
//! Processor `P_k` handles exactly the tuples whose `field1` falls in its
//! value segment — which is what makes two loops partitioned on the *same*
//! field use the same data distribution (§III-A4), and what the
//! distribution optimizer exploits.
//!
//! Privatization of reduction state is shared with blocking.rs; here the
//! leading dimension is still `k`, but because partitioning is by value,
//! per-key accumulator slots are written by exactly ONE partition — the
//! property that removes cross-partition reduction from the merge path
//! (each key's total lives in a single partition's slice).

use anyhow::{bail, Result};

use crate::ir::{Domain, Expr, IndexSet, Loop, LoopKind, Program, Stmt, Strategy, Value};

use super::blocking;
use super::pass::{Pass, PassCtx};

/// Indirectly partition the first eligible top-level forelem on the given
/// field (pass form used by pipelines; the driver usually calls
/// [`parallelize_indirect`] with an explicit loop index + field).
pub struct IndirectPartition {
    pub field: String,
}

impl Pass for IndirectPartition {
    fn name(&self) -> &'static str {
        "indirect-partition"
    }

    fn run(&self, p: &mut Program, ctx: &PassCtx) -> Result<bool> {
        if ctx.processors <= 1 {
            return Ok(false);
        }
        for idx in 0..p.body.len() {
            if eligible(&p.body[idx], &self.field) {
                parallelize_indirect(p, idx, &self.field, ctx.processors)?;
                return Ok(true);
            }
        }
        Ok(false)
    }
}

fn eligible(s: &Stmt, field: &str) -> bool {
    let Stmt::Loop(l) = s else { return false };
    if l.kind != LoopKind::Forelem {
        return false;
    }
    let Some(ix) = l.index_set() else {
        return false;
    };
    if ix.field_filter.is_some() || ix.distinct.is_some() || ix.partition.is_some() {
        return false;
    }
    // An ordered/bounded emission contract would be broken by per-value
    // blocking (the bound would apply per partition, not globally).
    if l.emit.is_some() {
        return false;
    }
    // The partitioning field must exist — validated against the relation
    // schema by the caller via Program::relations.
    let _ = field;
    crate::analysis::is_parallelizable(l)
}

/// Apply indirect partitioning on `field` to `p.body[idx]`.
pub fn parallelize_indirect(p: &mut Program, idx: usize, field: &str, n: usize) -> Result<()> {
    let Stmt::Loop(l) = p.body[idx].clone() else {
        bail!("statement {idx} is not a loop");
    };
    if !eligible(&p.body[idx], field) {
        bail!("loop {idx} is not an indirect-partitioning candidate");
    }
    let Some(ix) = l.index_set() else { unreachable!() };
    let relation = ix.relation.clone();
    let Some(schema) = p.relations.get(&relation) else {
        bail!("unknown relation `{relation}`");
    };
    if schema.field_id(field).is_none() {
        bail!("relation `{relation}` has no field `{field}`");
    }

    p.params.insert("N".into(), Value::Int(n as i64));
    let kvar = p.fresh_var("k");
    let lvar = p.fresh_var("l");

    // Privatize reduction state exactly as direct partitioning does.
    let du = crate::analysis::stmt_defuse(&p.body[idx], &[]);
    let privatized = du.arrays_def.clone();

    let mut inner = l.clone();
    inner.domain = Domain::IndexSet(
        IndexSet::filtered(&relation, field, Expr::var(&lvar)).with_strategy(Strategy::Hash),
    );
    for s in &mut inner.body {
        blocking_privatize(s, &privatized, &kvar);
    }
    for a in &privatized {
        if let Some(decl) = p.arrays.get_mut(a) {
            decl.dims += 1;
        }
    }

    let value_loop = Loop {
        kind: LoopKind::For,
        var: lvar.clone(),
        domain: Domain::ValuePartition {
            relation: relation.clone(),
            field: field.to_string(),
            part: Expr::var(&kvar),
            parts: Expr::var("N"),
        },
        body: vec![Stmt::Loop(inner)],
        emit: None,
    };
    let forall = Loop {
        kind: LoopKind::Forall,
        var: kvar.clone(),
        domain: Domain::Range {
            lo: Expr::int(1),
            hi: Expr::var("N"),
        },
        body: vec![Stmt::Loop(value_loop)],
        emit: None,
    };
    p.body[idx] = Stmt::Loop(forall);

    for s in p.body.iter_mut().skip(idx + 1) {
        blocking_rewrite_reads(s, &privatized, &kvar);
    }
    Ok(())
}

// Share the privatization helpers with blocking.rs (they are identical
// mechanics; only the iteration domain differs).
fn blocking_privatize(
    s: &mut Stmt,
    arrays: &std::collections::BTreeSet<String>,
    k: &str,
) {
    blocking::privatize_stmt(s, arrays, &Default::default(), k);
}

fn blocking_rewrite_reads(
    s: &mut Stmt,
    arrays: &std::collections::BTreeSet<String>,
    k: &str,
) {
    blocking::rewrite_reads(s, arrays, &Default::default(), k);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec;
    use crate::ir::{pretty, Multiset, Schema};
    use crate::sql::compile_sql;
    use crate::storage::StorageCatalog;

    fn catalog() -> StorageCatalog {
        let schema = Schema::new(vec![("url", crate::ir::DataType::Str)]);
        let mut m = Multiset::new(schema);
        for u in ["/a", "/b", "/a", "/c", "/a", "/b", "/d", "/e", "/c"] {
            m.push(vec![Value::str(u)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        c
    }

    #[test]
    fn produces_the_papers_indirect_shape() {
        let c = catalog();
        let mut p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        parallelize_indirect(&mut p, 0, "url", 4).unwrap();
        let text = pretty::program(&p);
        assert!(text.contains("forall (k = 1; k <= N; k++)"), "{text}");
        assert!(text.contains("for (l ∈ X_k)  // X = access.url"), "{text}");
        assert!(text.contains("i ∈ paccess.url[l]"), "{text}");
        assert!(text.contains("agg1[k][i.url]++;"), "{text}");
    }

    #[test]
    fn indirect_partitioning_preserves_semantics() {
        let c = catalog();
        let base = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        let reference = exec::run(&base, &c).unwrap();
        for n in [2, 3, 5, 8] {
            let mut p = base.clone();
            parallelize_indirect(&mut p, 0, "url", n).unwrap();
            crate::ir::validate(&p).unwrap();
            let out = exec::run(&p, &c).unwrap();
            assert!(
                out.result().unwrap().bag_eq(reference.result().unwrap()),
                "N={n}: {:?}",
                out.result().unwrap()
            );
        }
    }

    #[test]
    fn rejects_unknown_field() {
        let c = catalog();
        let mut p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        assert!(parallelize_indirect(&mut p, 0, "nope", 4).is_err());
    }
}
