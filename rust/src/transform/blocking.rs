//! Loop blocking → direct data partitioning (§III-A1).
//!
//! `forelem (i; i ∈ pA) SEQ` becomes
//!
//! ```text
//! forall (k = 1; k <= N; k++)
//!   forelem (i; i ∈ p_k A) SEQ'
//! ```
//!
//! where `pA = p_1A ∪ ... ∪ p_NA` and `SEQ'` is `SEQ` with its reduction
//! state *privatized*: every accumulator array the body writes gains a
//! leading partition dimension (`count` → `count_k`, §IV), and every
//! later read of such an array is rewritten to the cross-partition
//! reduction `Σ_{k=1}^{N} count_k[...]` — the Iteration Space Expansion +
//! Code Motion the paper applies before parallelizing the URL-count
//! query. Scalar reduction accumulators (`avg += ...`) are expanded the
//! same way (scalar → 1-dim array indexed by k, final `Assign` of the
//! sum).

use std::collections::BTreeSet;

use anyhow::{bail, Result};

use crate::analysis::{is_parallelizable, stmt_defuse};
use crate::ir::{
    ArrayDecl, BinOp, DataType, Expr, Loop, LoopKind, Program, Stmt, Value,
};

use super::pass::{Pass, PassCtx};

/// Parallelize every parallelizable top-level forelem by direct
/// partitioning into `ctx.processors` parts.
pub struct DirectPartition;

impl Pass for DirectPartition {
    fn name(&self) -> &'static str {
        "direct-partition"
    }

    fn run(&self, p: &mut Program, ctx: &PassCtx) -> Result<bool> {
        if ctx.processors <= 1 {
            return Ok(false);
        }
        let mut changed = false;
        for idx in 0..p.body.len() {
            if candidate(&p.body[idx]) {
                parallelize_direct(p, idx, ctx.processors)?;
                changed = true;
            }
        }
        Ok(changed)
    }
}

/// Is this statement a plain full-table forelem we can block?
fn candidate(s: &Stmt) -> bool {
    let Stmt::Loop(l) = s else { return false };
    if l.kind != LoopKind::Forelem {
        return false;
    }
    let Some(ix) = l.index_set() else {
        return false;
    };
    // Only full scans get blocked; distinct/filtered loops iterate reduced
    // domains and stay sequential (they are the cheap reduction side).
    if ix.field_filter.is_some() || ix.distinct.is_some() || ix.partition.is_some() {
        return false;
    }
    // Ordered/bounded emissions must stay whole: blocking would apply the
    // bound per partition instead of globally (the parallel driver has a
    // dedicated top-k fan-out with a k-way merge instead).
    if l.emit.is_some() {
        return false;
    }
    is_parallelizable_with_scalars(l)
}

/// Like `analysis::is_parallelizable` but additionally accepts scalar
/// `x = x + e` self-accumulations (we expand them).
fn is_parallelizable_with_scalars(l: &Loop) -> bool {
    if is_parallelizable(l) {
        return true;
    }
    // Re-check: allow Assign(var, var + e) forms only.
    let mut ok = true;
    for s in &l.body {
        s.walk(&mut |sub| match sub {
            Stmt::Assign { var, value } => {
                if !is_self_add(var, value) {
                    ok = false;
                }
            }
            Stmt::Accum { op, .. } if *op == crate::ir::AccumOp::Set => ok = false,
            _ => {}
        });
    }
    ok
}

fn is_self_add(var: &str, value: &Expr) -> bool {
    // var + e  or  e + var at the top level.
    if let Expr::Binary {
        op: BinOp::Add,
        lhs,
        rhs,
    } = value
    {
        let is_var = |e: &Expr| matches!(e, Expr::Var(v) if v == var);
        return is_var(lhs) || is_var(rhs);
    }
    false
}

/// Apply direct partitioning to `p.body[idx]` with `n` processors.
///
/// Declares/uses the parameter `N` (created if absent), privatizes the
/// written arrays, and rewrites downstream reads into `SumOverParts`.
pub fn parallelize_direct(p: &mut Program, idx: usize, n: usize) -> Result<()> {
    let Stmt::Loop(l) = p.body[idx].clone() else {
        bail!("statement {idx} is not a loop");
    };
    if !candidate(&p.body[idx]) {
        bail!("loop {idx} is not a direct-partitioning candidate");
    }

    p.params.insert("N".into(), Value::Int(n as i64));
    let kvar = p.fresh_var("k");

    // 1. Collect reduction state written by the body.
    let du = stmt_defuse(&p.body[idx], &[]);
    let privatized: BTreeSet<String> = du.arrays_def.clone();
    let scalars: BTreeSet<String> = du.scalars_def.clone();

    // 2. Rewrite the body: arrays gain leading [k], scalar accumulators
    //    become arrays indexed by [k].
    let mut inner = l.clone();
    if let Some(ix) = inner.index_set_mut() {
        *ix = ix
            .clone()
            .with_partition(Expr::var(&kvar), Expr::var("N"));
    }
    for s in &mut inner.body {
        privatize_stmt(s, &privatized, &scalars, &kvar);
    }

    // 3. Bump array declarations and convert expanded scalars to arrays.
    for a in &privatized {
        if let Some(decl) = p.arrays.get_mut(a) {
            decl.dims += 1;
        }
    }
    for v in &scalars {
        let init = p
            .scalars
            .remove(v)
            .unwrap_or(Value::Int(0));
        let dtype = match init {
            Value::Float(_) => DataType::Float,
            _ => DataType::Int,
        };
        p.arrays.insert(
            v.clone(),
            ArrayDecl {
                dims: 1,
                dtype,
                init,
            },
        );
    }

    // 4. Wrap in forall k = 1..N.
    let forall = Loop {
        kind: LoopKind::Forall,
        var: kvar.clone(),
        domain: crate::ir::Domain::Range {
            lo: Expr::int(1),
            hi: Expr::var("N"),
        },
        body: vec![Stmt::Loop(inner)],
        emit: None,
    };
    p.body[idx] = Stmt::Loop(forall);

    // 5. Rewrite later reads of privatized arrays / expanded scalars into
    //    cross-partition sums.
    for s in p.body.iter_mut().skip(idx + 1) {
        rewrite_reads(s, &privatized, &scalars, &kvar);
    }
    // Scalar reads may also occur in earlier prints — handle whole body
    // for scalars (they were scalars before; any read means "current
    // total", which before the loop is the init — keeping rewrite to
    // later statements is the conservative, correct choice).
    Ok(())
}

pub(crate) fn privatize_stmt(s: &mut Stmt, arrays: &BTreeSet<String>, scalars: &BTreeSet<String>, k: &str) {
    match s {
        Stmt::Accum { array, indices, .. } => {
            if arrays.contains(array) {
                indices.insert(0, Expr::var(k));
            }
        }
        Stmt::Assign { var, value } => {
            if scalars.contains(var) {
                // x = x + e  →  x[k] += e
                let e = strip_self_add(var, value);
                *s = Stmt::Accum {
                    array: var.clone(),
                    indices: vec![Expr::var(k)],
                    op: crate::ir::AccumOp::Add,
                    value: e,
                };
                // Re-run on the new accum for nested array reads below.
                privatize_reads_in_stmt(s, arrays, scalars, k);
                return;
            }
        }
        Stmt::Loop(l) => {
            for b in &mut l.body {
                privatize_stmt(b, arrays, scalars, k);
            }
        }
        Stmt::If { then, els, .. } => {
            for b in then.iter_mut().chain(els.iter_mut()) {
                privatize_stmt(b, arrays, scalars, k);
            }
        }
        _ => {}
    }
    privatize_reads_in_stmt(s, arrays, scalars, k);
}

/// Reads of a privatized array inside the parallel body refer to this
/// partition's slice.
fn privatize_reads_in_stmt(
    s: &mut Stmt,
    arrays: &BTreeSet<String>,
    scalars: &BTreeSet<String>,
    k: &str,
) {
    s.walk_exprs_mut(&mut |e| match e {
        Expr::ArrayRef { array, indices } if arrays.contains(array) => {
            // Avoid double-prefixing (walk_exprs_mut is post-order; the
            // Accum path above may already have inserted k).
            if indices.first() != Some(&Expr::var(k)) {
                indices.insert(0, Expr::var(k));
            }
        }
        Expr::Var(v) if scalars.contains(v) => {
            *e = Expr::array(v, vec![Expr::var(k)]);
        }
        _ => {}
    });
}

fn strip_self_add(var: &str, value: &Expr) -> Expr {
    if let Expr::Binary {
        op: BinOp::Add,
        lhs,
        rhs,
    } = value
    {
        if matches!(lhs.as_ref(), Expr::Var(v) if v == var) {
            return (**rhs).clone();
        }
        if matches!(rhs.as_ref(), Expr::Var(v) if v == var) {
            return (**lhs).clone();
        }
    }
    value.clone()
}

/// Rewrite reads in post-loop statements: `count[x]` → `Σ_k count[k][x]`,
/// scalar `avg` → `Σ_k avg[k]`.
pub(crate) fn rewrite_reads(s: &mut Stmt, arrays: &BTreeSet<String>, scalars: &BTreeSet<String>, kvar: &str) {
    let sum_var = format!("{kvar}s"); // fresh-ish; distinct from loop vars
    s.walk_exprs_mut(&mut |e| match e {
        Expr::ArrayRef { array, indices } if arrays.contains(array) => {
            let mut inner_idx = vec![Expr::var(&sum_var)];
            inner_idx.extend(indices.clone());
            *e = Expr::SumOverParts {
                var: sum_var.clone(),
                parts: Box::new(Expr::var("N")),
                body: Box::new(Expr::ArrayRef {
                    array: array.clone(),
                    indices: inner_idx,
                }),
            };
        }
        Expr::Var(v) if scalars.contains(v) => {
            *e = Expr::SumOverParts {
                var: sum_var.clone(),
                parts: Box::new(Expr::var("N")),
                body: Box::new(Expr::array(v, vec![Expr::var(&sum_var)])),
            };
        }
        _ => {}
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec;
    use crate::ir::{pretty, IndexSet, Multiset, Schema};
    use crate::sql::compile_sql;
    use crate::storage::StorageCatalog;

    fn access_catalog() -> StorageCatalog {
        let schema = Schema::new(vec![("url", DataType::Str)]);
        let mut m = Multiset::new(schema);
        for u in ["/a", "/b", "/a", "/c", "/a", "/b", "/d"] {
            m.push(vec![Value::str(u)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        c
    }

    #[test]
    fn produces_the_papers_parallel_shape() {
        let c = access_catalog();
        let mut p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        let changed = DirectPartition
            .run(&mut p, &PassCtx::new().with_processors(4))
            .unwrap();
        assert!(changed);
        let text = pretty::program(&p);
        // §IV's parallelized URL count: forall + partitioned index set +
        // privatized count + Σ_k read-back.
        assert!(text.contains("forall (k = 1; k <= N; k++)"), "{text}");
        assert!(text.contains("p_kaccess"), "{text}");
        assert!(text.contains("agg1[k][i.url]++;"), "{text}");
        assert!(text.contains("sum(ks=1..N; agg1[ks][i.url])"), "{text}");
    }

    #[test]
    fn parallelized_program_is_semantically_equal() {
        let c = access_catalog();
        let base = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        let reference = exec::run(&base, &c).unwrap();
        for n in [2, 3, 4, 7, 16] {
            let mut p = base.clone();
            DirectPartition
                .run(&mut p, &PassCtx::new().with_processors(n))
                .unwrap();
            let out = exec::run(&p, &c).unwrap();
            assert!(
                out.result().unwrap().bag_eq(reference.result().unwrap()),
                "N={n}"
            );
        }
    }

    #[test]
    fn scalar_accumulator_is_expanded() {
        let c = {
            let mut c = StorageCatalog::new();
            let m = Multiset::with_rows(
                Schema::new(vec![("g", DataType::Float), ("w", DataType::Float)]),
                vec![
                    vec![Value::Float(8.0), Value::Float(0.5)],
                    vec![Value::Float(6.0), Value::Float(0.5)],
                ],
            );
            c.insert_multiset("Grades", &m).unwrap();
            c
        };
        let mut p = Program::new("avg")
            .with_relation("Grades", c.schemas()["Grades"].clone())
            .with_scalar("avg", Value::Float(0.0));
        p.body = vec![
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::all("Grades"),
                vec![Stmt::assign(
                    "avg",
                    Expr::add(
                        Expr::var("avg"),
                        Expr::mul(Expr::field("i", "g"), Expr::field("i", "w")),
                    ),
                )],
            )),
            Stmt::Print {
                format: "{}".into(),
                args: vec![Expr::var("avg")],
            },
        ];
        DirectPartition
            .run(&mut p, &PassCtx::new().with_processors(2))
            .unwrap();
        let out = exec::run(&p, &c).unwrap();
        assert_eq!(out.prints, vec!["7".to_string()]);
    }

    #[test]
    fn filtered_loops_are_not_blocked() {
        let c = access_catalog();
        let mut p = compile_sql(
            "SELECT url FROM access WHERE url = '/a'",
            &c.schemas(),
        )
        .unwrap();
        // Body is one filtered loop — no candidates.
        assert!(!DirectPartition
            .run(&mut p, &PassCtx::new().with_processors(4))
            .unwrap());
    }
}
