//! Index-set materialization: decide *how* each forelem loop iterates
//! (§II, Figure 1).
//!
//! "At a later compilation stage, the compiler determines how to actually
//! execute the iteration specified by a forelem loop and accompanied
//! index set. This may be done by nested loops iteration, but also through
//! the use of hash functions or tree-based indexes."
//!
//! For every filtered index set still `Unspecified`, the pass estimates
//! how many times the loop will be *entered* (probes) from its enclosing
//! loops, pulls table statistics from the storage catalog, and asks the
//! cost model (analysis::cost) to pick Scan / Hash / Tree.

use anyhow::Result;

use crate::analysis::{choose_strategy, TableStats};
use crate::ir::{Domain, Program, Stmt, Strategy};

use super::pass::{Pass, PassCtx};

pub struct Materialize;

impl Pass for Materialize {
    fn name(&self) -> &'static str {
        "materialize"
    }

    fn run(&self, p: &mut Program, ctx: &PassCtx) -> Result<bool> {
        let Some(catalog) = ctx.catalog else {
            return Ok(false); // no statistics, leave strategies abstract
        };
        let mut changed = false;
        let relations = p.relations.clone();
        for s in &mut p.body {
            changed |= decide(s, 1, &|rel, field| {
                let fid = relations
                    .get(rel)
                    .and_then(|sch| sch.field_id(field));
                catalog
                    .stats(rel, fid)
                    .unwrap_or(TableStats::new(1024, 32))
            }, &|rel| {
                catalog
                    .stats(rel, None)
                    .map(|s| s.rows)
                    .unwrap_or(1024)
            });
        }
        Ok(changed)
    }
}

/// Recursively assign strategies. `probes` is the estimated number of
/// times this statement executes (product of enclosing loop trip counts).
fn decide(
    s: &mut Stmt,
    probes: u64,
    stats_of: &dyn Fn(&str, &str) -> TableStats,
    rows_of: &dyn Fn(&str) -> u64,
) -> bool {
    let Stmt::Loop(l) = s else { return false };
    let mut changed = false;
    #[allow(unused_assignments)]
    let mut inner_probes = probes;
    match &mut l.domain {
        Domain::IndexSet(ix) => {
            if let Some((field, _)) = &ix.field_filter {
                if ix.strategy == Strategy::Unspecified {
                    let stats = stats_of(&ix.relation, field);
                    let chosen = choose_strategy(stats, probes, false);
                    ix.strategy = chosen;
                    changed = true;
                }
                // Expected matches per probe.
                let stats = stats_of(&ix.relation, ix.field_filter.as_ref().map(|(f, _)| f.as_str()).unwrap());
                inner_probes = probes * (stats.rows / stats.distinct_keys).max(1);
            } else if ix.distinct.is_some() {
                let stats = stats_of(&ix.relation, ix.distinct.as_deref().unwrap());
                if ix.strategy == Strategy::Unspecified {
                    ix.strategy = Strategy::Scan; // distinct directory is its own structure
                    changed = true;
                }
                inner_probes = probes * stats.distinct_keys.max(1);
            } else {
                if ix.strategy == Strategy::Unspecified {
                    ix.strategy = Strategy::Scan;
                    changed = true;
                }
                inner_probes = probes * rows_of(&ix.relation).max(1);
            }
        }
        Domain::Range { .. } => {
            // Unknown trip count (params); assume modest fan-out.
            inner_probes = probes * 8;
        }
        Domain::ValuePartition { relation, field, .. } => {
            let stats = stats_of(relation, field);
            inner_probes = probes * (stats.distinct_keys / 8).max(1);
        }
        Domain::DistinctValues { relation, field } => {
            let stats = stats_of(relation, field);
            inner_probes = probes * stats.distinct_keys.max(1);
        }
    }
    for b in &mut l.body {
        changed |= decide(b, inner_probes, stats_of, rows_of);
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Multiset, Schema, Value};
    use crate::sql::compile_sql;
    use crate::storage::StorageCatalog;

    fn catalog(rows: usize) -> StorageCatalog {
        let a = Schema::new(vec![("b_id", DataType::Int), ("f", DataType::Int)]);
        let b = Schema::new(vec![("id", DataType::Int), ("g", DataType::Int)]);
        let mut ma = Multiset::new(a);
        let mut mb = Multiset::new(b);
        for i in 0..rows {
            ma.push(vec![Value::Int((i % 100) as i64), Value::Int(i as i64)]);
            mb.push(vec![Value::Int((i % 100) as i64), Value::Int(i as i64)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("A", &ma).unwrap();
        c.insert_multiset("B", &mb).unwrap();
        c
    }

    fn inner_strategy(p: &Program) -> Strategy {
        let Stmt::Loop(outer) = &p.body[0] else { panic!() };
        let Stmt::Loop(inner) = &outer.body[0] else { panic!() };
        inner.index_set().unwrap().strategy
    }

    #[test]
    fn join_inner_loop_gets_hash_index_on_large_tables() {
        let c = catalog(5000);
        let mut p = compile_sql(
            "SELECT A.f, B.g FROM A JOIN B ON A.b_id = B.id",
            &c.schemas(),
        )
        .unwrap();
        assert_eq!(inner_strategy(&p), Strategy::Unspecified);
        let changed = Materialize
            .run(&mut p, &PassCtx::new().with_catalog(&c))
            .unwrap();
        assert!(changed);
        assert_eq!(inner_strategy(&p), Strategy::Hash);
    }

    #[test]
    fn single_probe_lookup_stays_scan() {
        let c = catalog(200);
        // Top-level filtered loop: probed once.
        let mut p = compile_sql("SELECT f FROM A WHERE b_id = 7", &c.schemas()).unwrap();
        Materialize
            .run(&mut p, &PassCtx::new().with_catalog(&c))
            .unwrap();
        let Stmt::Loop(l) = &p.body[0] else { panic!() };
        assert_eq!(l.index_set().unwrap().strategy, Strategy::Scan);
    }

    #[test]
    fn no_catalog_means_no_decision() {
        let c = catalog(100);
        let mut p = compile_sql(
            "SELECT A.f, B.g FROM A JOIN B ON A.b_id = B.id",
            &c.schemas(),
        )
        .unwrap();
        assert!(!Materialize.run(&mut p, &PassCtx::new()).unwrap());
        assert_eq!(inner_strategy(&p), Strategy::Unspecified);
    }

    #[test]
    fn already_specified_strategies_are_untouched() {
        let c = catalog(5000);
        let mut p = compile_sql(
            "SELECT A.f, B.g FROM A JOIN B ON A.b_id = B.id",
            &c.schemas(),
        )
        .unwrap();
        if let Stmt::Loop(outer) = &mut p.body[0] {
            if let Stmt::Loop(inner) = &mut outer.body[0] {
                inner.index_set_mut().unwrap().strategy = Strategy::Tree;
            }
        }
        Materialize
            .run(&mut p, &PassCtx::new().with_catalog(&c))
            .unwrap();
        assert_eq!(inner_strategy(&p), Strategy::Tree);
    }
}
