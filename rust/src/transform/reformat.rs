//! Data reformatting (§III-C1) — the transformation behind Figure 2's
//! "integer keyed" and "relayout" bars.
//!
//! The compiler controls both *how tuples are stored* and *the structure
//! of the tuples themselves*. This pass analyses the program and emits a
//! `ReformatPlan`:
//!
//! * **dictionary encoding** for every string field used as a grouping /
//!   filter / join key ("the strings in the arrays have been replaced
//!   with integer keys ... the data model has been made relational");
//! * **dead-field elimination** for fields the program never reads
//!   ("removing unused structure fields");
//! * the plan is applied to the storage catalog (column-wise storage is
//!   the catalog's native representation — applying the plan *is* the
//!   relayout).
//!
//! Whether reformatting pays off is a cost decision (§III-C1: "Reformatting
//! all data for a small optimization is prohibitively expensive"): the
//! plan records an estimated byte delta, and `apply_if_profitable` skips
//! relayout unless the projected scan savings over `expected_runs`
//! outweigh the one-time encode cost.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::analysis::program_defuse;
use crate::ir::{DataType, Program};
use crate::storage::StorageCatalog;

/// Per-relation reformat directives.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RelationPlan {
    /// Field names to dictionary-encode.
    pub dict_encode: Vec<String>,
    /// Field names to keep (dead-field elimination) — None keeps all.
    pub keep: Option<Vec<String>>,
}

/// The whole reformat plan.
#[derive(Debug, Clone, Default)]
pub struct ReformatPlan {
    pub relations: BTreeMap<String, RelationPlan>,
}

/// Analyse a program and derive the reformat plan for its relations.
pub fn plan_reformat(p: &Program) -> ReformatPlan {
    let du = program_defuse(p);
    let mut plan = ReformatPlan::default();

    for (rel, schema) in &p.relations {
        let mut rp = RelationPlan::default();

        // Key fields: fields used for grouping (distinct), filtering or
        // value partitioning. Heuristic from the def-use field set: any
        // used string field that subscripts an accumulator or appears in
        // a filter. We approximate with: all used string fields (they
        // participate in key-like operations in this IR — pure payload
        // strings are rare and still benefit).
        for f in schema.fields() {
            let used = du.fields_use.contains(&(rel.clone(), f.name.clone()));
            if used && f.dtype == DataType::Str {
                rp.dict_encode.push(f.name.clone());
            }
        }

        // Dead fields: declared but never read.
        let live: Vec<String> = schema
            .fields()
            .iter()
            .filter(|f| du.fields_use.contains(&(rel.clone(), f.name.clone())))
            .map(|f| f.name.clone())
            .collect();
        if live.len() < schema.len() && !live.is_empty() {
            rp.keep = Some(live);
        }

        if rp != RelationPlan::default() {
            plan.relations.insert(rel.clone(), rp);
        }
    }
    plan
}

/// Apply a reformat plan to the storage catalog, rewriting the tables in
/// place (dictionary-encode keys, drop dead fields). Program schemas are
/// updated to match (field *names* are preserved, so the IR is unchanged
/// apart from relation schemas).
pub fn apply_reformat(
    plan: &ReformatPlan,
    p: &mut Program,
    catalog: &mut StorageCatalog,
) -> Result<()> {
    for (rel, rp) in &plan.relations {
        let mut table = (**catalog.get(rel)?).clone();

        if let Some(keep) = &rp.keep {
            let ids: Vec<usize> = keep
                .iter()
                .filter_map(|n| table.schema.field_id(n))
                .collect();
            table = table.project(&ids);
        }
        for fname in &rp.dict_encode {
            if let Some(fid) = table.schema.field_id(fname) {
                // Already-encoded (or non-string) fields are skipped.
                if matches!(table.column(fid), crate::storage::Column::Strs(_)) {
                    table.dict_encode_field(fid)?;
                }
            }
        }
        if let Some(schema) = p.relations.get_mut(rel) {
            *schema = table.schema.clone();
        }
        catalog.replace(rel, table);
    }
    Ok(())
}

/// The §III-C1 cost gate: apply only if the one-time reformat cost is
/// amortized by `expected_runs` of the program. Returns whether it was
/// applied.
pub fn apply_if_profitable(
    plan: &ReformatPlan,
    p: &mut Program,
    catalog: &mut StorageCatalog,
    expected_runs: u64,
) -> Result<bool> {
    // Cost model: encoding ~ 1 pass over affected string bytes;
    // savings ~ per-run reduction from hashing 8-byte keys instead of
    // strings (~60% of key-column scan cost) plus dropped dead columns.
    let mut encode_cost = 0f64;
    let mut per_run_saving = 0f64;
    for (rel, rp) in &plan.relations {
        let table = catalog.get(rel)?;
        for fname in &rp.dict_encode {
            if let Some(fid) = table.schema.field_id(fname) {
                let bytes = table.column(fid).heap_bytes() as f64;
                encode_cost += bytes;
                per_run_saving += bytes * 0.6;
            }
        }
        if let Some(keep) = &rp.keep {
            for f in table.schema.fields() {
                if !keep.contains(&f.name) {
                    if let Some(fid) = table.schema.field_id(&f.name) {
                        per_run_saving += table.column(fid).heap_bytes() as f64 * 0.1;
                    }
                }
            }
        }
    }
    if per_run_saving * expected_runs as f64 > encode_cost {
        apply_reformat(plan, p, catalog)?;
        Ok(true)
    } else {
        Ok(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec;
    use crate::ir::{Multiset, Schema, Value};
    use crate::sql::compile_sql;

    fn catalog() -> StorageCatalog {
        // access(url: str, agent: str, ms: int) — agent is never used.
        let schema = Schema::new(vec![
            ("url", DataType::Str),
            ("agent", DataType::Str),
            ("ms", DataType::Int),
        ]);
        let mut m = Multiset::new(schema);
        for i in 0..50 {
            m.push(vec![
                Value::str(format!("/p{}", i % 7)),
                Value::str("Mozilla/5.0 (compatible; something very long)"),
                Value::Int(i),
            ]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        c
    }

    #[test]
    fn plan_encodes_group_key_and_drops_dead_fields() {
        let c = catalog();
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        let plan = plan_reformat(&p);
        let rp = &plan.relations["access"];
        assert_eq!(rp.dict_encode, vec!["url".to_string()]);
        assert_eq!(rp.keep, Some(vec!["url".to_string()])); // agent+ms dead
    }

    #[test]
    fn reformat_preserves_query_results() {
        let mut c = catalog();
        let mut p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        let reference = exec::run(&p, &c).unwrap();
        let plan = plan_reformat(&p);
        apply_reformat(&plan, &mut p, &mut c).unwrap();
        crate::ir::validate(&p).unwrap();
        let out = exec::run(&p, &c).unwrap();
        assert!(out.result().unwrap().bag_eq(reference.result().unwrap()));
        // The table physically shrank (huge agent strings dropped).
        assert!(c.get("access").unwrap().schema.len() == 1);
    }

    #[test]
    fn reformatted_table_exposes_integer_keys() {
        let mut c = catalog();
        let mut p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        let plan = plan_reformat(&p);
        apply_reformat(&plan, &mut p, &mut c).unwrap();
        let t = c.get("access").unwrap();
        let fid = t.schema.field_id("url").unwrap();
        assert!(t.column(fid).as_int_keys().is_some());
        assert_eq!(t.column(fid).dictionary().unwrap().len(), 7);
    }

    #[test]
    fn profitability_gate() {
        // One run over a small table: not worth it. Many runs: worth it.
        let mut c1 = catalog();
        let mut p1 = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c1.schemas(),
        )
        .unwrap();
        let plan = plan_reformat(&p1);
        assert!(!apply_if_profitable(&plan, &mut p1, &mut c1, 1).unwrap());
        let mut c2 = catalog();
        let mut p2 = p1.clone();
        assert!(apply_if_profitable(&plan, &mut p2, &mut c2, 100).unwrap());
    }
}
