//! Dead-code elimination driven by Def-Use (§II): "detect and eliminate
//! data access of which the results are unused".
//!
//! Liveness roots: result-multiset appends and `print` statements. A
//! statement is dead if nothing it defines (arrays, scalars) is ever used
//! on a path to a root. Whole loops whose bodies become empty are removed
//! — which is how an unused query (data access code) disappears entirely.

use std::collections::BTreeSet;

use anyhow::Result;

use crate::analysis::stmt_defuse;
use crate::ir::{Program, Stmt};

use super::pass::{Pass, PassCtx};

pub struct DeadCode;

impl Pass for DeadCode {
    fn name(&self) -> &'static str {
        "dead-code"
    }

    fn run(&self, p: &mut Program, _ctx: &PassCtx) -> Result<bool> {
        let mut changed = false;
        // Iterate: removing a consumer can kill its producers.
        loop {
            let live = live_sets(p);
            let before = count_stmts(&p.body);
            let body = std::mem::take(&mut p.body);
            p.body = sweep(body, &live);
            let after = count_stmts(&p.body);
            if after == before {
                break;
            }
            changed = true;
        }
        if changed {
            // Drop declarations of arrays no longer referenced.
            let du = crate::analysis::program_defuse(p);
            p.arrays
                .retain(|name, _| du.arrays_def.contains(name) || du.arrays_use.contains(name));
        }
        Ok(changed)
    }
}

#[derive(Debug, Default)]
struct Live {
    arrays: BTreeSet<String>,
    scalars: BTreeSet<String>,
}

/// Compute the set of arrays/scalars that (transitively) feed a root.
fn live_sets(p: &Program) -> Live {
    let mut live = Live::default();
    // Seed: uses by result appends and prints anywhere in the program.
    let mut grow = true;
    while grow {
        grow = false;
        for s in &p.body {
            seed(s, &mut live, &mut grow);
        }
    }
    live
}

fn seed(s: &Stmt, live: &mut Live, grow: &mut bool) {
    let du = stmt_defuse(s, &[]);
    let is_root = !du.results_def.is_empty() || contains_print(s);
    let defines_live = du.arrays_def.iter().any(|a| live.arrays.contains(a))
        || du.scalars_def.iter().any(|v| live.scalars.contains(v));
    if is_root || defines_live {
        for a in &du.arrays_use {
            if live.arrays.insert(a.clone()) {
                *grow = true;
            }
        }
        for v in &du.scalars_use {
            if live.scalars.insert(v.clone()) {
                *grow = true;
            }
        }
    }
    // Recurse so nested roots (a print inside a loop) seed too.
    if let Stmt::Loop(l) = s {
        for b in &l.body {
            seed(b, live, grow);
        }
    }
    if let Stmt::If { then, els, .. } = s {
        for b in then.iter().chain(els) {
            seed(b, live, grow);
        }
    }
}

fn contains_print(s: &Stmt) -> bool {
    let mut found = false;
    s.walk(&mut |sub| {
        if matches!(sub, Stmt::Print { .. }) {
            found = true;
        }
    });
    found
}

fn sweep(body: Vec<Stmt>, live: &Live) -> Vec<Stmt> {
    let mut out = Vec::with_capacity(body.len());
    for s in body {
        match s {
            Stmt::Loop(mut l) => {
                l.body = sweep(l.body, live);
                if !l.body.is_empty() {
                    out.push(Stmt::Loop(l));
                }
            }
            Stmt::If { cond, then, els } => {
                let then = sweep(then, live);
                let els = sweep(els, live);
                if !then.is_empty() || !els.is_empty() {
                    out.push(Stmt::If { cond, then, els });
                }
            }
            Stmt::Accum { ref array, .. } => {
                if live.arrays.contains(array) {
                    out.push(s);
                }
            }
            Stmt::Assign { ref var, .. } => {
                if live.scalars.contains(var) {
                    out.push(s);
                }
            }
            // Roots stay.
            Stmt::ResultUnion { .. } | Stmt::Print { .. } => out.push(s),
        }
    }
    out
}

fn count_stmts(body: &[Stmt]) -> usize {
    let mut n = 0;
    for s in body {
        s.walk(&mut |_| n += 1);
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArrayDecl, DataType, Expr, IndexSet, Loop, Schema, Value};

    fn base() -> Program {
        Program::new("t")
            .with_relation("T", Schema::new(vec![("f", DataType::Int)]))
            .with_array("used", ArrayDecl::counter())
            .with_array("unused", ArrayDecl::counter())
            .with_result("R", Schema::new(vec![("n", DataType::Int)]))
    }

    #[test]
    fn removes_unused_counting_loop() {
        let mut p = base();
        p.body = vec![
            // Dead: accumulates into `unused`, never read.
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::all("T"),
                vec![Stmt::increment("unused", vec![Expr::field("i", "f")])],
            )),
            // Live chain: used → R.
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::all("T"),
                vec![Stmt::increment("used", vec![Expr::field("i", "f")])],
            )),
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::distinct_of("T", "f"),
                vec![Stmt::result_union(
                    "R",
                    vec![Expr::array("used", vec![Expr::field("i", "f")])],
                )],
            )),
        ];
        assert!(DeadCode.run(&mut p, &PassCtx::new()).unwrap());
        assert_eq!(p.body.len(), 2);
        assert!(!p.arrays.contains_key("unused"));
        assert!(p.arrays.contains_key("used"));
    }

    #[test]
    fn transitive_death() {
        // a feeds b, b feeds nothing → both die.
        let mut p = base().with_array("a", ArrayDecl::counter()).with_array("b", ArrayDecl::counter());
        p.body = vec![
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::all("T"),
                vec![Stmt::increment("a", vec![Expr::field("i", "f")])],
            )),
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::all("T"),
                vec![Stmt::accum(
                    "b",
                    vec![Expr::field("i", "f")],
                    crate::ir::AccumOp::Add,
                    Expr::array("a", vec![Expr::field("i", "f")]),
                )],
            )),
        ];
        assert!(DeadCode.run(&mut p, &PassCtx::new()).unwrap());
        assert!(p.body.is_empty(), "{:?}", p.body);
        assert!(p.arrays.is_empty());
    }

    #[test]
    fn print_keeps_scalar_chain_alive() {
        let mut p = base().with_scalar("avg", Value::Float(0.0));
        p.body = vec![
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::all("T"),
                vec![Stmt::assign(
                    "avg",
                    Expr::add(Expr::var("avg"), Expr::field("i", "f")),
                )],
            )),
            Stmt::Print {
                format: "{}".into(),
                args: vec![Expr::var("avg")],
            },
        ];
        assert!(!DeadCode.run(&mut p, &PassCtx::new()).unwrap());
        assert_eq!(p.body.len(), 2);
    }

    #[test]
    fn result_loops_always_survive() {
        let mut p = base();
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("T"),
            vec![Stmt::result_union("R", vec![Expr::field("i", "f")])],
        ))];
        assert!(!DeadCode.run(&mut p, &PassCtx::new()).unwrap());
        assert_eq!(p.body.len(), 1);
    }
}
