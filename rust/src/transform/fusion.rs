//! Statement reordering + Loop Fusion (§III-A4).
//!
//! The paper's data-distribution example: two group-by computations over
//! the same table are each split into a counting loop and a reduce loop;
//! reordering brings the two counting loops together (legal because they
//! are independent), and Loop Fusion merges them so both use the *same*
//! partitioning of X — eliminating the data redistribution between them.

use anyhow::Result;

use crate::analysis::{can_fuse, can_reorder};
use crate::ir::{Program, Stmt};

use super::pass::{Pass, PassCtx};

/// Fuse adjacent compatible top-level loops, using reordering to *create*
/// adjacency when legal.
pub struct LoopFusion;

impl Pass for LoopFusion {
    fn name(&self) -> &'static str {
        "loop-fusion"
    }

    fn run(&self, p: &mut Program, _ctx: &PassCtx) -> Result<bool> {
        let mut changed = false;
        // Keep trying until no fusion opportunity remains.
        loop {
            let Some((i, j)) = find_fusable_pair(&p.body) else {
                break;
            };
            // Move statement j directly after i by repeated adjacent swaps
            // (each swap individually legality-checked — conservative but
            // simple and obviously sound).
            let mut pos = j;
            while pos > i + 1 {
                p.body.swap(pos - 1, pos);
                pos -= 1;
            }
            // Fuse body of p.body[i+1] into p.body[i].
            let Stmt::Loop(src) = p.body.remove(i + 1) else {
                unreachable!()
            };
            let Stmt::Loop(dst) = &mut p.body[i] else {
                unreachable!()
            };
            let mut incoming = src.body;
            if src.var != dst.var {
                for s in &mut incoming {
                    s.rename_var(&src.var, &dst.var);
                }
            }
            dst.body.extend(incoming);
            changed = true;
        }
        Ok(changed)
    }
}

/// Find (i, j), i < j, such that loops i and j can fuse AND j can be
/// legally moved adjacent to i (it must commute with everything between).
fn find_fusable_pair(body: &[Stmt]) -> Option<(usize, usize)> {
    for i in 0..body.len() {
        let Stmt::Loop(a) = &body[i] else { continue };
        'next_j: for j in i + 1..body.len() {
            let Stmt::Loop(b) = &body[j] else { continue };
            if !can_fuse(a, b) {
                continue;
            }
            // Ordered/bounded emissions apply per loop; merging two
            // bodies under one annotation would change which rows the
            // bound keeps.
            if a.emit.is_some() || b.emit.is_some() {
                continue;
            }
            // j must commute with every statement strictly between i and j.
            for between in &body[i + 1..j] {
                if !can_reorder(between, &body[j]) {
                    continue 'next_j;
                }
            }
            return Some((i, j));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec;
    use crate::ir::{
        pretty, ArrayDecl, DataType, Expr, IndexSet, Loop, Multiset, Schema, Value,
    };
    use crate::storage::StorageCatalog;

    /// Build the §III-A4 program: two count loops + two reduce loops, in
    /// produce/reduce/produce/reduce order.
    fn two_groupbys() -> (Program, StorageCatalog) {
        let schema = Schema::new(vec![
            ("field1", DataType::Int),
            ("field2", DataType::Int),
        ]);
        let mut m = Multiset::new(schema.clone());
        for (a, b) in [(1, 10), (2, 10), (1, 20), (3, 20), (1, 10)] {
            m.push(vec![Value::Int(a), Value::Int(b)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("Table", &m).unwrap();

        let count = |arr: &str, f: &str| {
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::all("Table"),
                vec![Stmt::increment(arr, vec![Expr::field("i", f)])],
            ))
        };
        let reduce = |arr: &str, f: &str, res: &str| {
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::distinct_of("Table", f),
                vec![Stmt::result_union(
                    res,
                    vec![
                        Expr::field("i", f),
                        Expr::array(arr, vec![Expr::field("i", f)]),
                    ],
                )],
            ))
        };
        let p = Program::new("two_groupbys")
            .with_relation("Table", schema)
            .with_array("count1", ArrayDecl::counter())
            .with_array("count2", ArrayDecl::counter())
            .with_result(
                "R1",
                Schema::new(vec![("v", DataType::Int), ("n", DataType::Int)]),
            )
            .with_result(
                "R2",
                Schema::new(vec![("v", DataType::Int), ("n", DataType::Int)]),
            )
            .with_body(vec![
                count("count1", "field1"),
                reduce("count1", "field1", "R1"),
                count("count2", "field2"),
                reduce("count2", "field2", "R2"),
            ]);
        (p, c)
    }

    #[test]
    fn fuses_the_papers_counting_loops() {
        let (mut p, _c) = two_groupbys();
        assert!(LoopFusion.run(&mut p, &PassCtx::new()).unwrap());
        // The two counting loops fused: 3 top-level statements remain.
        assert_eq!(p.body.len(), 3);
        let Stmt::Loop(first) = &p.body[0] else { panic!() };
        assert_eq!(first.body.len(), 2, "{}", pretty::program(&p));
        // Both count1 and count2 updated in the same loop body.
        let text = pretty::stmt_string(&p.body[0]);
        assert!(text.contains("count1[i.field1]++;"), "{text}");
        assert!(text.contains("count2[i.field2]++;"), "{text}");
    }

    #[test]
    fn fusion_preserves_semantics() {
        let (base, c) = two_groupbys();
        let reference = exec::run(&base, &c).unwrap();
        let mut fused = base.clone();
        LoopFusion.run(&mut fused, &PassCtx::new()).unwrap();
        let out = exec::run(&fused, &c).unwrap();
        for r in ["R1", "R2"] {
            assert!(out.results[r].bag_eq(&reference.results[r]), "{r}");
        }
    }

    #[test]
    fn does_not_fuse_across_dependences() {
        // produce → consume: reduce1 reads count1, so count2's loop may
        // jump over it (independent) but reduce loops cannot fuse with
        // count loops.
        let (mut p, _c) = two_groupbys();
        LoopFusion.run(&mut p, &PassCtx::new()).unwrap();
        // Re-running finds nothing further.
        assert!(!LoopFusion.run(&mut p, &PassCtx::new()).unwrap());
    }

    #[test]
    fn renames_loop_variables_on_fuse() {
        let (mut p, c) = two_groupbys();
        // Rename the second count loop's var to j beforehand.
        if let Stmt::Loop(l) = &mut p.body[2] {
            l.var = "j".into();
            for s in &mut l.body {
                s.rename_var("i", "j");
            }
        }
        let reference = exec::run(&p, &c).unwrap();
        assert!(LoopFusion.run(&mut p, &PassCtx::new()).unwrap());
        crate::ir::validate(&p).unwrap();
        let out = exec::run(&p, &c).unwrap();
        assert!(out.results["R2"].bag_eq(&reference.results["R2"]));
    }
}
