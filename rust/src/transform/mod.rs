//! Re-targeted compiler transformations over the single IR (§III).
//!
//! * parallelization: [`blocking`] (direct partitioning),
//!   [`orthogonalization`] (indirect/value-range partitioning);
//! * locality & distribution: [`fusion`] (statement reordering + Loop
//!   Fusion, §III-A4), [`interchange`] (filter hoisting, §III-B);
//! * classic optimizations: [`const_prop`], [`dead_code`], [`code_motion`]
//!   (LICM + CSE);
//! * late decisions: [`materialization`] (index-set strategies, Figure 1),
//!   [`reformat`] (dictionary encoding / dead-field elimination /
//!   relayout, §III-C1).

pub mod blocking;
pub mod code_motion;
pub mod const_prop;
pub mod dead_code;
pub mod fusion;
pub mod interchange;
pub mod materialization;
pub mod orthogonalization;
pub mod pass;
pub mod reformat;

pub use blocking::{parallelize_direct, DirectPartition};
pub use code_motion::{CodeMotion, Cse};
pub use const_prop::ConstProp;
pub use dead_code::DeadCode;
pub use fusion::LoopFusion;
pub use interchange::LoopInterchange;
pub use materialization::Materialize;
pub use orthogonalization::{parallelize_indirect, IndirectPartition};
pub use pass::{run_pipeline, run_to_fixpoint, Pass, PassCtx, Trace};
pub use reformat::{apply_if_profitable, apply_reformat, plan_reformat, ReformatPlan};

/// The standard optimization pipeline the compiler driver runs before
/// code generation: classic cleanups → fusion/interchange → strategy
/// decisions. Parallelization (blocking/orthogonalization) is applied
/// separately by the driver because the partitioning choice couples to
/// the distribution optimizer (distrib::distribution).
pub fn standard_pipeline() -> Vec<Box<dyn Pass>> {
    vec![
        Box::new(ConstProp),
        Box::new(DeadCode),
        Box::new(CodeMotion),
        Box::new(Cse),
        Box::new(LoopInterchange),
        Box::new(LoopFusion),
        Box::new(Materialize),
    ]
}
