//! The pass framework: a uniform interface for the re-targeted compiler
//! transformations, plus the pipeline that sequences them (§II–III).

use anyhow::Result;

use crate::ir::Program;
use crate::storage::StorageCatalog;

/// Context a pass may consult: table statistics drive materialization and
/// reformat decisions (passes must not *mutate* storage — reformat emits a
/// plan that the driver applies).
#[derive(Default)]
pub struct PassCtx<'a> {
    pub catalog: Option<&'a StorageCatalog>,
    /// Target processor count for parallelization passes.
    pub processors: usize,
}

impl<'a> PassCtx<'a> {
    pub fn new() -> Self {
        PassCtx {
            catalog: None,
            processors: 1,
        }
    }

    pub fn with_catalog(mut self, c: &'a StorageCatalog) -> Self {
        self.catalog = Some(c);
        self
    }

    pub fn with_processors(mut self, n: usize) -> Self {
        self.processors = n;
        self
    }
}

/// One rewriting pass over a program.
pub trait Pass {
    /// Name used in pipeline traces.
    fn name(&self) -> &'static str;
    /// Rewrite the program in place; return true if anything changed.
    fn run(&self, p: &mut Program, ctx: &PassCtx) -> Result<bool>;
}

/// A record of what the pipeline did (CLI `--emit trace`).
#[derive(Debug, Default, Clone)]
pub struct Trace {
    pub steps: Vec<(String, bool)>,
}

impl Trace {
    pub fn changed_passes(&self) -> Vec<&str> {
        self.steps
            .iter()
            .filter(|(_, c)| *c)
            .map(|(n, _)| n.as_str())
            .collect()
    }
}

/// Run a sequence of passes, validating after each one.
pub fn run_pipeline(
    p: &mut Program,
    passes: &[&dyn Pass],
    ctx: &PassCtx,
) -> Result<Trace> {
    let mut trace = Trace::default();
    for pass in passes {
        let changed = pass.run(p, ctx)?;
        crate::ir::validate(p).map_err(|e| {
            anyhow::anyhow!("pass `{}` produced an invalid program: {e}", pass.name())
        })?;
        trace.steps.push((pass.name().to_string(), changed));
    }
    Ok(trace)
}

/// Iterate a pipeline until fixpoint (bounded).
pub fn run_to_fixpoint(
    p: &mut Program,
    passes: &[&dyn Pass],
    ctx: &PassCtx,
    max_rounds: usize,
) -> Result<Trace> {
    let mut trace = Trace::default();
    for _ in 0..max_rounds {
        let round = run_pipeline(p, passes, ctx)?;
        let any = round.steps.iter().any(|(_, c)| *c);
        trace.steps.extend(round.steps);
        if !any {
            break;
        }
    }
    Ok(trace)
}
