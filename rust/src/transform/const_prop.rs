//! Constant propagation + folding (§III-C2's "classic code optimizations").
//!
//! Folds constant subexpressions (`1 + 2` → `3`, `"a" == "a"` → `true`)
//! and simplifies trivially-decidable `If` statements, shrinking the code
//! the later passes and the code generator must consider.

use anyhow::Result;

use crate::exec::eval::value_binop;
use crate::ir::{Expr, Program, Stmt, UnOp, Value};

use super::pass::{Pass, PassCtx};

pub struct ConstProp;

impl Pass for ConstProp {
    fn name(&self) -> &'static str {
        "const-prop"
    }

    fn run(&self, p: &mut Program, _ctx: &PassCtx) -> Result<bool> {
        let mut changed = false;
        for s in &mut p.body {
            changed |= fold_stmt(s);
        }
        Ok(changed)
    }
}

fn fold_stmt(s: &mut Stmt) -> bool {
    let mut changed = false;
    s.walk_exprs_mut(&mut |e| {
        if let Some(folded) = fold_expr(e) {
            *e = folded;
            changed = true;
        }
    });
    // Simplify decidable Ifs (then/else selection).
    changed |= simplify_ifs(s);
    changed
}

fn simplify_ifs(s: &mut Stmt) -> bool {
    match s {
        Stmt::Loop(l) => simplify_body(&mut l.body),
        Stmt::If { then, els, .. } => {
            let mut c = simplify_body(then);
            c |= simplify_body(els);
            c
        }
        _ => false,
    }
}

fn simplify_body(body: &mut Vec<Stmt>) -> bool {
    let mut changed = false;
    let mut i = 0;
    while i < body.len() {
        let replace = match &body[i] {
            Stmt::If {
                cond: Expr::Const(v),
                then,
                els,
            } => Some(if v.truthy() { then.clone() } else { els.clone() }),
            _ => None,
        };
        if let Some(stmts) = replace {
            body.splice(i..=i, stmts);
            changed = true;
            continue; // re-examine at the same index
        }
        changed |= simplify_ifs(&mut body[i]);
        i += 1;
    }
    changed
}

fn fold_expr(e: &Expr) -> Option<Expr> {
    match e {
        Expr::Binary { op, lhs, rhs } => {
            if let (Expr::Const(l), Expr::Const(r)) = (lhs.as_ref(), rhs.as_ref()) {
                value_binop(*op, l, r).ok().map(Expr::Const)
            } else {
                None
            }
        }
        Expr::Unary { op, expr } => {
            if let Expr::Const(v) = expr.as_ref() {
                match (op, v) {
                    (UnOp::Neg, Value::Int(i)) => Some(Expr::Const(Value::Int(-i))),
                    (UnOp::Neg, Value::Float(f)) => Some(Expr::Const(Value::Float(-f))),
                    (UnOp::Not, v) => Some(Expr::Const(Value::Bool(!v.truthy()))),
                    _ => None,
                }
            } else {
                None
            }
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{BinOp, IndexSet, Loop, Schema};

    #[test]
    fn folds_arithmetic() {
        let mut p = Program::new("t").with_scalar("x", Value::Int(0));
        p.body = vec![Stmt::assign(
            "x",
            Expr::bin(BinOp::Mul, Expr::int(6), Expr::add(Expr::int(3), Expr::int(4))),
        )];
        assert!(ConstProp.run(&mut p, &PassCtx::new()).unwrap());
        assert_eq!(
            p.body[0],
            Stmt::assign("x", Expr::Const(Value::Int(42)))
        );
    }

    #[test]
    fn removes_decidable_if_inside_loop() {
        let mut p = Program::new("t")
            .with_relation("T", Schema::new(vec![("f", crate::ir::DataType::Int)]))
            .with_array("c", crate::ir::ArrayDecl::counter());
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("T"),
            vec![Stmt::If {
                cond: Expr::bin(BinOp::Lt, Expr::int(1), Expr::int(2)),
                then: vec![Stmt::increment("c", vec![Expr::field("i", "f")])],
                els: vec![],
            }],
        ))];
        assert!(ConstProp.run(&mut p, &PassCtx::new()).unwrap());
        if let Stmt::Loop(l) = &p.body[0] {
            assert!(matches!(l.body[0], Stmt::Accum { .. }), "{:?}", l.body);
        } else {
            panic!();
        }
    }

    #[test]
    fn false_branch_selected() {
        let mut p = Program::new("t")
            .with_relation("T", Schema::new(vec![("f", crate::ir::DataType::Int)]))
            .with_array("c", crate::ir::ArrayDecl::counter());
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("T"),
            vec![Stmt::If {
                cond: Expr::Const(Value::Bool(false)),
                then: vec![Stmt::increment("c", vec![Expr::field("i", "f")])],
                els: vec![],
            }],
        ))];
        assert!(ConstProp.run(&mut p, &PassCtx::new()).unwrap());
        if let Stmt::Loop(l) = &p.body[0] {
            assert!(l.body.is_empty());
        } else {
            panic!();
        }
    }

    #[test]
    fn no_change_reports_false() {
        let mut p = Program::new("t").with_scalar("x", Value::Int(0));
        p.body = vec![Stmt::assign("x", Expr::var("x"))];
        assert!(!ConstProp.run(&mut p, &PassCtx::new()).unwrap());
    }
}
