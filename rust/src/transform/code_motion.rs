//! Loop-invariant code motion + common-subexpression elimination
//! (§III-C2's "classic code optimizations", and one of the two enabling
//! transformations — with Iteration Space Expansion — the paper applies
//! before parallelizing §IV's group-by).
//!
//! * `CodeMotion` hoists `Assign` statements whose right-hand side does
//!   not depend on the loop variable (or anything bound inside the loop)
//!   out of the loop.
//! * `Cse` introduces a temporary for a repeated pure subexpression
//!   within one loop body (conservative: only bodies without nested
//!   loops, only expressions without array reads).

use std::collections::HashSet;

use anyhow::Result;

use crate::ir::{Expr, Program, Stmt, Value};

use super::pass::{Pass, PassCtx};

pub struct CodeMotion;

impl Pass for CodeMotion {
    fn name(&self) -> &'static str {
        "code-motion"
    }

    fn run(&self, p: &mut Program, _ctx: &PassCtx) -> Result<bool> {
        let mut changed = false;
        let mut i = 0;
        while i < p.body.len() {
            if let Stmt::Loop(l) = &mut p.body[i] {
                let mut bound = HashSet::new();
                bound.insert(l.var.clone());
                let hoisted = hoist_invariants(&mut l.body, &mut bound);
                if !hoisted.is_empty() {
                    changed = true;
                    // Hoisted scalars must be declared program-level.
                    for s in &hoisted {
                        if let Stmt::Assign { var, .. } = s {
                            p.scalars.entry(var.clone()).or_insert(Value::Int(0));
                        }
                    }
                    for (off, s) in hoisted.into_iter().enumerate() {
                        p.body.insert(i + off, s);
                        i += 1;
                    }
                }
            }
            i += 1;
        }
        Ok(changed)
    }
}

/// Remove and return loop-invariant Assigns (in order). `bound` is the set
/// of variables bound by enclosing loops.
fn hoist_invariants(body: &mut Vec<Stmt>, bound: &mut HashSet<String>) -> Vec<Stmt> {
    let mut hoisted = Vec::new();
    let mut assigned_in_loop: HashSet<String> = HashSet::new();
    for s in body.iter() {
        s.walk(&mut |sub| {
            if let Stmt::Assign { var, .. } = sub {
                assigned_in_loop.insert(var.clone());
            }
            if let Stmt::Loop(l) = sub {
                bound.insert(l.var.clone());
            }
        });
    }
    body.retain(|s| {
        if let Stmt::Assign { var, value } = s {
            // Hoistable iff the RHS depends on nothing bound by the loop:
            // no loop variables, no variables assigned inside the loop
            // (which covers self-accumulation `var = var + e`), and no
            // accumulator arrays (those change across iterations).
            let deps = value.used_vars();
            let invariant = deps
                .iter()
                .all(|d| !bound.contains(d) && !assigned_in_loop.contains(d))
                && value.used_arrays().is_empty()
                && !deps.contains(var);
            if invariant {
                hoisted.push(s.clone());
                return false;
            }
        }
        true
    });
    hoisted
}

pub struct Cse;

impl Pass for Cse {
    fn name(&self) -> &'static str {
        "cse"
    }

    fn run(&self, p: &mut Program, _ctx: &PassCtx) -> Result<bool> {
        let mut changed = false;
        let mut fresh = 0usize;
        for s in &mut p.body {
            changed |= cse_stmt(s, &mut fresh, ());
        }
        // Declare the temporaries (collect names used).
        let mut tmps = Vec::new();
        for s in &p.body {
            s.walk(&mut |sub| {
                if let Stmt::Assign { var, .. } = sub {
                    if var.starts_with("_cse") {
                        tmps.push(var.clone());
                    }
                }
            });
        }
        for t in tmps {
            p.scalars.entry(t).or_insert(Value::Int(0));
        }
        Ok(changed)
    }
}

fn cse_stmt(s: &mut Stmt, fresh: &mut usize, _sc: ()) -> bool {
    let Stmt::Loop(l) = s else { return false };
    // Recurse into nested loops first.
    let mut changed = false;
    for b in &mut l.body {
        changed |= cse_stmt(b, fresh, ());
    }
    // Only flat bodies (no nested loops) are candidates at this level.
    if l.body.iter().any(|b| matches!(b, Stmt::Loop(_))) {
        return changed;
    }
    // Count pure, non-trivial subexpressions.
    let mut counts: Vec<(Expr, usize)> = Vec::new();
    for b in &l.body {
        b.walk_exprs(&mut |e| {
            if is_cse_candidate(e) {
                if let Some(slot) = counts.iter_mut().find(|(c, _)| c == e) {
                    slot.1 += 1;
                } else {
                    counts.push((e.clone(), 1));
                }
            }
        });
    }
    let Some((expr, _)) = counts.iter().find(|(_, n)| *n >= 2) else {
        return changed;
    };
    let expr = expr.clone();
    let tmp = format!("_cse{}", *fresh);
    *fresh += 1;
    for b in &mut l.body {
        b.walk_exprs_mut(&mut |e| {
            if *e == expr {
                *e = Expr::var(&tmp);
            }
        });
    }
    l.body.insert(0, Stmt::assign(&tmp, expr));
    true
}

/// Pure non-trivial expressions: binaries over fields/vars/consts, no
/// array reads (arrays may be written inside the body).
fn is_cse_candidate(e: &Expr) -> bool {
    match e {
        Expr::Binary { .. } => {
            let mut pure = true;
            e.walk(&mut |sub| {
                if matches!(sub, Expr::ArrayRef { .. } | Expr::SumOverParts { .. }) {
                    pure = false;
                }
            });
            pure
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec;
    use crate::ir::{DataType, IndexSet, Loop, Multiset, Schema};
    use crate::storage::StorageCatalog;

    fn setup() -> StorageCatalog {
        let schema = Schema::new(vec![("g", DataType::Float), ("w", DataType::Float)]);
        let mut m = Multiset::new(schema);
        for (g, w) in [(8.0, 0.5), (6.0, 0.25)] {
            m.push(vec![Value::Float(g), Value::Float(w)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("T", &m).unwrap();
        c
    }

    #[test]
    fn hoists_invariant_assign() {
        let c = setup();
        let mut p = Program::new("t")
            .with_relation("T", c.schemas()["T"].clone())
            .with_scalar("base", Value::Float(0.0))
            .with_scalar("acc", Value::Float(0.0));
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("T"),
            vec![
                Stmt::assign("base", Expr::mul(Expr::float(2.0), Expr::float(3.0))),
                Stmt::assign(
                    "acc",
                    Expr::add(Expr::var("acc"), Expr::mul(Expr::var("base"), Expr::field("i", "g"))),
                ),
            ],
        ))];
        let reference = exec::run(&p, &c).unwrap();
        assert!(CodeMotion.run(&mut p, &PassCtx::new()).unwrap());
        // The invariant assign is now top-level, before the loop.
        assert!(matches!(&p.body[0], Stmt::Assign { var, .. } if var == "base"));
        let out = exec::run(&p, &c).unwrap();
        assert_eq!(out.scalars["acc"], reference.scalars["acc"]);
    }

    #[test]
    fn does_not_hoist_self_accumulation() {
        let c = setup();
        let mut p = Program::new("t")
            .with_relation("T", c.schemas()["T"].clone())
            .with_scalar("acc", Value::Float(0.0));
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("T"),
            vec![Stmt::assign(
                "acc",
                Expr::add(Expr::var("acc"), Expr::float(1.0)),
            )],
        ))];
        assert!(!CodeMotion.run(&mut p, &PassCtx::new()).unwrap());
    }

    #[test]
    fn cse_introduces_single_temp() {
        let c = setup();
        let gw = || Expr::mul(Expr::field("i", "g"), Expr::field("i", "w"));
        let mut p = Program::new("t")
            .with_relation("T", c.schemas()["T"].clone())
            .with_scalar("a", Value::Float(0.0))
            .with_scalar("b", Value::Float(0.0));
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("T"),
            vec![
                Stmt::assign("a", Expr::add(Expr::var("a"), gw())),
                Stmt::assign("b", Expr::add(Expr::var("b"), gw())),
            ],
        ))];
        let reference = exec::run(&p, &c).unwrap();
        assert!(Cse.run(&mut p, &PassCtx::new()).unwrap());
        crate::ir::validate(&p).unwrap();
        let out = exec::run(&p, &c).unwrap();
        assert_eq!(out.scalars["a"], reference.scalars["a"]);
        assert_eq!(out.scalars["b"], reference.scalars["b"]);
        // The product appears exactly once now (in the temp assign).
        let text = crate::ir::pretty::program(&p);
        assert_eq!(text.matches("(i.g * i.w)").count(), 1, "{text}");
    }

    #[test]
    fn cse_skips_array_reads() {
        let mut p = Program::new("t")
            .with_relation("T", Schema::new(vec![("g", DataType::Int)]))
            .with_array("c", crate::ir::ArrayDecl::counter())
            .with_result("R", Schema::new(vec![("x", DataType::Int)]));
        let read = || {
            Expr::add(
                Expr::array("c", vec![Expr::field("i", "g")]),
                Expr::int(1),
            )
        };
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("T"),
            vec![
                Stmt::increment("c", vec![Expr::field("i", "g")]),
                Stmt::result_union("R", vec![read()]),
            ],
        ))];
        assert!(!Cse.run(&mut p, &PassCtx::new()).unwrap());
    }
}
