//! Lowering a MapReduce program INTO the single intermediate (§IV's other
//! direction): the generic-intermediate claim is that MapReduce programs,
//! like SQL, are just another front-end.

use anyhow::Result;

use crate::ir::{
    ArrayDecl, DataType, Expr, IndexSet, Loop, Program, Schema, Stmt,
};

use super::ast::{MapFn, MapReduceProgram, ReduceFn};

/// Lower a MapReduce program over `table` (with `schema`) into the
/// two-loop forelem IR.
pub fn lower(mr: &MapReduceProgram, table: &str, schema: &Schema) -> Result<Program> {
    let key_field = schema.field(mr.map.key_field()).name.clone();

    let accum_stmt = match (mr.map, mr.reduce) {
        (MapFn::EmitKeyOne { .. }, ReduceFn::CountValues) => {
            Stmt::increment("agg", vec![Expr::field("i", &key_field)])
        }
        (MapFn::EmitKeyValue { val_field, .. }, ReduceFn::SumValues) => {
            let val = schema.field(val_field).name.clone();
            Stmt::accum(
                "agg",
                vec![Expr::field("i", &key_field)],
                crate::ir::AccumOp::Add,
                Expr::field("i", &val),
            )
        }
        (MapFn::EmitKeyOne { .. }, ReduceFn::SumValues) => {
            // Summing dummy 1s is counting.
            Stmt::increment("agg", vec![Expr::field("i", &key_field)])
        }
        (MapFn::EmitKeyValue { .. }, ReduceFn::CountValues) => {
            // Counting ignores the emitted value.
            Stmt::increment("agg", vec![Expr::field("i", &key_field)])
        }
    };

    let out_dtype = match mr.reduce {
        ReduceFn::CountValues => DataType::Int,
        ReduceFn::SumValues => match mr.map {
            MapFn::EmitKeyValue { val_field, .. } => schema.dtype(val_field),
            MapFn::EmitKeyOne { .. } => DataType::Int,
        },
    };
    let decl = match out_dtype {
        DataType::Float => ArrayDecl::accumulator(DataType::Float),
        _ => ArrayDecl::counter(),
    };

    let mut p = Program::new(&format!("mapreduce_{table}"))
        .with_relation(table, schema.clone())
        .with_array("agg", decl)
        .with_result(
            "R",
            Schema::new(vec![
                (&key_field, schema.dtype(mr.map.key_field())),
                ("value", out_dtype),
            ]),
        );
    p.body = vec![
        Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all(table),
            vec![accum_stmt],
        )),
        Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::distinct_of(table, &key_field),
            vec![Stmt::result_union(
                "R",
                vec![
                    Expr::field("i", &key_field),
                    Expr::array("agg", vec![Expr::field("i", &key_field)]),
                ],
            )],
        )),
    ];
    crate::ir::validate(&p)?;
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec;
    use crate::ir::{Multiset, Value};
    use crate::storage::StorageCatalog;

    #[test]
    fn mapreduce_roundtrips_through_the_intermediate() {
        // SQL → IR → MR → IR: the derived and re-lowered program computes
        // the same result as the original.
        let schema = Schema::new(vec![("url", DataType::Str)]);
        let mut m = Multiset::new(schema.clone());
        for u in ["/a", "/b", "/a"] {
            m.push(vec![Value::str(u)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();

        let p1 = crate::sql::compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        let (mr, info) = crate::mapreduce::derive::derive(&p1).unwrap();
        let p2 = lower(&mr, &info.table, &schema).unwrap();

        let r1 = exec::run(&p1, &c).unwrap();
        let r2 = exec::run(&p2, &c).unwrap();
        // Schemas differ in field names; compare pairs.
        let pairs = |m: &Multiset| {
            let mut v: Vec<(String, i64)> = m
                .rows()
                .iter()
                .map(|r| (r[0].to_string(), r[1].as_int().unwrap()))
                .collect();
            v.sort();
            v
        };
        assert_eq!(pairs(r1.result().unwrap()), pairs(r2.result().unwrap()));
    }

    #[test]
    fn sum_program_lowers_with_float_output() {
        let schema = Schema::new(vec![("k", DataType::Str), ("v", DataType::Float)]);
        let mr = MapReduceProgram {
            map: MapFn::EmitKeyValue {
                key_field: 0,
                val_field: 1,
            },
            reduce: ReduceFn::SumValues,
        };
        let p = lower(&mr, "t", &schema).unwrap();
        assert_eq!(p.results["R"].dtype(1), DataType::Float);
    }
}
