//! Deriving a MapReduce program from the single intermediate (§IV).
//!
//! "In general, two adjacent forelem loops where the former loop stores
//! values in an array subscripted by a field of the array being iterated,
//! and the latter loop accesses elements of this array, can be written as
//! a MapReduce program."
//!
//! Recognition is shared with the compiled-plan machinery
//! (exec::plan::recognize) — the same idiom that compiles to a native/XLA
//! kernel also exports to MapReduce, which is precisely the paper's
//! genericity claim.

use anyhow::{bail, Context, Result};

use crate::exec::plan::{recognize, Idiom};
use crate::ir::Program;

use super::ast::{MapFn, MapReduceProgram, ReduceFn};

/// Derive the MapReduce form of a forelem program (the §IV translation).
pub fn derive(p: &Program) -> Result<(MapReduceProgram, DeriveInfo)> {
    let idiom = recognize(p).context(
        "program is not two adjacent accumulate/emit forelem loops — \
         no MapReduce form exists (§IV's derivation precondition)",
    )?;
    match idiom {
        Idiom::GroupCount {
            table, key_field, ..
        } => {
            let schema = p
                .relations
                .get(&table)
                .with_context(|| format!("unknown relation `{table}`"))?;
            let kf = schema
                .field_id(&key_field)
                .with_context(|| format!("no field `{key_field}`"))?;
            Ok((
                MapReduceProgram {
                    map: MapFn::EmitKeyOne { key_field: kf },
                    reduce: ReduceFn::CountValues,
                },
                DeriveInfo { table, key_field },
            ))
        }
        Idiom::GroupSum {
            table,
            key_field,
            val_field,
            ..
        } => {
            let schema = p
                .relations
                .get(&table)
                .with_context(|| format!("unknown relation `{table}`"))?;
            let kf = schema
                .field_id(&key_field)
                .with_context(|| format!("no field `{key_field}`"))?;
            let vf = schema
                .field_id(&val_field)
                .with_context(|| format!("no field `{val_field}`"))?;
            if kf == vf {
                bail!("key and value fields coincide");
            }
            Ok((
                MapReduceProgram {
                    map: MapFn::EmitKeyValue {
                        key_field: kf,
                        val_field: vf,
                    },
                    reduce: ReduceFn::SumValues,
                },
                DeriveInfo { table, key_field },
            ))
        }
    }
}

/// Context for running the derived program (which table feeds the map).
#[derive(Debug, Clone)]
pub struct DeriveInfo {
    pub table: String,
    pub key_field: String,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Schema};
    use crate::sql::compile_sql;

    fn catalog() -> std::collections::BTreeMap<String, Schema> {
        let mut c = std::collections::BTreeMap::new();
        c.insert("access".into(), Schema::new(vec![("url", DataType::Str)]));
        c.insert(
            "t".into(),
            Schema::new(vec![("k", DataType::Str), ("v", DataType::Float)]),
        );
        c
    }

    #[test]
    fn url_count_derives_to_the_papers_mapreduce() {
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &catalog(),
        )
        .unwrap();
        let (mr, info) = derive(&p).unwrap();
        assert_eq!(mr.map, MapFn::EmitKeyOne { key_field: 0 });
        assert_eq!(mr.reduce, ReduceFn::CountValues);
        assert_eq!(info.table, "access");
    }

    #[test]
    fn sum_derives_to_key_value_emit() {
        let p = compile_sql("SELECT k, SUM(v) FROM t GROUP BY k", &catalog()).unwrap();
        let (mr, _) = derive(&p).unwrap();
        assert_eq!(
            mr.map,
            MapFn::EmitKeyValue {
                key_field: 0,
                val_field: 1
            }
        );
        assert_eq!(mr.reduce, ReduceFn::SumValues);
    }

    #[test]
    fn non_idiomatic_programs_refuse() {
        let p = compile_sql("SELECT url FROM access", &catalog()).unwrap();
        assert!(derive(&p).is_err());
    }
}
