//! Hadoop-like MapReduce executor: the Figure-2 baseline.
//!
//! Substituted for a real Hadoop cluster per DESIGN.md §Substitutions.
//! The mechanics that dominate Hadoop's cost profile are REAL here, not
//! modelled by a fudge factor:
//!
//! * map output is **string-serialized** (`key\tvalue\n`, as in Hadoop
//!   streaming / Text formats), **sorted**, partitioned by key hash and
//!   **spilled to actual disk files**;
//! * reducers **read those files back**, merge-sort by key, and apply the
//!   reduce function;
//! * only the fixed overheads that come from the JVM/daemon architecture
//!   are injected as calibrated constants: per-job startup (JVM spawn,
//!   job submission, InputSplit computation) and per-task dispatch (task
//!   tracker heartbeat scheduling), with a bounded number of concurrent
//!   task slots (the paper's 7 data nodes).
//!
//! The Figure-2 gap then *emerges from mechanism*: the forelem pipeline
//! computes the same aggregate in one pass over memory-resident columns
//! with no serialization, no sort, and no disk round-trip.

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::distrib::{hash_value, FaultPlan};
use crate::ir::Value;
use crate::storage::{temp_path, Table};

use super::ast::{MapFn, MapReduceProgram, ReduceFn};

/// Cluster/cost configuration.
#[derive(Debug, Clone)]
pub struct HadoopConfig {
    /// Number of map tasks (≈ input splits).
    pub map_tasks: usize,
    /// Number of reduce tasks.
    pub reducers: usize,
    /// Concurrent task slots (nodes × slots-per-node).
    pub task_slots: usize,
    /// One-time job overhead: JVM spawn, submission, split computation.
    pub job_startup: Duration,
    /// Per-task dispatch latency (task-tracker heartbeat scheduling).
    pub task_dispatch: Duration,
    /// Deterministic fault schedule, interpreted per *task index* (the
    /// JobTracker's view): a crash fails that task's first attempt (the
    /// attempt's partial spill is discarded and the task re-dispatched,
    /// Hadoop's task-level re-execution), a latency multiplier slows
    /// that task's dispatch (a loaded tracker heartbeating late).
    pub faults: FaultPlan,
}

impl Default for HadoopConfig {
    fn default() -> Self {
        // Calibrated to a small, *favourable-to-Hadoop* rendition of the
        // paper's 7-datanode deployment: generous slots, sub-second task
        // dispatch, a few seconds of job startup.
        HadoopConfig {
            map_tasks: 16,
            reducers: 7,
            task_slots: 14,
            job_startup: Duration::from_millis(2500),
            task_dispatch: Duration::from_millis(120),
            faults: FaultPlan::none(),
        }
    }
}

impl HadoopConfig {
    /// Zero-overhead variant for unit tests: mechanics only.
    pub fn instant(map_tasks: usize, reducers: usize) -> Self {
        HadoopConfig {
            map_tasks,
            reducers,
            task_slots: map_tasks.max(reducers),
            job_startup: Duration::ZERO,
            task_dispatch: Duration::ZERO,
            faults: FaultPlan::none(),
        }
    }

    /// Inject a deterministic fault schedule (see the `faults` field).
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }
}

/// Execution metrics.
#[derive(Debug, Default, Clone)]
pub struct HadoopMetrics {
    pub elapsed: Duration,
    pub map_tasks: usize,
    pub reduce_tasks: usize,
    pub spill_bytes: u64,
    pub shuffle_records: u64,
    /// Task attempts that failed and were re-dispatched (map + reduce).
    pub tasks_retried: u64,
}

/// The job result: (key, aggregate) pairs + metrics.
#[derive(Debug)]
pub struct HadoopResult {
    pub pairs: Vec<(Value, f64)>,
    pub metrics: HadoopMetrics,
}

/// Run a MapReduce program over a table.
pub fn run(cfg: &HadoopConfig, mr: &MapReduceProgram, input: &Table) -> Result<HadoopResult> {
    let t0 = Instant::now();
    std::thread::sleep(cfg.job_startup);

    let spill_bytes = Arc::new(AtomicU64::new(0));
    let shuffle_records = Arc::new(AtomicU64::new(0));

    // ---- Map phase -------------------------------------------------------
    // spills[m][r] = file with map m's records destined for reducer r.
    let m_tasks = cfg.map_tasks.max(1);
    let reducers = cfg.reducers.max(1);
    let mut spills: Vec<Vec<PathBuf>> = Vec::with_capacity(m_tasks);
    for _ in 0..m_tasks {
        spills.push((0..reducers).map(|_| temp_path("spill")).collect());
    }
    let spills = Arc::new(spills);

    let map_retries = run_task_pool(cfg, m_tasks, |m| {
        let (lo, hi) = crate::exec::block_bounds(input.len(), m_tasks, m);
        // Partition buffers of serialized records.
        let mut buffers: Vec<Vec<String>> = vec![Vec::new(); reducers];
        for row in lo..hi {
            let (key, val) = match mr.map {
                MapFn::EmitKeyOne { key_field } => (input.value(row, key_field), 1.0),
                MapFn::EmitKeyValue {
                    key_field,
                    val_field,
                } => (
                    input.value(row, key_field),
                    input.value(row, val_field).as_float().unwrap_or(0.0),
                ),
            };
            let r = (hash_value(&key) % reducers as u64) as usize;
            // Text serialization, exactly what makes Hadoop's shuffle fat.
            buffers[r].push(format!("{key}\t{val}"));
        }
        for (r, mut buf) in buffers.into_iter().enumerate() {
            // Hadoop sorts map output per partition before spilling.
            buf.sort_unstable();
            let path = &spills[m][r];
            let mut f = std::io::BufWriter::new(
                std::fs::File::create(path).context("create spill").unwrap(),
            );
            let mut bytes = 0u64;
            for line in &buf {
                bytes += line.len() as u64 + 1;
                writeln!(f, "{line}").unwrap();
            }
            f.flush().unwrap();
            spill_bytes.fetch_add(bytes, Ordering::Relaxed);
            shuffle_records.fetch_add(buf.len() as u64, Ordering::Relaxed);
        }
    });

    // ---- Shuffle + Reduce phase ------------------------------------------
    let outputs: Arc<Mutex<Vec<Vec<(Value, f64)>>>> =
        Arc::new(Mutex::new(vec![Vec::new(); reducers]));
    let reduce_retries = run_task_pool(cfg, reducers, |r| {
        // Fetch this reducer's partition from every map's spill (disk read).
        let mut records: Vec<(String, f64)> = Vec::new();
        for m in 0..m_tasks {
            let path = &spills[m][r];
            let f = std::fs::File::open(path).context("open spill").unwrap();
            for line in BufReader::new(f).lines() {
                let line = line.unwrap();
                if let Some((k, v)) = line.rsplit_once('\t') {
                    records.push((k.to_string(), v.parse().unwrap_or(0.0)));
                }
            }
        }
        // Merge-sort by key (Hadoop's reduce-side sort).
        records.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        // Apply the reduce function per key group.
        let mut out = Vec::new();
        let mut i = 0;
        while i < records.len() {
            let key = records[i].0.clone();
            let mut agg = 0.0;
            while i < records.len() && records[i].0 == key {
                agg += match mr.reduce {
                    ReduceFn::CountValues => 1.0,
                    ReduceFn::SumValues => records[i].1,
                };
                i += 1;
            }
            out.push((Value::str(key), agg));
        }
        outputs.lock().unwrap()[r] = out;
    });

    // Cleanup spills.
    for per_map in spills.iter() {
        for p in per_map {
            let _ = std::fs::remove_file(p);
        }
    }

    let pairs: Vec<(Value, f64)> = Arc::try_unwrap(outputs)
        .map_err(|_| anyhow::anyhow!("output refs leaked"))?
        .into_inner()
        .unwrap()
        .into_iter()
        .flatten()
        .collect();

    Ok(HadoopResult {
        pairs,
        metrics: HadoopMetrics {
            elapsed: t0.elapsed(),
            map_tasks: m_tasks,
            reduce_tasks: reducers,
            spill_bytes: spill_bytes.load(Ordering::Relaxed),
            shuffle_records: shuffle_records.load(Ordering::Relaxed),
            tasks_retried: map_retries + reduce_retries,
        },
    })
}

/// Run `n` tasks on `cfg.task_slots` concurrent slots, charging the
/// per-task dispatch latency and applying the fault schedule per task
/// index. Returns the number of re-dispatched (failed-then-retried)
/// attempts.
fn run_task_pool(cfg: &HadoopConfig, n: usize, task: impl Fn(usize) + Sync) -> u64 {
    let next = AtomicUsize::new(0);
    let retried = AtomicU64::new(0);
    let slots = cfg.task_slots.max(1).min(n.max(1));
    std::thread::scope(|scope| {
        for _ in 0..slots {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    return;
                }
                let mult = cfg.faults.multiplier_of(i);
                let dispatch = cfg.task_dispatch.mul_f64(mult);
                if !dispatch.is_zero() {
                    std::thread::sleep(dispatch);
                }
                if cfg.faults.crash_of(i).is_some() {
                    // First attempt dies; its partial output is discarded
                    // and the JobTracker re-dispatches the whole task.
                    retried.fetch_add(1, Ordering::Relaxed);
                    if !dispatch.is_zero() {
                        std::thread::sleep(dispatch);
                    }
                }
                task(i);
            });
        }
    });
    retried.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Multiset, Schema};

    fn access_table(rows: usize, urls: usize) -> Table {
        let schema = Schema::new(vec![("url", DataType::Str)]);
        let mut m = Multiset::new(schema);
        for i in 0..rows {
            m.push(vec![Value::str(format!("/page{}", i % urls))]);
        }
        Table::from_multiset(&m).unwrap()
    }

    fn count_program() -> MapReduceProgram {
        MapReduceProgram {
            map: MapFn::EmitKeyOne { key_field: 0 },
            reduce: ReduceFn::CountValues,
        }
    }

    #[test]
    fn counts_are_exact() {
        let t = access_table(5000, 37);
        let r = run(&HadoopConfig::instant(8, 3), &count_program(), &t).unwrap();
        assert_eq!(r.pairs.len(), 37);
        for (_, n) in &r.pairs {
            assert!((*n - 5000.0 / 37.0).abs() < 2.0);
        }
        assert_eq!(r.pairs.iter().map(|(_, n)| *n).sum::<f64>(), 5000.0);
        assert!(r.metrics.spill_bytes > 0);
        assert_eq!(r.metrics.shuffle_records, 5000);
    }

    #[test]
    fn sum_program_sums() {
        let schema = Schema::new(vec![("k", DataType::Str), ("v", DataType::Float)]);
        let mut m = Multiset::new(schema);
        for i in 0..100 {
            m.push(vec![Value::str(format!("k{}", i % 5)), Value::Float(0.5)]);
        }
        let t = Table::from_multiset(&m).unwrap();
        let mr = MapReduceProgram {
            map: MapFn::EmitKeyValue {
                key_field: 0,
                val_field: 1,
            },
            reduce: ReduceFn::SumValues,
        };
        let r = run(&HadoopConfig::instant(4, 2), &mr, &t).unwrap();
        assert_eq!(r.pairs.len(), 5);
        for (_, s) in &r.pairs {
            assert!((s - 10.0).abs() < 1e-9);
        }
    }

    #[test]
    fn single_map_single_reduce_edge() {
        let t = access_table(10, 3);
        let r = run(&HadoopConfig::instant(1, 1), &count_program(), &t).unwrap();
        assert_eq!(r.pairs.iter().map(|(_, n)| *n).sum::<f64>(), 10.0);
    }

    #[test]
    fn startup_overhead_is_charged() {
        let t = access_table(10, 2);
        let mut cfg = HadoopConfig::instant(1, 1);
        cfg.job_startup = Duration::from_millis(80);
        let r = run(&cfg, &count_program(), &t).unwrap();
        assert!(r.metrics.elapsed >= Duration::from_millis(80));
    }

    #[test]
    fn faulted_tasks_are_retried_and_results_stay_exact() {
        use crate::distrib::FaultPlan;
        let t = access_table(5000, 37);
        // Task index 2 crashes once (both pools have a task 2: one map
        // retry + one reduce retry); task 1 runs slow.
        let cfg = HadoopConfig::instant(8, 3)
            .with_faults(FaultPlan::none().crash(2, 0).slow(1, 5.0));
        let r = run(&cfg, &count_program(), &t).unwrap();
        assert_eq!(r.metrics.tasks_retried, 2);
        // The retried attempts' spills are not double-counted.
        assert_eq!(r.metrics.shuffle_records, 5000);
        assert_eq!(r.pairs.iter().map(|(_, n)| *n).sum::<f64>(), 5000.0);
        assert_eq!(r.pairs.len(), 37);
        // A fault-free run retries nothing.
        let clean = run(&HadoopConfig::instant(8, 3), &count_program(), &t).unwrap();
        assert_eq!(clean.metrics.tasks_retried, 0);
    }

    #[test]
    fn matches_coordinator_result() {
        let t = access_table(2000, 23);
        let hadoop = run(&HadoopConfig::instant(8, 4), &count_program(), &t).unwrap();
        let table = std::sync::Arc::new(t);
        let fore = crate::coordinator::run_job(
            &crate::coordinator::ClusterConfig::new(4, crate::sched::Policy::Gss),
            &crate::coordinator::AggJob::count(table, 0),
        )
        .unwrap();
        let norm = |mut v: Vec<(Value, f64)>| {
            v.sort_by(|a, b| a.0.cmp(&b.0));
            v
        };
        assert_eq!(norm(hadoop.pairs), norm(fore.pairs));
    }
}
