//! MapReduce as a front-end and back-end of the single intermediate
//! (§IV), plus the Hadoop-like baseline executor Figure 2 compares
//! against.

pub mod ast;
pub mod derive;
pub mod hadoop_sim;
pub mod lower;

pub use ast::{MapFn, MapReduceProgram, ReduceFn};
pub use derive::{derive, DeriveInfo};
pub use hadoop_sim::{run as run_hadoop, HadoopConfig, HadoopMetrics, HadoopResult};
pub use lower::lower;
