//! MapReduce program model (the §IV pseudo-code, structured).
//!
//! The supported shapes are the ones the paper derives from the single
//! intermediate: map emits `(key, 1)` or `(key, value)`; reduce counts or
//! sums the values per unique key.

use std::fmt;

/// The map function.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapFn {
    /// `emitIntermediate(t[key_field], 1)` — the URL-count / weblink map.
    EmitKeyOne { key_field: usize },
    /// `emitIntermediate(t[key_field], t[val_field])` — the §IV sum
    /// variant.
    EmitKeyValue { key_field: usize, val_field: usize },
}

impl MapFn {
    pub fn key_field(&self) -> usize {
        match self {
            MapFn::EmitKeyOne { key_field } | MapFn::EmitKeyValue { key_field, .. } => *key_field,
        }
    }
}

/// The reduce function.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceFn {
    /// `count++ per value` — emits (key, count).
    CountValues,
    /// `sum += value` — emits (key, sum).
    SumValues,
}

/// A complete MapReduce program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MapReduceProgram {
    pub map: MapFn,
    pub reduce: ReduceFn,
}

impl fmt::Display for MapReduceProgram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Render as the paper's pseudo-code.
        match self.map {
            MapFn::EmitKeyOne { key_field } => {
                writeln!(f, "map(key, value):")?;
                writeln!(f, "  for t in value:")?;
                writeln!(f, "    emitIntermediate(t[{key_field}], 1)")?;
            }
            MapFn::EmitKeyValue {
                key_field,
                val_field,
            } => {
                writeln!(f, "map(key, value):")?;
                writeln!(f, "  for t in value:")?;
                writeln!(f, "    emitIntermediate(t[{key_field}], t[{val_field}])")?;
            }
        }
        match self.reduce {
            ReduceFn::CountValues => {
                writeln!(f, "reduce(key, values):")?;
                writeln!(f, "  count = 0")?;
                writeln!(f, "  for v in values: count++")?;
                write!(f, "  emit(key, count)")
            }
            ReduceFn::SumValues => {
                writeln!(f, "reduce(key, values):")?;
                writeln!(f, "  sum = 0")?;
                writeln!(f, "  for v in values: sum += v")?;
                write!(f, "  emit(key, sum)")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_paper_pseudocode() {
        let p = MapReduceProgram {
            map: MapFn::EmitKeyOne { key_field: 0 },
            reduce: ReduceFn::CountValues,
        };
        let text = p.to_string();
        assert!(text.contains("emitIntermediate(t[0], 1)"));
        assert!(text.contains("emit(key, count)"));
    }
}
