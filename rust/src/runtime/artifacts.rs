//! AOT artifact discovery: parse `artifacts/manifest.tsv` produced by
//! `python -m compile.aot` (see python/compile/aot.py for the format).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Element type of an artifact tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemType {
    I32,
    F32,
}

/// Shape spec of one input/output: dtype + dims.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: ElemType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn parse(s: &str) -> Result<TensorSpec> {
        let (tag, dims) = s
            .split_once(':')
            .with_context(|| format!("bad tensor spec `{s}`"))?;
        let dtype = match tag {
            "i32" => ElemType::I32,
            "f32" => ElemType::F32,
            other => bail!("unknown dtype `{other}`"),
        };
        let dims = dims
            .split('x')
            .map(|d| d.parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { dtype, dims })
    }

    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("read {} (run `make artifacts`)", mpath.display()))?;
        let mut entries = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split('\t').collect();
            if parts.len() != 4 {
                bail!("manifest line {}: expected 4 fields", lineno + 1);
            }
            let inputs = parts[2]
                .split(';')
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            let entry = ArtifactEntry {
                name: parts[0].to_string(),
                path: dir.join(parts[1]),
                inputs,
                output: TensorSpec::parse(parts[3])?,
            };
            entries.insert(entry.name.clone(), entry);
        }
        Ok(Manifest { entries })
    }

    /// All entries whose name starts with `prefix`, e.g. `count_scatter_`.
    pub fn with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a ArtifactEntry> {
        self.entries
            .values()
            .filter(move |e| e.name.starts_with(prefix))
    }

    /// Pick the `prefix` entry with the smallest key-space width (output
    /// dim 0) that still covers `num_keys`. Returns None when every
    /// artifact is too narrow.
    pub fn best_for_keyspace(&self, prefix: &str, num_keys: usize) -> Option<&ArtifactEntry> {
        self.entries
            .values()
            .filter(|e| e.name.starts_with(prefix) && e.output.dims[0] >= num_keys)
            .min_by_key(|e| e.output.dims[0])
    }
}

/// The default artifacts directory: `$FORELEM_ARTIFACTS` or
/// `<repo-root>/artifacts` (relative to the executable's cwd).
pub fn default_dir() -> PathBuf {
    if let Ok(d) = std::env::var("FORELEM_ARTIFACTS") {
        return PathBuf::from(d);
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tensor_specs() {
        let t = TensorSpec::parse("i32:65536").unwrap();
        assert_eq!(t.dtype, ElemType::I32);
        assert_eq!(t.dims, vec![65536]);
        let t = TensorSpec::parse("f32:2x3").unwrap();
        assert_eq!(t.elements(), 6);
        assert!(TensorSpec::parse("bad").is_err());
        assert!(TensorSpec::parse("u8:4").is_err());
    }

    #[test]
    fn loads_real_manifest_when_built() {
        // Integration-style: only runs meaningfully after `make artifacts`.
        let dir = default_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.contains_key("count_scatter_65536x131072"));
        let e = &m.entries["count_scatter_65536x131072"];
        assert_eq!(e.inputs[0].dims, vec![65536]);
        assert_eq!(e.output.dims, vec![131072]);
        // Key-space routing.
        let best = m.best_for_keyspace("count_scatter_", 1000).unwrap();
        assert_eq!(best.output.dims[0], 1024);
        let best = m.best_for_keyspace("count_scatter_", 100_000).unwrap();
        assert_eq!(best.output.dims[0], 131072);
        assert!(m.best_for_keyspace("count_scatter_", 10_000_000).is_none());
    }
}
