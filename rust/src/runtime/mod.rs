//! The XLA/PJRT runtime: loads the AOT-compiled artifacts produced by the
//! Python build path (`make artifacts`) and exposes them as typed kernels
//! on the Rust hot path. Python never runs at request time.

pub mod artifacts;
pub mod client;
pub mod kernel;

pub use artifacts::{default_dir, ArtifactEntry, ElemType, Manifest, TensorSpec};
pub use client::{InputBuf, XlaRuntime};
pub use kernel::Kernels;
