//! PJRT client wrapper: load HLO-text artifacts, compile once, cache the
//! executables. Mirrors /opt/xla-example/load_hlo (see aot_recipe.md):
//! HLO *text* is the interchange format — xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids), while the text
//! parser reassigns ids.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::artifacts::{ArtifactEntry, Manifest};

/// A PJRT CPU client + compiled-executable cache.
///
/// The xla crate's types are not Sync; everything lives behind one mutex.
/// Artifact execution is leader-side (merge/emit path), so the lock is
/// uncontended in practice.
pub struct XlaRuntime {
    inner: Mutex<Inner>,
    pub manifest: Manifest,
}

struct Inner {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl XlaRuntime {
    /// Create a CPU runtime over an artifacts directory.
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(XlaRuntime {
            inner: Mutex::new(Inner {
                client,
                executables: HashMap::new(),
            }),
            manifest,
        })
    }

    /// Load from the default directory (`$FORELEM_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default() -> Result<XlaRuntime> {
        Self::load(&super::artifacts::default_dir())
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.manifest
            .entries
            .get(name)
            .with_context(|| format!("no artifact `{name}`"))
    }

    /// Execute artifact `name` on 1-D input literals, returning the f32
    /// output vector. Compiles and caches the executable on first use.
    pub fn run_f32(&self, name: &str, inputs: &[InputBuf]) -> Result<Vec<f32>> {
        let entry = self.entry(name)?.clone();
        let mut inner = self.inner.lock().expect("runtime lock");
        if !inner.executables.contains_key(name) {
            let proto = xla::HloModuleProto::from_text_file(
                entry
                    .path
                    .to_str()
                    .context("artifact path is not valid UTF-8")?,
            )
            .with_context(|| format!("parse HLO text {}", entry.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = inner
                .client
                .compile(&comp)
                .with_context(|| format!("compile artifact `{name}`"))?;
            inner.executables.insert(name.to_string(), exe);
        }
        let exe = &inner.executables[name];

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|b| match b {
                InputBuf::I32(v) => xla::Literal::vec1(v),
                InputBuf::F32(v) => xla::Literal::vec1(v),
            })
            .collect();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute `{name}`"))?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True → single-element tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// A 1-D input buffer.
pub enum InputBuf {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::default_dir;

    fn runtime() -> Option<XlaRuntime> {
        if !default_dir().join("manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(XlaRuntime::load(&default_dir()).unwrap())
    }

    #[test]
    fn count_scatter_artifact_counts() {
        let Some(rt) = runtime() else { return };
        let mut keys = vec![-1i32; 1024];
        keys[0] = 3;
        keys[1] = 3;
        keys[2] = 0;
        let out = rt
            .run_f32("count_scatter_1024x256", &[InputBuf::I32(keys)])
            .unwrap();
        assert_eq!(out.len(), 256);
        assert_eq!(out[3], 2.0);
        assert_eq!(out[0], 1.0);
        assert_eq!(out.iter().sum::<f32>(), 3.0);
    }

    #[test]
    fn pallas_onehot_artifact_matches_scatter() {
        let Some(rt) = runtime() else { return };
        let keys: Vec<i32> = (0..1024).map(|i| (i * 7) % 256).collect();
        let a = rt
            .run_f32("count_scatter_1024x256", &[InputBuf::I32(keys.clone())])
            .unwrap();
        let b = rt
            .run_f32("count_onehot_1024x256", &[InputBuf::I32(keys)])
            .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn weighted_avg_artifact() {
        let Some(rt) = runtime() else { return };
        let vals = vec![2.0f32; 1024];
        let wts = vec![0.5f32; 1024];
        let out = rt
            .run_f32("weighted_avg_1024", &[InputBuf::F32(vals), InputBuf::F32(wts)])
            .unwrap();
        assert_eq!(out.len(), 2);
        assert!((out[0] - 1024.0).abs() < 1e-3); // sum(v*w)
        assert!((out[1] - 512.0).abs() < 1e-3); // sum(w)
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(rt) = runtime() else { return };
        assert!(rt.run_f32("nope", &[]).is_err());
    }
}
