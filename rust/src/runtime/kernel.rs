//! Typed kernel wrappers: the `exec::plan::KernelExec` implementation
//! backed by the AOT-compiled XLA artifacts.
//!
//! Chunking protocol (shared with python/compile/aot.py):
//! * keys are i32; padding slots are `-1` and drop out of every bucket;
//! * each call uses the smallest artifact whose key-space covers
//!   `num_keys` and whose chunk size the key stream is padded to;
//! * per-chunk f32 counts are exact (chunk ≤ 65536 < 2^24); cross-chunk
//!   accumulation happens here in i64/f64.

use anyhow::{bail, Result};

use crate::exec::plan::KernelExec;

use super::client::{InputBuf, XlaRuntime};

/// Kernel dispatch over the XLA runtime, with the scatter family for wide
/// key spaces and the Pallas one-hot family for narrow ones (the
/// TPU-adapted path; see DESIGN.md §Hardware-Adaptation).
pub struct Kernels {
    rt: XlaRuntime,
    /// Prefer the Pallas one-hot artifacts when the key space fits them.
    pub prefer_onehot: bool,
}

impl Kernels {
    pub fn new(rt: XlaRuntime) -> Self {
        Kernels {
            rt,
            prefer_onehot: false,
        }
    }

    pub fn load_default() -> Result<Self> {
        Ok(Kernels::new(XlaRuntime::load_default()?))
    }

    pub fn runtime(&self) -> &XlaRuntime {
        &self.rt
    }

    fn pick(&self, op: &str, num_keys: usize, n: usize) -> Result<(String, usize, usize)> {
        // Try one-hot (Pallas) first if preferred and narrow enough.
        let families: &[&str] = if self.prefer_onehot {
            &["onehot", "scatter"]
        } else {
            &["scatter", "onehot"]
        };
        for fam in families {
            let prefix = format!("{op}_{fam}_");
            // Smallest key space that covers num_keys...
            let Some(keyspace) = self
                .rt
                .manifest
                .with_prefix(&prefix)
                .filter(|e| e.output.dims[0] >= num_keys)
                .map(|e| e.output.dims[0])
                .min()
            else {
                continue;
            };
            // ...then the chunk size that minimizes calls+padding: the
            // largest chunk <= n, else the smallest available (all-padding
            // single call). Amortizes the per-call PJRT overhead on big
            // tables (EXPERIMENTS.md §Perf).
            let candidates: Vec<_> = self
                .rt
                .manifest
                .with_prefix(&prefix)
                .filter(|e| e.output.dims[0] == keyspace)
                .collect();
            let best = candidates
                .iter()
                .filter(|e| e.inputs[0].dims[0] <= n.max(1))
                .max_by_key(|e| e.inputs[0].dims[0])
                .or_else(|| candidates.iter().min_by_key(|e| e.inputs[0].dims[0]));
            if let Some(e) = best {
                return Ok((e.name.clone(), e.inputs[0].dims[0], e.output.dims[0]));
            }
        }
        bail!("no `{op}` artifact covers a key space of {num_keys}")
    }

    /// §III-B weighted-average fold on the device; returns (dot, wsum).
    pub fn weighted_average(&self, values: &[f64], weights: &[f64]) -> Result<(f64, f64)> {
        let Some(e) = self
            .rt
            .manifest
            .with_prefix("weighted_avg_")
            .filter(|e| e.inputs[0].dims[0] >= 1)
            .min_by_key(|e| {
                let n = e.inputs[0].dims[0];
                if n >= values.len() {
                    n
                } else {
                    usize::MAX
                }
            })
        else {
            bail!("no weighted_avg artifact");
        };
        let chunk = e.inputs[0].dims[0];
        if values.len() > chunk {
            // Fold chunk by chunk.
            let mut dot = 0.0;
            let mut wsum = 0.0;
            for (vs, ws) in values.chunks(chunk).zip(weights.chunks(chunk)) {
                let (d, w) = self.weighted_average_chunk(&e.name, chunk, vs, ws)?;
                dot += d;
                wsum += w;
            }
            return Ok((dot, wsum));
        }
        self.weighted_average_chunk(&e.name, chunk, values, weights)
    }

    fn weighted_average_chunk(
        &self,
        name: &str,
        chunk: usize,
        values: &[f64],
        weights: &[f64],
    ) -> Result<(f64, f64)> {
        let mut v = vec![0f32; chunk];
        let mut w = vec![0f32; chunk];
        for (dst, src) in v.iter_mut().zip(values) {
            *dst = *src as f32;
        }
        for (dst, src) in w.iter_mut().zip(weights) {
            *dst = *src as f32;
        }
        let out = self
            .rt
            .run_f32(name, &[InputBuf::F32(v), InputBuf::F32(w)])?;
        Ok((out[0] as f64, out[1] as f64))
    }
}

impl KernelExec for Kernels {
    fn group_count(&self, keys: &[i64], num_keys: usize) -> Result<Vec<i64>> {
        let (name, chunk, keyspace) = self.pick("count", num_keys, keys.len())?;
        let mut totals = vec![0i64; keyspace];
        for part in keys.chunks(chunk) {
            let mut buf = vec![-1i32; chunk];
            for (dst, &src) in buf.iter_mut().zip(part) {
                *dst = src as i32;
            }
            let counts = self.rt.run_f32(&name, &[InputBuf::I32(buf)])?;
            for (t, c) in totals.iter_mut().zip(&counts) {
                *t += *c as i64;
            }
        }
        totals.truncate(num_keys);
        Ok(totals)
    }

    fn group_sum(&self, keys: &[i64], vals: &[f64], num_keys: usize) -> Result<Vec<f64>> {
        let (name, chunk, keyspace) = self.pick("segsum", num_keys, keys.len())?;
        let mut totals = vec![0f64; keyspace];
        for (kpart, vpart) in keys.chunks(chunk).zip(vals.chunks(chunk)) {
            let mut kbuf = vec![-1i32; chunk];
            let mut vbuf = vec![0f32; chunk];
            for (dst, &src) in kbuf.iter_mut().zip(kpart) {
                *dst = src as i32;
            }
            for (dst, &src) in vbuf.iter_mut().zip(vpart) {
                *dst = src as f32;
            }
            let sums = self
                .rt
                .run_f32(&name, &[InputBuf::I32(kbuf), InputBuf::F32(vbuf)])?;
            for (t, s) in totals.iter_mut().zip(&sums) {
                *t += *s as f64;
            }
        }
        totals.truncate(num_keys);
        Ok(totals)
    }
}

// Safe: all interior mutability is behind the runtime's mutex.
unsafe impl Sync for Kernels {}
unsafe impl Send for Kernels {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::default_dir;

    fn kernels() -> Option<Kernels> {
        if !default_dir().join("manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Kernels::load_default().unwrap())
    }

    #[test]
    fn group_count_multi_chunk_with_padding() {
        let Some(k) = kernels() else { return };
        // 1500 keys → two 1024-chunks with padding.
        let keys: Vec<i64> = (0..1500).map(|i| i % 100).collect();
        let counts = k.group_count(&keys, 256).unwrap();
        assert_eq!(counts.len(), 256);
        assert_eq!(counts.iter().sum::<i64>(), 1500);
        assert_eq!(counts[0], 15);
        assert_eq!(counts[99], 15);
        assert_eq!(counts[100], 0);
    }

    #[test]
    fn group_count_routes_to_wide_artifact() {
        let Some(k) = kernels() else { return };
        let keys: Vec<i64> = (0..100).map(|i| 1000 + i).collect();
        let counts = k.group_count(&keys, 2000).unwrap();
        assert_eq!(counts.len(), 2000);
        assert_eq!(counts[1000], 1);
        assert_eq!(counts.iter().sum::<i64>(), 100);
    }

    #[test]
    fn group_sum_matches_native() {
        let Some(k) = kernels() else { return };
        let keys: Vec<i64> = (0..500).map(|i| i % 7).collect();
        let vals: Vec<f64> = (0..500).map(|i| (i % 13) as f64 * 0.5).collect();
        let sums = k.group_sum(&keys, &vals, 256).unwrap();
        let mut want = vec![0f64; 256];
        for (&key, &v) in keys.iter().zip(&vals) {
            want[key as usize] += v;
        }
        for (a, b) in sums.iter().zip(&want) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn onehot_preference_changes_artifact_not_result() {
        let Some(mut k) = kernels() else { return };
        let keys: Vec<i64> = (0..2048).map(|i| i % 200).collect();
        let a = k.group_count(&keys, 1024).unwrap();
        k.prefer_onehot = true;
        let b = k.group_count(&keys, 1024).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn weighted_average_device_fold() {
        let Some(k) = kernels() else { return };
        let vals: Vec<f64> = (0..3000).map(|i| (i % 10) as f64).collect();
        let wts: Vec<f64> = (0..3000).map(|_| 0.5).collect();
        let (dot, wsum) = k.weighted_average(&vals, &wts).unwrap();
        let want_dot: f64 = vals.iter().map(|v| v * 0.5).sum();
        assert!((dot - want_dot).abs() / want_dot < 1e-3, "{dot} vs {want_dot}");
        assert!((wsum - 1500.0).abs() < 1.0);
    }
}
