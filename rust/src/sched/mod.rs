//! Loop scheduling (§III-A2) and its fault-tolerance role (§III-A3).
//!
//! A scheduler hands out *chunks* of a parallel loop's iteration space to
//! requesting workers. Static schedules are fixed at compile time; the
//! dynamic family (GSS, trapezoid, factoring, feedback-guided) shrinks
//! chunk sizes over time to balance skewed iteration costs; the hybrid
//! scheme runs dynamic scheduling over super-chunks that are executed
//! with a static schedule inside, so a node failure costs exactly one
//! super-chunk of recompute.
//!
//! [`SharedScheduler::with_affinity`] adds *cache affinity* on top of
//! any policy: each worker owns a contiguous home region of the
//! iteration space and pulls the range adjacent to its last-completed
//! chunk (chunk sizes still follow the policy), stealing from the
//! largest remaining region only once its neighborhood is drained.
//! Fan-outs that observed an adjacent pull tag `"sched.affinity"`.
//! [`MultiScheduler`] generalizes the shared scheduler to N concurrent
//! queries over ONE pool: per-query morsel spaces, FIFO admission with a
//! bounded in-flight count, and fair round-robin chunk interleaving (the
//! `serve` layer's `"sched.multi"` machinery).
//! [`pin_worker`] optionally pins worker threads to cores — best-effort,
//! behind the off-by-default `core_affinity` feature, a no-op elsewhere.

use std::collections::{BTreeSet, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// A contiguous chunk of iterations `[lo, hi)`. `Hash` lets the
/// coordinator keep its commit set of merged chunks, so duplicated work
/// (speculative re-execution, re-queued retries) is merged exactly once.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Chunk {
    pub lo: usize,
    pub hi: usize,
}

impl Chunk {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.lo >= self.hi
    }
}

/// The scheduling discipline, selectable per job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Compile-time block schedule: worker w owns block w. Zero overhead,
    /// no run-time changes possible (§III-A3's caveat).
    StaticBlock,
    /// Fixed-size chunks handed out dynamically (self-scheduling).
    FixedChunk(usize),
    /// Guided Self-Scheduling [Polychronopoulos & Kuck]: chunk = ceil(remaining / p).
    Gss,
    /// Trapezoid Self-Scheduling [Tzen & Ni]: chunk sizes decrease
    /// linearly from n/(2p) to 1.
    Trapezoid,
    /// Factoring [Hummel et al.]: batches of p chunks, each batch half the
    /// remaining work.
    Factoring,
    /// Feedback-guided: starts like GSS but rescales per-worker chunk
    /// sizes by observed throughput.
    FeedbackGuided,
    /// Hybrid (§III-A3): dynamic over super-chunks (static inside), fault
    /// recovery at super-chunk granularity.
    Hybrid { super_chunks_per_worker: usize },
}

impl Policy {
    /// One representative instance of every scheduling discipline, for
    /// exhaustive policy sweeps in tests and benches. Parameterized
    /// variants carry typical values; sweep-specific parameters (chunk
    /// sizes, super-chunk counts) can still be built directly.
    pub const ALL: [Policy; 7] = [
        Policy::StaticBlock,
        Policy::FixedChunk(64),
        Policy::Gss,
        Policy::Trapezoid,
        Policy::Factoring,
        Policy::FeedbackGuided,
        Policy::Hybrid {
            super_chunks_per_worker: 4,
        },
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Policy::StaticBlock => "static",
            Policy::FixedChunk(_) => "fixed-chunk",
            Policy::Gss => "gss",
            Policy::Trapezoid => "trapezoid",
            Policy::Factoring => "factoring",
            Policy::FeedbackGuided => "feedback",
            Policy::Hybrid { .. } => "hybrid",
        }
    }
}

/// Runtime scheduler state. Thread-safe use is the coordinator's job
/// (it wraps this in a mutex).
#[derive(Debug)]
pub struct Scheduler {
    policy: Policy,
    n: usize,
    workers: usize,
    /// Next unassigned iteration (for progressive policies).
    cursor: usize,
    /// Requeued chunks (fault recovery) take priority.
    requeued: VecDeque<Chunk>,
    /// Static pre-assignment (StaticBlock): one block per worker.
    static_blocks: Vec<Option<Chunk>>,
    /// Trapezoid state.
    trapezoid_next: f64,
    trapezoid_delta: f64,
    /// Factoring state.
    factoring_batch: VecDeque<Chunk>,
    /// Feedback: per-worker relative speed estimate (EWMA of iters/sec).
    speeds: Vec<f64>,
    /// Total chunks handed out (stats).
    pub chunks_issued: usize,
}

impl Scheduler {
    pub fn new(policy: Policy, n: usize, workers: usize) -> Self {
        assert!(workers > 0);
        let p = workers as f64;
        let first = (n as f64 / (2.0 * p)).ceil().max(1.0);
        // Trapezoid: chunk sizes decrease linearly from `first` to 1 over
        // approximately 2n/(first+1) chunks.
        let steps = (2.0 * n as f64 / (first + 1.0)).ceil().max(1.0);
        let delta = if steps > 1.0 {
            (first - 1.0) / (steps - 1.0)
        } else {
            0.0
        };
        let mut static_blocks = vec![None; workers];
        if policy == Policy::StaticBlock {
            for (w, slot) in static_blocks.iter_mut().enumerate() {
                let (lo, hi) = crate::exec::block_bounds(n, workers, w);
                if lo < hi {
                    *slot = Some(Chunk { lo, hi });
                }
            }
        }
        Scheduler {
            policy,
            n,
            workers,
            cursor: 0,
            requeued: VecDeque::new(),
            static_blocks,
            trapezoid_next: first,
            trapezoid_delta: delta,
            factoring_batch: VecDeque::new(),
            speeds: vec![1.0; workers],
            chunks_issued: 0,
        }
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Can iterations be re-assigned after a failure?
    pub fn supports_requeue(&self) -> bool {
        self.policy != Policy::StaticBlock
    }

    /// Next chunk for `worker`, or None when the loop is exhausted.
    pub fn next_chunk(&mut self, worker: usize) -> Option<Chunk> {
        debug_assert!(worker < self.workers);
        if let Some(c) = self.requeued.pop_front() {
            self.chunks_issued += 1;
            return Some(c);
        }
        // Factoring pre-carves batches past the cursor; drain them first.
        if let Some(c) = self.factoring_batch.pop_front() {
            self.chunks_issued += 1;
            return Some(c);
        }
        let remaining = self.n - self.cursor;
        if remaining == 0 {
            return None;
        }
        let size = match self.policy {
            Policy::StaticBlock => {
                let c = self.static_blocks[worker].take();
                if let Some(c) = &c {
                    self.cursor += c.len();
                    self.chunks_issued += 1;
                }
                return c;
            }
            Policy::FixedChunk(s) => s.max(1),
            Policy::Gss => remaining.div_ceil(self.workers),
            Policy::Trapezoid => {
                let s = self.trapezoid_next.round().max(1.0) as usize;
                self.trapezoid_next = (self.trapezoid_next - self.trapezoid_delta).max(1.0);
                s
            }
            Policy::Factoring => {
                // Allocate half the remaining work as p equal chunks.
                let batch = (remaining / 2).max(self.workers.min(remaining));
                let per = (batch / self.workers).max(1);
                let mut lo = self.cursor;
                for _ in 0..self.workers {
                    let hi = (lo + per).min(self.n);
                    if lo < hi {
                        self.factoring_batch.push_back(Chunk { lo, hi });
                    }
                    lo = hi;
                }
                self.cursor = lo;
                let c = self.factoring_batch.pop_front().expect("nonempty batch");
                self.chunks_issued += 1;
                return Some(c);
            }
            Policy::FeedbackGuided => {
                // GSS baseline scaled by this worker's relative speed.
                let base = remaining.div_ceil(self.workers);
                let avg: f64 = self.speeds.iter().sum::<f64>() / self.workers as f64;
                ((base as f64) * (self.speeds[worker] / avg).clamp(0.25, 4.0))
                    .round()
                    .max(1.0) as usize
            }
            Policy::Hybrid {
                super_chunks_per_worker,
            } => {
                let total_chunks = self.workers * super_chunks_per_worker.max(1);
                (self.n / total_chunks).max(1)
            }
        };
        let lo = self.cursor;
        let hi = (lo + size).min(self.n);
        self.cursor = hi;
        self.chunks_issued += 1;
        Some(Chunk { lo, hi })
    }

    /// Chunk *size* the policy would issue with `remaining` iterations
    /// left — the position-free half of [`next_chunk`](Self::next_chunk),
    /// used by the affinity-aware shared scheduler, which carves chunks
    /// off per-worker regions rather than off one global cursor.
    /// Factoring degrades to its per-chunk size (regions shrink
    /// independently, so batches cannot be pre-carved); StaticBlock takes
    /// the caller's whole region.
    fn next_size(&mut self, worker: usize, remaining: usize) -> usize {
        match self.policy {
            Policy::StaticBlock => remaining,
            Policy::FixedChunk(s) => s.max(1),
            Policy::Gss => remaining.div_ceil(self.workers),
            Policy::Trapezoid => {
                let s = self.trapezoid_next.round().max(1.0) as usize;
                self.trapezoid_next = (self.trapezoid_next - self.trapezoid_delta).max(1.0);
                s
            }
            Policy::Factoring => {
                let batch = (remaining / 2).max(self.workers.min(remaining));
                (batch / self.workers).max(1)
            }
            Policy::FeedbackGuided => {
                let base = remaining.div_ceil(self.workers);
                let avg: f64 = self.speeds.iter().sum::<f64>() / self.workers as f64;
                ((base as f64) * (self.speeds[worker] / avg).clamp(0.25, 4.0))
                    .round()
                    .max(1.0) as usize
            }
            Policy::Hybrid {
                super_chunks_per_worker,
            } => {
                let total_chunks = self.workers * super_chunks_per_worker.max(1);
                (self.n / total_chunks).max(1)
            }
        }
    }

    /// Report a completed chunk (feedback-guided uses the timing).
    pub fn report(&mut self, worker: usize, chunk: Chunk, elapsed: Duration) {
        if self.policy == Policy::FeedbackGuided {
            let secs = elapsed.as_secs_f64().max(1e-9);
            let speed = chunk.len() as f64 / secs;
            let s = &mut self.speeds[worker];
            *s = 0.7 * *s + 0.3 * speed;
        }
    }

    /// Give back iterations from a failed worker (§III-A3). Panics if the
    /// policy cannot reassign work — callers must check
    /// [`supports_requeue`] and restart the computation instead.
    pub fn requeue(&mut self, chunk: Chunk) {
        assert!(
            self.supports_requeue(),
            "static schedules cannot reassign work at run time"
        );
        if !chunk.is_empty() {
            self.requeued.push_back(chunk);
        }
    }

    /// All iterations assigned so far (monotone; includes requeued ones
    /// once re-issued).
    pub fn exhausted(&self) -> bool {
        self.cursor >= self.n
            && self.requeued.is_empty()
            && self.factoring_batch.is_empty()
            && self.static_blocks.iter().all(|b| b.is_none())
    }
}

/// A [`Scheduler`] shareable across an in-process worker pool: the same
/// §III-A2 policy machinery the distributed coordinator's leader drives,
/// behind a mutex so `exec::parallel`'s morsel workers can pull chunks
/// concurrently. Workers take the lock once per chunk — not per row or
/// morsel — so contention stays negligible next to chunk execution.
#[derive(Debug)]
pub struct SharedScheduler {
    inner: Mutex<SharedInner>,
}

#[derive(Debug)]
struct SharedInner {
    sched: Scheduler,
    affinity: Option<AffinityState>,
}

/// Per-worker chunk-affinity state: the iteration space is carved into
/// one contiguous home region per worker and each region is consumed
/// front-to-back, so every chunk a worker pulls from its own region is
/// adjacent to its previous one (the column windows it just touched stay
/// cache-resident). Chunk *sizes* still follow the wrapped policy.
#[derive(Debug)]
struct AffinityState {
    /// Un-issued remainder of each worker's contiguous share of `[0, n)`.
    regions: Vec<Chunk>,
    /// End of the last chunk each worker pulled, for the adjacency check.
    last_hi: Vec<Option<usize>>,
    /// Some worker pulled the range adjacent to its previous chunk.
    engaged: bool,
    /// Iterations not yet issued, across all regions.
    remaining: usize,
}

impl SharedScheduler {
    pub fn new(policy: Policy, n: usize, workers: usize) -> Self {
        SharedScheduler {
            inner: Mutex::new(SharedInner {
                sched: Scheduler::new(policy, n, workers),
                affinity: None,
            }),
        }
    }

    /// Like [`new`](Self::new), but cache- and affinity-aware: `[0, n)`
    /// is carved into one contiguous home region per worker (via
    /// `exec::block_bounds`, the static-block shape), and `next_chunk`
    /// serves worker `w` from region `w` front-to-back — preferentially
    /// the range adjacent to its last-completed chunk — falling back to
    /// stealing from the front of the largest remaining region once its
    /// own neighborhood is drained. [`Policy::StaticBlock`] never steals:
    /// its affinity regions *are* the static blocks, preserving the
    /// one-contiguous-range-per-worker guarantee fused joins rely on.
    pub fn with_affinity(policy: Policy, n: usize, workers: usize) -> Self {
        let regions: Vec<Chunk> = (0..workers)
            .map(|w| {
                let (lo, hi) = crate::exec::block_bounds(n, workers, w);
                Chunk { lo, hi }
            })
            .collect();
        SharedScheduler {
            inner: Mutex::new(SharedInner {
                sched: Scheduler::new(policy, n, workers),
                affinity: Some(AffinityState {
                    regions,
                    last_hi: vec![None; workers],
                    engaged: false,
                    remaining: n,
                }),
            }),
        }
    }

    /// Next chunk for `worker`, or `None` when the space is exhausted.
    pub fn next_chunk(&self, worker: usize) -> Option<Chunk> {
        let inner = &mut *self.inner.lock().expect("scheduler lock");
        let Some(aff) = &mut inner.affinity else {
            return inner.sched.next_chunk(worker);
        };
        if aff.remaining == 0 {
            return None;
        }
        // Own region first (the range adjacent to the worker's last
        // chunk); steal from the largest remainder once it is drained.
        let source = if !aff.regions[worker].is_empty() {
            worker
        } else {
            if inner.sched.policy == Policy::StaticBlock {
                return None;
            }
            aff.regions
                .iter()
                .enumerate()
                .max_by_key(|(_, r)| r.len())
                .map(|(w, _)| w)?
        };
        let size = inner
            .sched
            .next_size(worker, aff.remaining)
            .clamp(1, aff.regions[source].len());
        let region = &mut aff.regions[source];
        let c = Chunk {
            lo: region.lo,
            hi: region.lo + size,
        };
        region.lo = c.hi;
        aff.remaining -= c.len();
        inner.sched.chunks_issued += 1;
        if source == worker && aff.last_hi[worker] == Some(c.lo) {
            aff.engaged = true;
        }
        aff.last_hi[worker] = Some(c.hi);
        Some(c)
    }

    /// Report a completed chunk (feedback-guided policies use the timing).
    pub fn report(&self, worker: usize, chunk: Chunk, elapsed: Duration) {
        self.inner
            .lock()
            .expect("scheduler lock")
            .sched
            .report(worker, chunk, elapsed);
    }

    /// Total chunks handed out so far.
    pub fn chunks_issued(&self) -> usize {
        self.inner.lock().expect("scheduler lock").sched.chunks_issued
    }

    /// True when some worker pulled the range adjacent to its previous
    /// chunk — the signal fan-outs turn into the `"sched.affinity"` tag.
    /// Always `false` for schedulers built with [`new`](Self::new).
    pub fn affinity_engaged(&self) -> bool {
        match &self.inner.lock().expect("scheduler lock").affinity {
            Some(a) => a.engaged,
            None => false,
        }
    }
}

/// The multi-query generalization of [`SharedScheduler`]: N concurrent
/// queries multiplex ONE worker pool. Each admitted query submits its
/// morsel space as a *phase* (its own [`Scheduler`], so every query keeps
/// its own policy state and chunk-size progression); pool workers pull
/// `(query, chunk)` pairs and the scheduler round-robins across live
/// phases on every pull, so one long scan cannot starve its neighbors —
/// fair chunk interleaving, not query-at-a-time draining.
///
/// Admission control is a bounded FIFO lane: at most `max_inflight`
/// queries hold execution slots; later arrivals queue in strict ticket
/// order (no barging) until a slot frees. The serving layer
/// (`serve::Server`) turns an admitted query into the `"serve.admit"`
/// tag and a pool-executed phase into `"sched.multi"`.
///
/// Worker threads are expected to poll [`next_chunk`](Self::next_chunk)
/// in a loop until it returns `None` (which only happens after
/// [`shutdown`](Self::shutdown)); with [`Policy::StaticBlock`] every
/// worker must keep polling or its pre-assigned block is never issued —
/// pools that park workers should use a dynamic policy.
#[derive(Debug)]
pub struct MultiScheduler {
    workers: usize,
    max_inflight: usize,
    state: Mutex<MultiState>,
    /// FIFO admission lane.
    admit_cv: Condvar,
    /// Pool workers parked waiting for chunks.
    work_cv: Condvar,
    /// Clients parked in `wait_done`.
    done_cv: Condvar,
}

#[derive(Debug)]
struct MultiState {
    /// Admission tickets: `next_ticket` is handed to the next arrival,
    /// `now_serving` gates the queue front, `inflight` counts held slots.
    next_ticket: u64,
    now_serving: u64,
    inflight: usize,
    /// Deepest the overflow queue ever got (observability).
    queued_peak: usize,
    /// Live morsel spaces, one per query currently fanning out.
    phases: Vec<MultiPhase>,
    /// Most phases ever live at once (observability: >= 2 proves real
    /// multi-query interleaving happened).
    phases_peak: usize,
    /// Completed phase ids awaiting their `wait_done` pickup.
    finished: BTreeSet<u64>,
    /// Round-robin cursor for fair interleaving across phases.
    rr: usize,
    shutdown: bool,
}

#[derive(Debug)]
struct MultiPhase {
    query: u64,
    sched: Scheduler,
    /// Chunks handed to workers and not yet reported back. A phase
    /// completes when its space is exhausted AND nothing is outstanding.
    outstanding: usize,
}

impl MultiScheduler {
    pub fn new(workers: usize, max_inflight: usize) -> Self {
        MultiScheduler {
            workers: workers.max(1),
            max_inflight: max_inflight.max(1),
            state: Mutex::new(MultiState {
                next_ticket: 0,
                now_serving: 0,
                inflight: 0,
                queued_peak: 0,
                phases: Vec::new(),
                phases_peak: 0,
                finished: BTreeSet::new(),
                rr: 0,
                shutdown: false,
            }),
            admit_cv: Condvar::new(),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        }
    }

    /// Pool width this scheduler was built for (phases are created with
    /// this worker count).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Admit one query, blocking while `max_inflight` slots are held.
    /// Returns the query's unique id and whether it had to queue. Strict
    /// FIFO: tickets are served in arrival order even when several
    /// arrivals race one freed slot.
    pub fn admit(&self) -> (u64, bool) {
        let mut st = self.state.lock().expect("multi-scheduler lock");
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        let depth = (st.next_ticket - st.now_serving) as usize;
        st.queued_peak = st.queued_peak.max(depth.saturating_sub(1));
        let mut waited = false;
        while !(st.now_serving == ticket && st.inflight < self.max_inflight) {
            waited = true;
            st = self.admit_cv.wait(st).expect("multi-scheduler lock");
        }
        st.now_serving += 1;
        st.inflight += 1;
        drop(st);
        // The next ticket in line may also fit (inflight could still be
        // under the bound); let it re-check.
        self.admit_cv.notify_all();
        (ticket, waited)
    }

    /// Release an admitted query's slot (its execution finished).
    pub fn release(&self, _query: u64) {
        let mut st = self.state.lock().expect("multi-scheduler lock");
        st.inflight -= 1;
        drop(st);
        self.admit_cv.notify_all();
    }

    /// Deepest the admission overflow queue ever got.
    pub fn queued_peak(&self) -> usize {
        self.state.lock().expect("multi-scheduler lock").queued_peak
    }

    /// Most phases ever live at once.
    pub fn phases_peak(&self) -> usize {
        self.state.lock().expect("multi-scheduler lock").phases_peak
    }

    /// Open query `query`'s morsel space of `n` iterations under
    /// `policy`. An empty space completes immediately.
    pub fn submit(&self, query: u64, policy: Policy, n: usize) {
        let mut st = self.state.lock().expect("multi-scheduler lock");
        if n == 0 {
            st.finished.insert(query);
            drop(st);
            self.done_cv.notify_all();
            return;
        }
        st.phases.push(MultiPhase {
            query,
            sched: Scheduler::new(policy, n, self.workers),
            outstanding: 0,
        });
        let live = st.phases.len();
        st.phases_peak = st.phases_peak.max(live);
        drop(st);
        self.work_cv.notify_all();
    }

    /// Next `(query, chunk)` for `worker`. Blocks while no phase has
    /// work; returns `None` only after [`shutdown`](Self::shutdown).
    /// Consecutive pulls rotate across live phases (fair interleaving).
    pub fn next_chunk(&self, worker: usize) -> Option<(u64, Chunk)> {
        let mut st = self.state.lock().expect("multi-scheduler lock");
        loop {
            let len = st.phases.len();
            if len > 0 {
                let start = st.rr % len;
                for i in 0..len {
                    let idx = (start + i) % len;
                    if let Some(c) = st.phases[idx].sched.next_chunk(worker) {
                        st.phases[idx].outstanding += 1;
                        let query = st.phases[idx].query;
                        st.rr = idx + 1;
                        return Some((query, c));
                    }
                }
            }
            if st.shutdown {
                return None;
            }
            st = self.work_cv.wait(st).expect("multi-scheduler lock");
        }
    }

    /// Report a completed chunk. The phase retires (waking its
    /// `wait_done` caller) once its space is exhausted and every issued
    /// chunk has been reported.
    pub fn report(&self, query: u64, worker: usize, chunk: Chunk, elapsed: Duration) {
        let mut st = self.state.lock().expect("multi-scheduler lock");
        let Some(idx) = st.phases.iter().position(|p| p.query == query) else {
            return;
        };
        let p = &mut st.phases[idx];
        p.sched.report(worker, chunk, elapsed);
        p.outstanding -= 1;
        if p.outstanding == 0 && p.sched.exhausted() {
            st.phases.remove(idx);
            st.finished.insert(query);
            drop(st);
            self.done_cv.notify_all();
        }
    }

    /// Block until `query`'s submitted space has fully executed (every
    /// chunk issued and reported).
    pub fn wait_done(&self, query: u64) {
        let mut st = self.state.lock().expect("multi-scheduler lock");
        while !st.finished.contains(&query) {
            st = self.done_cv.wait(st).expect("multi-scheduler lock");
        }
        st.finished.remove(&query);
    }

    /// Wake every parked worker and make `next_chunk` return `None` once
    /// the remaining phases are drained. Call after all queries finished.
    pub fn shutdown(&self) {
        let mut st = self.state.lock().expect("multi-scheduler lock");
        st.shutdown = true;
        drop(st);
        self.work_cv.notify_all();
    }
}

/// Best-effort: pin the calling worker thread to a core chosen by worker
/// index (round-robin over the machine's cores). Returns whether the pin
/// took. Compiled to a no-op returning `false` unless the off-by-default
/// `core_affinity` feature is enabled on Linux — schedulers treat
/// pinning strictly as a hint, never a requirement.
#[cfg(all(feature = "core_affinity", target_os = "linux"))]
pub fn pin_worker(worker: usize) -> bool {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    pin::pin_to_core(worker % cores)
}

/// No-op fallback: the `core_affinity` feature is off or the platform
/// has no `sched_setaffinity`.
#[cfg(not(all(feature = "core_affinity", target_os = "linux")))]
pub fn pin_worker(_worker: usize) -> bool {
    false
}

#[cfg(all(feature = "core_affinity", target_os = "linux"))]
mod pin {
    /// `cpu_set_t` as `sched_setaffinity(2)` expects it: 1024 bits.
    #[repr(C)]
    struct CpuSet {
        bits: [u64; 16],
    }

    extern "C" {
        // std already links libc on Linux, so no new dependency.
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const CpuSet) -> i32;
    }

    pub fn pin_to_core(core: usize) -> bool {
        if core >= 16 * 64 {
            return false;
        }
        let mut set = CpuSet { bits: [0u64; 16] };
        set.bits[core / 64] = 1u64 << (core % 64);
        // SAFETY: pid 0 targets the calling thread; the mask is a live
        // local of exactly the size we pass.
        unsafe { sched_setaffinity(0, std::mem::size_of::<CpuSet>(), &set) == 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain a scheduler round-robin and assert exact coverage of 0..n.
    fn coverage(policy: Policy, n: usize, p: usize) -> Vec<Chunk> {
        let mut s = Scheduler::new(policy, n, p);
        let mut got = Vec::new();
        let mut w = 0;
        while let Some(c) = s.next_chunk(w % p) {
            got.push(c);
            w += 1;
        }
        let mut seen = vec![false; n];
        for c in &got {
            for i in c.lo..c.hi {
                assert!(!seen[i], "{policy:?}: iteration {i} issued twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "{policy:?}: some iteration never issued");
        assert!(s.exhausted());
        got
    }

    #[test]
    fn all_policies_cover_exactly_once() {
        for policy in [
            Policy::StaticBlock,
            Policy::FixedChunk(7),
            Policy::Gss,
            Policy::Trapezoid,
            Policy::Factoring,
            Policy::FeedbackGuided,
            Policy::Hybrid {
                super_chunks_per_worker: 4,
            },
        ] {
            for (n, p) in [(100, 4), (1000, 8), (5, 8), (1, 1), (64, 3)] {
                coverage(policy, n, p);
            }
        }
    }

    #[test]
    fn gss_chunks_decrease() {
        let chunks = coverage(Policy::Gss, 1000, 4);
        assert!(chunks[0].len() >= chunks[chunks.len() - 1].len());
        assert_eq!(chunks[0].len(), 250); // ceil(1000/4)
    }

    #[test]
    fn trapezoid_decreases_linearly() {
        let chunks = coverage(Policy::Trapezoid, 1000, 4);
        assert_eq!(chunks[0].len(), 125); // n/(2p)
        for w in chunks.windows(2) {
            assert!(w[1].len() <= w[0].len() + 1);
        }
    }

    #[test]
    fn static_gives_one_block_per_worker() {
        let mut s = Scheduler::new(Policy::StaticBlock, 100, 4);
        for w in 0..4 {
            let c = s.next_chunk(w).unwrap();
            assert_eq!(c.len(), 25);
            assert!(s.next_chunk(w).is_none() || w < 3);
        }
        assert!(!s.supports_requeue());
    }

    #[test]
    fn requeue_reissues_failed_chunk() {
        let mut s = Scheduler::new(Policy::Gss, 100, 4);
        let c1 = s.next_chunk(0).unwrap();
        s.requeue(c1);
        let again = s.next_chunk(1).unwrap();
        assert_eq!(c1, again);
    }

    #[test]
    #[should_panic(expected = "static schedules")]
    fn static_requeue_panics() {
        let mut s = Scheduler::new(Policy::StaticBlock, 100, 4);
        let c = s.next_chunk(0).unwrap();
        s.requeue(c);
    }

    #[test]
    fn feedback_gives_fast_workers_bigger_chunks() {
        let mut s = Scheduler::new(Policy::FeedbackGuided, 100_000, 2);
        // Teach it: worker 0 is 4x faster.
        let c = s.next_chunk(0).unwrap();
        s.report(0, c, Duration::from_millis(10));
        let c = s.next_chunk(1).unwrap();
        s.report(1, c, Duration::from_millis(40 * c.len() as u64 / 25_000.max(1)));
        // Let the EWMA converge a little.
        for _ in 0..3 {
            let c0 = s.next_chunk(0).unwrap();
            s.report(0, c0, Duration::from_micros((c0.len() as u64).max(1)));
            let c1 = s.next_chunk(1).unwrap();
            s.report(1, c1, Duration::from_micros((c1.len() as u64 * 8).max(1)));
        }
        let big = s.next_chunk(0).unwrap();
        let small = s.next_chunk(1).unwrap();
        assert!(
            big.len() > small.len(),
            "fast worker got {} vs slow {}",
            big.len(),
            small.len()
        );
    }

    #[test]
    fn shared_scheduler_covers_exactly_once_under_concurrency() {
        for policy in Policy::ALL {
            let n = 10_000;
            let workers = 4;
            let s = SharedScheduler::new(policy, n, workers);
            let s = &s;
            let covered: Vec<Vec<Chunk>> = std::thread::scope(|scope| {
                (0..workers)
                    .map(|w| {
                        scope.spawn(move || {
                            let mut got = Vec::new();
                            while let Some(c) = s.next_chunk(w) {
                                s.report(w, c, Duration::from_micros(c.len() as u64));
                                got.push(c);
                            }
                            got
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
            let mut seen = vec![false; n];
            for c in covered.iter().flatten() {
                for i in c.lo..c.hi {
                    assert!(!seen[i], "{policy:?}: iteration {i} issued twice");
                    seen[i] = true;
                }
            }
            assert!(
                seen.iter().all(|&b| b),
                "{policy:?}: some iteration never issued"
            );
            assert!(s.chunks_issued() >= workers.min(n));
        }
    }

    /// Drain an affinity scheduler round-robin and assert exactly-once
    /// coverage of `0..n` (StaticBlock workers stop at their own region;
    /// the round-robin still covers everything).
    fn affinity_coverage(policy: Policy, n: usize, p: usize) {
        let s = SharedScheduler::with_affinity(policy, n, p);
        let mut seen = vec![false; n];
        loop {
            let mut any = false;
            for w in 0..p {
                if let Some(c) = s.next_chunk(w) {
                    any = true;
                    s.report(w, c, Duration::from_micros(c.len() as u64));
                    for i in c.lo..c.hi {
                        assert!(!seen[i], "{policy:?}: iteration {i} issued twice");
                        seen[i] = true;
                    }
                }
            }
            if !any {
                break;
            }
        }
        assert!(
            seen.iter().all(|&b| b),
            "{policy:?}: some iteration never issued"
        );
    }

    #[test]
    fn affinity_scheduler_covers_exactly_once() {
        for policy in Policy::ALL {
            for (n, p) in [(100, 4), (1000, 8), (5, 8), (1, 1), (64, 3)] {
                affinity_coverage(policy, n, p);
            }
        }
    }

    #[test]
    fn affinity_scheduler_covers_exactly_once_under_concurrency() {
        for policy in Policy::ALL {
            let n = 10_000;
            let workers = 4;
            let s = SharedScheduler::with_affinity(policy, n, workers);
            let s = &s;
            let covered: Vec<Vec<Chunk>> = std::thread::scope(|scope| {
                (0..workers)
                    .map(|w| {
                        scope.spawn(move || {
                            let mut got = Vec::new();
                            while let Some(c) = s.next_chunk(w) {
                                s.report(w, c, Duration::from_micros(c.len() as u64));
                                got.push(c);
                            }
                            got
                        })
                    })
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect()
            });
            let mut seen = vec![false; n];
            for c in covered.iter().flatten() {
                for i in c.lo..c.hi {
                    assert!(!seen[i], "{policy:?}: iteration {i} issued twice");
                    seen[i] = true;
                }
            }
            // StaticBlock workers never steal, so a worker that finishes
            // early leaves its peers' regions alone — but every region is
            // still drained by its owner.
            assert!(
                seen.iter().all(|&b| b),
                "{policy:?}: some iteration never issued"
            );
        }
    }

    #[test]
    fn affinity_workers_pull_adjacent_chunks_and_engage() {
        let s = SharedScheduler::with_affinity(Policy::FixedChunk(10), 100, 2);
        assert!(!s.affinity_engaged());
        let a = s.next_chunk(0).unwrap();
        assert_eq!((a.lo, a.hi), (0, 10));
        let b = s.next_chunk(0).unwrap();
        assert_eq!((b.lo, b.hi), (10, 20), "second pull continues the region");
        assert!(s.affinity_engaged());
        // Worker 1 serves its own half, not worker 0's neighborhood.
        let c = s.next_chunk(1).unwrap();
        assert_eq!((c.lo, c.hi), (50, 60));
    }

    #[test]
    fn affinity_steals_only_after_neighborhood_drained() {
        let s = SharedScheduler::with_affinity(Policy::FixedChunk(25), 100, 2);
        // Worker 0 drains its own half, then steals worker 1's remainder.
        let mut rows = 0;
        let mut chunks = Vec::new();
        while let Some(c) = s.next_chunk(0) {
            rows += c.len();
            chunks.push(c);
        }
        assert_eq!(rows, 100, "dynamic policies steal the whole space");
        assert!(chunks[0].hi <= 50 && chunks[1].hi <= 50);
        assert!(chunks.last().unwrap().hi == 100);
    }

    #[test]
    fn affinity_static_blocks_stay_pinned() {
        let s = SharedScheduler::with_affinity(Policy::StaticBlock, 100, 4);
        for w in 0..4 {
            let c = s.next_chunk(w).unwrap();
            assert_eq!((c.lo, c.hi), crate::exec::block_bounds(100, 4, w));
            assert!(s.next_chunk(w).is_none(), "static never steals");
        }
    }

    #[test]
    fn pin_worker_is_best_effort() {
        // No-op (false) without the `core_affinity` feature; with it,
        // pinning to an in-range core must not panic either way.
        let _ = pin_worker(0);
    }

    #[test]
    fn hybrid_chunk_count_matches_super_chunks() {
        let chunks = coverage(
            Policy::Hybrid {
                super_chunks_per_worker: 4,
            },
            1600,
            4,
        );
        assert_eq!(chunks.len(), 16);
        assert!(chunks.iter().all(|c| c.len() == 100));
    }

    #[test]
    fn multi_scheduler_interleaves_two_queries_fairly() {
        // One worker, two equal phases of 4 fixed chunks each: pulls must
        // strictly alternate between the queries, not drain one first.
        let s = MultiScheduler::new(1, 4);
        let (a, _) = s.admit();
        let (b, _) = s.admit();
        s.submit(a, Policy::FixedChunk(10), 40);
        s.submit(b, Policy::FixedChunk(10), 40);
        let mut order = Vec::new();
        for _ in 0..8 {
            let (q, c) = s.next_chunk(0).expect("work remains");
            order.push(q);
            s.report(q, 0, c, Duration::from_micros(1));
        }
        assert_eq!(order, vec![a, b, a, b, a, b, a, b], "{order:?}");
        s.wait_done(a);
        s.wait_done(b);
        s.release(a);
        s.release(b);
        assert_eq!(s.phases_peak(), 2);
        s.shutdown();
        assert!(s.next_chunk(0).is_none());
    }

    #[test]
    fn multi_scheduler_admission_is_bounded_fifo() {
        use std::sync::mpsc;
        let s = std::sync::Arc::new(MultiScheduler::new(2, 2));
        let (a, wa) = s.admit();
        let (b, wb) = s.admit();
        assert!(!wa && !wb, "slots were free: no queueing");
        // A third arrival must block until a slot is released.
        let (tx, rx) = mpsc::channel();
        let s2 = s.clone();
        let t = std::thread::spawn(move || {
            let (c, waited) = s2.admit();
            tx.send((c, waited)).unwrap();
            s2.release(c);
        });
        std::thread::sleep(Duration::from_millis(50));
        assert!(
            rx.try_recv().is_err(),
            "third query admitted past the in-flight bound"
        );
        s.release(a);
        let (c, waited) = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(waited, "the overflowed query must report it queued");
        assert!(c > b);
        t.join().unwrap();
        s.release(b);
        assert!(s.queued_peak() >= 1);
    }

    #[test]
    fn multi_scheduler_covers_every_query_exactly_once_under_concurrency() {
        let workers = 4;
        let s = MultiScheduler::new(workers, 8);
        let sizes = [1000usize, 500, 2000];
        let seen: Vec<Mutex<Vec<bool>>> = sizes
            .iter()
            .map(|&n| Mutex::new(vec![false; n]))
            .collect();
        let (s, seen) = (&s, &seen);
        std::thread::scope(|scope| {
            for w in 0..workers {
                scope.spawn(move || {
                    while let Some((q, c)) = s.next_chunk(w) {
                        let mut bits = seen[q as usize].lock().unwrap();
                        for i in c.lo..c.hi {
                            assert!(!bits[i], "query {q} iteration {i} issued twice");
                            bits[i] = true;
                        }
                        drop(bits);
                        s.report(q, w, c, Duration::from_micros(c.len() as u64));
                    }
                });
            }
            for (q, &n) in sizes.iter().enumerate() {
                let (id, _) = s.admit();
                assert_eq!(id, q as u64);
                s.submit(id, Policy::Gss, n);
            }
            for q in 0..sizes.len() as u64 {
                s.wait_done(q);
                s.release(q);
            }
            s.shutdown();
        });
        for (q, bits) in seen.iter().enumerate() {
            assert!(
                bits.lock().unwrap().iter().all(|&b| b),
                "query {q}: some iteration never issued"
            );
        }
    }

    #[test]
    fn multi_scheduler_empty_space_completes_immediately() {
        let s = MultiScheduler::new(2, 2);
        let (q, _) = s.admit();
        s.submit(q, Policy::Gss, 0);
        s.wait_done(q); // must not hang: no worker is polling
        s.release(q);
    }
}
