//! Statements and loop nodes of the single intermediate representation.

use std::fmt;

use super::expr::Expr;
use super::index_set::IndexSet;
use super::value::Tuple;

/// Loop flavours (§II–III).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoopKind {
    /// `forelem` — inherently parallel iteration over an index set.
    Forelem,
    /// `for` — sequential iteration (over a range or value set).
    For,
    /// `forall` — explicitly parallelized iteration: the unit the
    /// loop scheduler distributes over processors.
    Forall,
}

impl fmt::Display for LoopKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LoopKind::Forelem => write!(f, "forelem"),
            LoopKind::For => write!(f, "for"),
            LoopKind::Forall => write!(f, "forall"),
        }
    }
}

/// What a loop iterates over.
#[derive(Debug, Clone, PartialEq)]
pub enum Domain {
    /// `i ∈ pA...` — tuples selected by an index set.
    IndexSet(IndexSet),
    /// `k = lo..=hi` — integer range (the `forall (k = 1; k <= N; k++)`
    /// of the paper's parallelized loops).
    Range { lo: Expr, hi: Expr },
    /// `l ∈ X_k` — the k-th segment of a partitioning of the value range
    /// of `relation.field` into `parts` segments (indirect partitioning,
    /// §III-A1). `part` is usually the enclosing `forall` variable.
    ValuePartition {
        relation: String,
        field: String,
        part: Expr,
        parts: Expr,
    },
    /// `v ∈ distinct(relation.field)` — all distinct values of a field.
    DistinctValues { relation: String, field: String },
}

/// How an ordered/bounded emission executes — decided late by the
/// cost-based optimizer (`opt::optimize`), exactly like
/// [`Strategy`](super::index_set::Strategy) on index sets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum TopKStrategy {
    /// Not yet decided (the state SQL lowering leaves emit loops in).
    /// Executors treat a bounded, undecided emission as [`Heap`].
    #[default]
    Unspecified,
    /// Bounded-heap emission, O(n log k): only the current top `k` rows
    /// are retained (the vectorized tier's `vec.topk` kernel).
    Heap,
    /// Materialize every emitted row, sort, then truncate — chosen when
    /// there is no `LIMIT`, or when `k` covers the whole domain anyway.
    Sort,
}

impl fmt::Display for TopKStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            TopKStrategy::Unspecified => "?",
            TopKStrategy::Heap => "heap",
            TopKStrategy::Sort => "sort",
        };
        write!(f, "{s}")
    }
}

/// Ordered/bounded emission: the IR form of `ORDER BY` / `LIMIT` (§IV).
///
/// The IR is order-free — multisets have no row order — so ordering is
/// not a property of data but of *emission*: a loop annotated with an
/// `EmitOrder` appends its result rows sorted by tuple position
/// [`key`](EmitOrder::key) (and/or bounded to the first
/// [`limit`](EmitOrder::limit) rows). SQL lowering produces it for
/// `ORDER BY`/`LIMIT`; the reference semantics are
/// [`apply_rows`](EmitOrder::apply_rows) (stable sort, then truncate) and
/// every execution tier — including the `vec.topk` bounded-heap kernel —
/// must emit the exact same rows in the exact same order.
///
/// # Examples
///
/// ```
/// use forelem::ir::{EmitOrder, Value};
///
/// // ORDER BY column #1 DESC LIMIT 2 over (name, count) tuples.
/// let emit = EmitOrder::top_k(1, true, 2);
/// let mut rows = vec![
///     vec![Value::str("/a"), Value::Int(3)],
///     vec![Value::str("/b"), Value::Int(9)],
///     vec![Value::str("/c"), Value::Int(5)],
/// ];
/// emit.apply_rows(&mut rows);
/// assert_eq!(rows.len(), 2);
/// assert_eq!(rows[0][1], Value::Int(9));
/// assert_eq!(rows[1][1], Value::Int(5));
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct EmitOrder {
    /// Position within the emitted result tuple to sort by; `None` means
    /// "no ordering" (a bare `LIMIT`, which keeps the first rows in
    /// emission order).
    pub key: Option<usize>,
    /// Sort descending (`ORDER BY ... DESC`).
    pub descending: bool,
    /// Keep only the top `limit` rows; `None` means emit everything
    /// (a bare `ORDER BY`).
    pub limit: Option<usize>,
    /// Heap-vs-sort execution choice, decided by the optimizer
    /// (`opt.topk_heap` / `opt.topk_sort`).
    pub strategy: TopKStrategy,
}

impl EmitOrder {
    /// `ORDER BY #key [DESC] LIMIT k`.
    pub fn top_k(key: usize, descending: bool, k: usize) -> Self {
        EmitOrder {
            key: Some(key),
            descending,
            limit: Some(k),
            strategy: TopKStrategy::Unspecified,
        }
    }

    /// `ORDER BY #key [DESC]` without a bound.
    pub fn ordered(key: usize, descending: bool) -> Self {
        EmitOrder {
            key: Some(key),
            descending,
            limit: None,
            strategy: TopKStrategy::Unspecified,
        }
    }

    /// Bare `LIMIT k`: the first `k` rows in emission order.
    pub fn first_k(k: usize) -> Self {
        EmitOrder {
            key: None,
            descending: false,
            limit: Some(k),
            strategy: TopKStrategy::Unspecified,
        }
    }

    /// Comparison the emission contract sorts by: the key column
    /// (respecting direction); ties keep emission order (stable).
    pub fn cmp_rows(&self, a: &Tuple, b: &Tuple) -> std::cmp::Ordering {
        match self.key {
            Some(f) => {
                let ord = a[f].cmp(&b[f]);
                if self.descending {
                    ord.reverse()
                } else {
                    ord
                }
            }
            None => std::cmp::Ordering::Equal,
        }
    }

    /// The reference semantics: stable-sort `rows` by the key (when one
    /// is set) and truncate to `limit`. Every tier's emission — including
    /// the bounded-heap `vec.topk` kernel and the parallel k-way merge —
    /// must equal this exactly, ties included.
    pub fn apply_rows(&self, rows: &mut Vec<Tuple>) {
        if self.key.is_some() {
            rows.sort_by(|a, b| self.cmp_rows(a, b));
        }
        if let Some(k) = self.limit {
            rows.truncate(k);
        }
    }
}

impl fmt::Display for EmitOrder {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "topk(")?;
        let mut sep = "";
        if let Some(k) = self.key {
            write!(f, "#{k} {}", if self.descending { "desc" } else { "asc" })?;
            sep = ", ";
        }
        if let Some(k) = self.limit {
            write!(f, "{sep}k={k}")?;
        }
        write!(f, ")")?;
        if self.strategy != TopKStrategy::Unspecified {
            write!(f, " /*{}*/", self.strategy)?;
        }
        Ok(())
    }
}

/// A loop node.
#[derive(Debug, Clone, PartialEq)]
pub struct Loop {
    pub kind: LoopKind,
    pub var: String,
    pub domain: Domain,
    pub body: Vec<Stmt>,
    /// Ordered/bounded emission contract for the result rows this loop
    /// appends (the IR form of `ORDER BY`/`LIMIT`). `None` for ordinary
    /// loops.
    pub emit: Option<EmitOrder>,
}

impl Loop {
    pub fn forelem(var: &str, ix: IndexSet, body: Vec<Stmt>) -> Self {
        Loop {
            kind: LoopKind::Forelem,
            var: var.to_string(),
            domain: Domain::IndexSet(ix),
            body,
            emit: None,
        }
    }

    pub fn forall_range(var: &str, lo: Expr, hi: Expr, body: Vec<Stmt>) -> Self {
        Loop {
            kind: LoopKind::Forall,
            var: var.to_string(),
            domain: Domain::Range { lo, hi },
            body,
            emit: None,
        }
    }

    pub fn for_range(var: &str, lo: Expr, hi: Expr, body: Vec<Stmt>) -> Self {
        Loop {
            kind: LoopKind::For,
            var: var.to_string(),
            domain: Domain::Range { lo, hi },
            body,
            emit: None,
        }
    }

    /// Attach an ordered/bounded emission contract.
    pub fn with_emit(mut self, emit: EmitOrder) -> Self {
        self.emit = Some(emit);
        self
    }

    /// The index set, if this is a forelem-style loop.
    pub fn index_set(&self) -> Option<&IndexSet> {
        match &self.domain {
            Domain::IndexSet(ix) => Some(ix),
            _ => None,
        }
    }

    pub fn index_set_mut(&mut self) -> Option<&mut IndexSet> {
        match &mut self.domain {
            Domain::IndexSet(ix) => Some(ix),
            _ => None,
        }
    }
}

/// Accumulation operators (`count[x]++`, `sum[x] += v`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccumOp {
    /// `+= value`
    Add,
    /// `= value` (plain store)
    Set,
    /// `= max(old, value)`
    Max,
    /// `= min(old, value)`
    Min,
}

impl fmt::Display for AccumOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AccumOp::Add => write!(f, "+="),
            AccumOp::Set => write!(f, "="),
            AccumOp::Max => write!(f, "max="),
            AccumOp::Min => write!(f, "min="),
        }
    }
}

/// A statement.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// A (possibly nested) loop.
    Loop(Loop),
    /// `array[i0][i1] op value` — accumulator update.
    Accum {
        array: String,
        indices: Vec<Expr>,
        op: AccumOp,
        value: Expr,
    },
    /// `R = R ∪ (e0, e1, ...)` — append a tuple to a result multiset.
    ResultUnion { result: String, tuple: Vec<Expr> },
    /// `var = expr` — scalar assignment.
    Assign { var: String, value: Expr },
    /// Conditional.
    If {
        cond: Expr,
        then: Vec<Stmt>,
        els: Vec<Stmt>,
    },
    /// Diagnostic output (the paper's `print` in §III-B).
    Print { format: String, args: Vec<Expr> },
}

impl Stmt {
    pub fn accum(array: &str, indices: Vec<Expr>, op: AccumOp, value: Expr) -> Stmt {
        Stmt::Accum {
            array: array.to_string(),
            indices,
            op,
            value,
        }
    }

    /// `count[indices]++`
    pub fn increment(array: &str, indices: Vec<Expr>) -> Stmt {
        Stmt::accum(array, indices, AccumOp::Add, Expr::int(1))
    }

    pub fn result_union(result: &str, tuple: Vec<Expr>) -> Stmt {
        Stmt::ResultUnion {
            result: result.to_string(),
            tuple,
        }
    }

    pub fn assign(var: &str, value: Expr) -> Stmt {
        Stmt::Assign {
            var: var.to_string(),
            value,
        }
    }

    /// Visit this statement and all nested statements (pre-order).
    pub fn walk(&self, f: &mut impl FnMut(&Stmt)) {
        f(self);
        match self {
            Stmt::Loop(l) => {
                for s in &l.body {
                    s.walk(f);
                }
            }
            Stmt::If { then, els, .. } => {
                for s in then {
                    s.walk(f);
                }
                for s in els {
                    s.walk(f);
                }
            }
            _ => {}
        }
    }

    /// Visit every expression in this statement tree.
    pub fn walk_exprs(&self, f: &mut impl FnMut(&Expr)) {
        self.walk(&mut |s| match s {
            Stmt::Loop(l) => match &l.domain {
                Domain::IndexSet(ix) => {
                    if let Some((_, v)) = &ix.field_filter {
                        v.walk(f);
                    }
                    if let Some(p) = &ix.partition {
                        p.part.walk(f);
                        p.parts.walk(f);
                    }
                }
                Domain::Range { lo, hi } => {
                    lo.walk(f);
                    hi.walk(f);
                }
                Domain::ValuePartition { part, parts, .. } => {
                    part.walk(f);
                    parts.walk(f);
                }
                Domain::DistinctValues { .. } => {}
            },
            Stmt::Accum { indices, value, .. } => {
                for i in indices {
                    i.walk(f);
                }
                value.walk(f);
            }
            Stmt::ResultUnion { tuple, .. } => {
                for e in tuple {
                    e.walk(f);
                }
            }
            Stmt::Assign { value, .. } => value.walk(f),
            Stmt::If { cond, .. } => cond.walk(f),
            Stmt::Print { args, .. } => {
                for a in args {
                    a.walk(f);
                }
            }
        });
    }

    /// Mutate every expression in this statement tree (post-order).
    pub fn walk_exprs_mut(&mut self, f: &mut impl FnMut(&mut Expr)) {
        match self {
            Stmt::Loop(l) => {
                match &mut l.domain {
                    Domain::IndexSet(ix) => {
                        if let Some((_, v)) = &mut ix.field_filter {
                            v.walk_mut(f);
                        }
                        if let Some(p) = &mut ix.partition {
                            p.part.walk_mut(f);
                            p.parts.walk_mut(f);
                        }
                    }
                    Domain::Range { lo, hi } => {
                        lo.walk_mut(f);
                        hi.walk_mut(f);
                    }
                    Domain::ValuePartition { part, parts, .. } => {
                        part.walk_mut(f);
                        parts.walk_mut(f);
                    }
                    Domain::DistinctValues { .. } => {}
                }
                for s in &mut l.body {
                    s.walk_exprs_mut(f);
                }
            }
            Stmt::Accum { indices, value, .. } => {
                for i in indices {
                    i.walk_mut(f);
                }
                value.walk_mut(f);
            }
            Stmt::ResultUnion { tuple, .. } => {
                for e in tuple {
                    e.walk_mut(f);
                }
            }
            Stmt::Assign { value, .. } => value.walk_mut(f),
            Stmt::If { cond, then, els } => {
                cond.walk_mut(f);
                for s in then {
                    s.walk_exprs_mut(f);
                }
                for s in els {
                    s.walk_exprs_mut(f);
                }
            }
            Stmt::Print { args, .. } => {
                for a in args {
                    a.walk_mut(f);
                }
            }
        }
    }

    /// Rename a variable throughout the statement tree.
    pub fn rename_var(&mut self, from: &str, to: &str) {
        // Loop variables that shadow `from` are left alone only if they bind
        // the same name; transformations in this codebase always generate
        // fresh names, so plain substitution is sound here.
        self.walk_exprs_mut(&mut |e| e.rename_var(from, to));
        if let Stmt::Loop(l) = self {
            if l.var == from {
                l.var = to.to_string();
            }
            for s in &mut l.body {
                s.rename_var(from, to);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count_loop() -> Stmt {
        // forelem (i; i ∈ pAccess) count[i.url]++
        Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("Access"),
            vec![Stmt::increment("count", vec![Expr::field("i", "url")])],
        ))
    }

    #[test]
    fn walk_visits_nested() {
        let s = count_loop();
        let mut n = 0;
        s.walk(&mut |_| n += 1);
        assert_eq!(n, 2); // the loop + the accum
    }

    #[test]
    fn walk_exprs_sees_subscripts() {
        let s = count_loop();
        let mut fields = Vec::new();
        s.walk_exprs(&mut |e| {
            if let Expr::Field { field, .. } = e {
                fields.push(field.clone());
            }
        });
        assert_eq!(fields, vec!["url".to_string()]);
    }

    #[test]
    fn emit_order_apply_matches_stable_sort_semantics() {
        use super::super::value::Value;
        // Descending by #1, ties (9) keep emission order: "/b" before "/d".
        let rows = vec![
            vec![Value::str("/a"), Value::Int(3)],
            vec![Value::str("/b"), Value::Int(9)],
            vec![Value::str("/c"), Value::Int(5)],
            vec![Value::str("/d"), Value::Int(9)],
        ];
        let mut top3 = rows.clone();
        EmitOrder::top_k(1, true, 3).apply_rows(&mut top3);
        assert_eq!(top3.len(), 3);
        assert_eq!(top3[0][0], Value::str("/b"));
        assert_eq!(top3[1][0], Value::str("/d"));
        assert_eq!(top3[2][0], Value::str("/c"));
        // Bare LIMIT keeps the first rows in emission order.
        let mut first2 = rows.clone();
        EmitOrder::first_k(2).apply_rows(&mut first2);
        assert_eq!(first2, rows[..2].to_vec());
        // Bare ORDER BY sorts everything, ascending.
        let mut all = rows.clone();
        EmitOrder::ordered(1, false).apply_rows(&mut all);
        assert_eq!(all.len(), 4);
        assert_eq!(all[0][1], Value::Int(3));
    }

    #[test]
    fn emit_order_display_forms() {
        assert_eq!(EmitOrder::top_k(1, true, 5).to_string(), "topk(#1 desc, k=5)");
        assert_eq!(EmitOrder::ordered(0, false).to_string(), "topk(#0 asc)");
        assert_eq!(EmitOrder::first_k(7).to_string(), "topk(k=7)");
        let mut e = EmitOrder::top_k(1, true, 5);
        e.strategy = TopKStrategy::Heap;
        assert_eq!(e.to_string(), "topk(#1 desc, k=5) /*heap*/");
    }

    #[test]
    fn rename_var_recurses_into_loops() {
        let mut s = count_loop();
        s.rename_var("i", "j");
        if let Stmt::Loop(l) = &s {
            assert_eq!(l.var, "j");
            if let Stmt::Accum { indices, .. } = &l.body[0] {
                assert_eq!(indices[0], Expr::field("j", "url"));
            } else {
                panic!("expected accum");
            }
        } else {
            panic!("expected loop");
        }
    }
}
