//! In-memory multisets of tuples — the universal data container of the IR.
//!
//! This is the *logical* container used by the compiler, the interpreter
//! and the tests. Physical layouts (row files, column stores, compressed
//! columns, dictionaries) live in `crate::storage` and are chosen by the
//! code-generation stage (§III-C1); they all convert to/from this form.

use std::collections::HashSet;

use super::schema::{FieldId, Schema};
use super::value::{Tuple, Value};

/// A multiset of tuples with a schema.
#[derive(Debug, Clone, Default)]
pub struct Multiset {
    pub schema: Schema,
    rows: Vec<Tuple>,
}

impl Multiset {
    pub fn new(schema: Schema) -> Self {
        Multiset {
            schema,
            rows: Vec::new(),
        }
    }

    pub fn with_rows(schema: Schema, rows: Vec<Tuple>) -> Self {
        debug_assert!(rows.iter().all(|r| r.len() == schema.len()));
        Multiset { schema, rows }
    }

    pub fn push(&mut self, tuple: Tuple) {
        debug_assert_eq!(tuple.len(), self.schema.len());
        self.rows.push(tuple);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn rows(&self) -> &[Tuple] {
        &self.rows
    }

    pub fn rows_mut(&mut self) -> &mut Vec<Tuple> {
        &mut self.rows
    }

    pub fn into_rows(self) -> Vec<Tuple> {
        self.rows
    }

    pub fn get(&self, row: usize, field: FieldId) -> &Value {
        &self.rows[row][field]
    }

    /// All distinct values of one field (the paper's `pA.distinct(field)`).
    pub fn distinct(&self, field: FieldId) -> Vec<Value> {
        let mut seen = HashSet::new();
        let mut out = Vec::new();
        for r in &self.rows {
            if seen.insert(r[field].clone()) {
                out.push(r[field].clone());
            }
        }
        out
    }

    /// The multiset of values of one field (the paper's `A.field` notation,
    /// used by indirect partitioning §III-A1).
    pub fn field_values(&self, field: FieldId) -> Vec<Value> {
        self.rows.iter().map(|r| r[field].clone()).collect()
    }

    /// Projection onto a subset of fields (dead-field elimination).
    pub fn project(&self, keep: &[FieldId]) -> Multiset {
        Multiset {
            schema: self.schema.project(keep),
            rows: self
                .rows
                .iter()
                .map(|r| keep.iter().map(|&i| r[i].clone()).collect())
                .collect(),
        }
    }

    /// Multiset equality up to row order (bag semantics) — used by tests to
    /// check that transformed programs compute the same result.
    pub fn bag_eq(&self, other: &Multiset) -> bool {
        if self.schema != other.schema || self.len() != other.len() {
            return false;
        }
        let mut a: Vec<&Tuple> = self.rows.iter().collect();
        let mut b: Vec<&Tuple> = other.rows.iter().collect();
        a.sort();
        b.sort();
        a == b
    }

    pub fn iter(&self) -> std::slice::Iter<'_, Tuple> {
        self.rows.iter()
    }
}

impl<'a> IntoIterator for &'a Multiset {
    type Item = &'a Tuple;
    type IntoIter = std::slice::Iter<'a, Tuple>;
    fn into_iter(self) -> Self::IntoIter {
        self.rows.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::value::DataType;

    fn sample() -> Multiset {
        let schema = Schema::new(vec![("url", DataType::Str), ("n", DataType::Int)]);
        Multiset::with_rows(
            schema,
            vec![
                vec![Value::str("a"), Value::Int(1)],
                vec![Value::str("b"), Value::Int(2)],
                vec![Value::str("a"), Value::Int(3)],
            ],
        )
    }

    #[test]
    fn distinct_preserves_first_seen_order() {
        let m = sample();
        assert_eq!(m.distinct(0), vec![Value::str("a"), Value::str("b")]);
    }

    #[test]
    fn field_values_is_a_multiset() {
        let m = sample();
        assert_eq!(m.field_values(0).len(), 3);
    }

    #[test]
    fn bag_equality_ignores_order() {
        let m = sample();
        let mut rev = m.clone();
        rev.rows_mut().reverse();
        assert!(m.bag_eq(&rev));
        let mut other = m.clone();
        other.rows_mut()[0][1] = Value::Int(99);
        assert!(!m.bag_eq(&other));
    }

    #[test]
    fn projection_drops_dead_fields() {
        let m = sample().project(&[1]);
        assert_eq!(m.schema.len(), 1);
        assert_eq!(m.get(2, 0), &Value::Int(3));
    }
}
