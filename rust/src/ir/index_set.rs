//! Index sets: the iteration descriptors of `forelem` loops.
//!
//! The paper's key abstraction (§II): a `forelem` loop iterates a subset
//! of a multiset, and the *index set* (`pA`, `pA.field[v]`,
//! `pA.distinct(field)`) encapsulates how. Early in compilation only the
//! *what* is fixed; the *how* — full scan, hash index, tree index — is a
//! `Strategy` the materialization pass (transform/materialization.rs)
//! decides late, exactly as Figure 1 shows one spec generating both
//! nested-loops and hash-based evaluation code.

use std::fmt;

use super::expr::Expr;

/// How an index set is executed at runtime — decided by the compiler's
/// materialization pass, not by the author of the IR.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Strategy {
    /// Not yet decided (the state SQL lowering leaves loops in).
    #[default]
    Unspecified,
    /// Visit every tuple, testing the filter inline (Figure 1 middle).
    Scan,
    /// Build/use a hash index keyed on the filter field (Figure 1 bottom).
    Hash,
    /// Build/use a sorted (tree) index keyed on the filter field — wins
    /// when range predicates or ordered output are required.
    Tree,
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Strategy::Unspecified => "?",
            Strategy::Scan => "scan",
            Strategy::Hash => "hash",
            Strategy::Tree => "tree",
        };
        write!(f, "{s}")
    }
}

/// A partition tag attached by the data-partitioning transformations
/// (§III-A1): after loop blocking, `pA` becomes `p_k A`.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    /// Expression selecting the partition (usually the `forall` variable).
    pub part: Expr,
    /// Total number of partitions (usually the parameter `N`).
    pub parts: Expr,
}

/// An index set.
#[derive(Debug, Clone, PartialEq)]
pub struct IndexSet {
    /// The multiset being iterated (the paper writes `pA` for multiset `A`).
    pub relation: String,
    /// `pA.field[v]`: restrict to tuples whose `field` equals `v`.
    pub field_filter: Option<(String, Expr)>,
    /// `pA.distinct(field)`: iterate one representative tuple per distinct
    /// value of `field`.
    pub distinct: Option<String>,
    /// Direct data partitioning (`p_k A`), if applied.
    pub partition: Option<Partition>,
    /// Execution strategy (chosen late).
    pub strategy: Strategy,
}

impl IndexSet {
    /// `pA` — the whole multiset.
    pub fn all(relation: &str) -> Self {
        IndexSet {
            relation: relation.to_string(),
            field_filter: None,
            distinct: None,
            partition: None,
            strategy: Strategy::Unspecified,
        }
    }

    /// `pA.field[value]`.
    pub fn filtered(relation: &str, field: &str, value: Expr) -> Self {
        IndexSet {
            field_filter: Some((field.to_string(), value)),
            ..IndexSet::all(relation)
        }
    }

    /// `pA.distinct(field)`.
    pub fn distinct_of(relation: &str, field: &str) -> Self {
        IndexSet {
            distinct: Some(field.to_string()),
            ..IndexSet::all(relation)
        }
    }

    pub fn with_strategy(mut self, s: Strategy) -> Self {
        self.strategy = s;
        self
    }

    pub fn with_partition(mut self, part: Expr, parts: Expr) -> Self {
        self.partition = Some(Partition { part, parts });
        self
    }

    /// The field this index set would be keyed on, if an index structure is
    /// built (the filter field).
    pub fn key_field(&self) -> Option<&str> {
        self.field_filter.as_ref().map(|(f, _)| f.as_str())
    }
}

impl fmt::Display for IndexSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "p")?;
        if let Some(p) = &self.partition {
            write!(f, "_{}", p.part)?;
        }
        write!(f, "{}", self.relation)?;
        if let Some((field, v)) = &self.field_filter {
            write!(f, ".{field}[{v}]")?;
        }
        if let Some(d) = &self.distinct {
            write!(f, ".distinct({d})")?;
        }
        if self.strategy != Strategy::Unspecified {
            write!(f, " /*{}*/", self.strategy)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_forms() {
        assert_eq!(IndexSet::all("A").to_string(), "pA");
        assert_eq!(
            IndexSet::filtered("B", "id", Expr::field("i", "b_id")).to_string(),
            "pB.id[i.b_id]"
        );
        assert_eq!(
            IndexSet::distinct_of("Access", "url").to_string(),
            "pAccess.distinct(url)"
        );
        assert_eq!(
            IndexSet::all("A")
                .with_partition(Expr::var("k"), Expr::var("N"))
                .to_string(),
            "p_kA"
        );
        assert_eq!(
            IndexSet::all("A").with_strategy(Strategy::Hash).to_string(),
            "pA /*hash*/"
        );
    }

    #[test]
    fn key_field() {
        let ix = IndexSet::filtered("B", "id", Expr::int(1));
        assert_eq!(ix.key_field(), Some("id"));
        assert_eq!(IndexSet::all("B").key_field(), None);
    }
}
