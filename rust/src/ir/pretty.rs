//! Pretty printer producing the paper's forelem syntax.
//!
//! Used by the CLI (`forelem compile --emit ir`), by documentation
//! examples, and by golden tests that pin the shape of transformed
//! programs (e.g. that parallelization produced the §IV code).

use std::fmt::Write;

use super::program::Program;
use super::stmt::{Domain, Stmt};

/// Render a whole program.
pub fn program(p: &Program) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "// program {}", p.name);
    for (name, schema) in &p.relations {
        let _ = writeln!(out, "// multiset {name}: {schema}");
    }
    for (name, v) in &p.params {
        let _ = writeln!(out, "// param {name} = {v}");
    }
    for s in &p.body {
        stmt(s, 0, &mut out);
    }
    out
}

/// Render a single statement at an indent level.
pub fn stmt(s: &Stmt, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    match s {
        Stmt::Loop(l) => {
            let header = match (&l.kind, &l.domain) {
                (k, Domain::IndexSet(ix)) => format!("{k} ({}; {} ∈ {ix})", l.var, l.var),
                (k, Domain::Range { lo, hi }) => {
                    format!("{k} ({} = {lo}; {} <= {hi}; {}++)", l.var, l.var, l.var)
                }
                (k, Domain::ValuePartition {
                    relation,
                    field,
                    part,
                    ..
                }) => format!("{k} ({} ∈ X_{part})  // X = {relation}.{field}", l.var),
                (k, Domain::DistinctValues { relation, field }) => {
                    format!("{k} ({} ∈ distinct({relation}.{field}))", l.var)
                }
            };
            let header = match &l.emit {
                Some(e) => format!("{header} {e}"),
                None => header,
            };
            let _ = writeln!(out, "{pad}{header} {{");
            for b in &l.body {
                stmt(b, indent + 1, out);
            }
            let _ = writeln!(out, "{pad}}}");
        }
        Stmt::Accum {
            array,
            indices,
            op,
            value,
        } => {
            let subs: String = indices.iter().map(|i| format!("[{i}]")).collect();
            // Render `x += 1` as the paper's `x++`.
            if matches!(op, super::stmt::AccumOp::Add)
                && matches!(value, super::expr::Expr::Const(super::value::Value::Int(1)))
            {
                let _ = writeln!(out, "{pad}{array}{subs}++;");
            } else {
                let _ = writeln!(out, "{pad}{array}{subs} {op} {value};");
            }
        }
        Stmt::ResultUnion { result, tuple } => {
            let items: Vec<String> = tuple.iter().map(|e| e.to_string()).collect();
            let _ = writeln!(out, "{pad}{result} = {result} ∪ ({});", items.join(", "));
        }
        Stmt::Assign { var, value } => {
            let _ = writeln!(out, "{pad}{var} = {value};");
        }
        Stmt::If { cond, then, els } => {
            let _ = writeln!(out, "{pad}if ({cond}) {{");
            for b in then {
                stmt(b, indent + 1, out);
            }
            if !els.is_empty() {
                let _ = writeln!(out, "{pad}}} else {{");
                for b in els {
                    stmt(b, indent + 1, out);
                }
            }
            let _ = writeln!(out, "{pad}}}");
        }
        Stmt::Print { format, args } => {
            let items: Vec<String> = args.iter().map(|e| e.to_string()).collect();
            let _ = writeln!(out, "{pad}print(\"{format}\", {});", items.join(", "));
        }
    }
}

/// Convenience: render one statement to a fresh string.
pub fn stmt_string(s: &Stmt) -> String {
    let mut out = String::new();
    stmt(s, 0, &mut out);
    out
}

#[allow(unused_imports)]
use super::{expr, value};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;
    use crate::ir::index_set::IndexSet;
    use crate::ir::stmt::Loop;

    #[test]
    fn renders_paper_syntax() {
        // The §IV URL-count first loop.
        let s = Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("Access"),
            vec![Stmt::increment("count", vec![Expr::field("i", "url")])],
        ));
        let text = stmt_string(&s);
        assert!(text.contains("forelem (i; i ∈ pAccess) {"), "{text}");
        assert!(text.contains("count[i.url]++;"), "{text}");
    }

    #[test]
    fn renders_result_union() {
        let s = Stmt::result_union(
            "R",
            vec![Expr::field("i", "url"), Expr::array("count", vec![Expr::field("i", "url")])],
        );
        assert_eq!(stmt_string(&s).trim(), "R = R ∪ (i.url, count[i.url]);");
    }

    #[test]
    fn renders_topk_emit_annotation() {
        use crate::ir::stmt::EmitOrder;
        let s = Stmt::Loop(
            Loop::forelem(
                "i",
                IndexSet::distinct_of("Access", "url"),
                vec![Stmt::result_union("R", vec![Expr::field("i", "url")])],
            )
            .with_emit(EmitOrder::top_k(1, true, 5)),
        );
        let text = stmt_string(&s);
        assert!(
            text.contains("forelem (i; i ∈ pAccess.distinct(url)) topk(#1 desc, k=5) {"),
            "{text}"
        );
    }

    #[test]
    fn renders_forall_range() {
        let s = Stmt::Loop(Loop::forall_range("k", Expr::int(1), Expr::var("N"), vec![]));
        assert!(stmt_string(&s).contains("forall (k = 1; k <= N; k++) {"));
    }
}
