//! Scalar values and data types of the single intermediate representation.
//!
//! The paper's data model is "(multi)sets of tuples" (§II); tuples are
//! vectors of these scalar values. `Str` values are reference-counted so
//! that tuple copies during joins/shuffles do not reallocate string data —
//! the *dictionary-encoded* path (§III-C1) replaces them with `Int` keys
//! entirely, which is what the Figure-2 "integer keyed" variants measure.

use std::cmp::Ordering;
use std::fmt;
use std::sync::Arc;

/// The scalar types a tuple field can take.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataType {
    /// 64-bit signed integer (also used for dictionary keys).
    Int,
    /// 64-bit float.
    Float,
    /// Immutable UTF-8 string.
    Str,
    /// Boolean.
    Bool,
}

impl fmt::Display for DataType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DataType::Int => write!(f, "int"),
            DataType::Float => write!(f, "float"),
            DataType::Str => write!(f, "str"),
            DataType::Bool => write!(f, "bool"),
        }
    }
}

/// A runtime scalar value.
#[derive(Debug, Clone)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(Arc<str>),
    Bool(bool),
    /// Absent value (e.g. aggregate over an empty group).
    Null,
}

impl Value {
    pub fn str(s: impl Into<Arc<str>>) -> Self {
        Value::Str(s.into())
    }

    pub fn data_type(&self) -> Option<DataType> {
        match self {
            Value::Int(_) => Some(DataType::Int),
            Value::Float(_) => Some(DataType::Float),
            Value::Str(_) => Some(DataType::Str),
            Value::Bool(_) => Some(DataType::Bool),
            Value::Null => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Truthiness used by filter conditions.
    pub fn truthy(&self) -> bool {
        match self {
            Value::Bool(b) => *b,
            Value::Int(i) => *i != 0,
            Value::Float(f) => *f != 0.0,
            Value::Str(s) => !s.is_empty(),
            Value::Null => false,
        }
    }
}

impl PartialEq for Value {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Value::Int(a), Value::Int(b)) => a == b,
            (Value::Float(a), Value::Float(b)) => a == b,
            (Value::Int(a), Value::Float(b)) | (Value::Float(b), Value::Int(a)) => {
                (*a as f64) == *b
            }
            (Value::Str(a), Value::Str(b)) => a == b,
            (Value::Bool(a), Value::Bool(b)) => a == b,
            (Value::Null, Value::Null) => true,
            _ => false,
        }
    }
}

impl Eq for Value {}

impl std::hash::Hash for Value {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        match self {
            // Int and Float that compare equal must hash equal: hash the
            // f64 bit pattern of the numeric value for both.
            Value::Int(i) => (*i as f64).to_bits().hash(state),
            Value::Float(f) => f.to_bits().hash(state),
            Value::Str(s) => s.hash(state),
            Value::Bool(b) => b.hash(state),
            Value::Null => 0u8.hash(state),
        }
    }
}

impl PartialOrd for Value {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Value {
    fn cmp(&self, other: &Self) -> Ordering {
        use Value::*;
        match (self, other) {
            (Int(a), Int(b)) => a.cmp(b),
            (Float(a), Float(b)) => a.partial_cmp(b).unwrap_or(Ordering::Equal),
            (Int(a), Float(b)) => (*a as f64).partial_cmp(b).unwrap_or(Ordering::Equal),
            (Float(a), Int(b)) => a.partial_cmp(&(*b as f64)).unwrap_or(Ordering::Equal),
            (Str(a), Str(b)) => a.cmp(b),
            (Bool(a), Bool(b)) => a.cmp(b),
            (Null, Null) => Ordering::Equal,
            // Heterogeneous orderings are stable but arbitrary: by type rank.
            _ => self.type_rank().cmp(&other.type_rank()),
        }
    }
}

impl Value {
    fn type_rank(&self) -> u8 {
        match self {
            Value::Null => 0,
            Value::Bool(_) => 1,
            Value::Int(_) => 2,
            Value::Float(_) => 2, // numeric tower shares a rank
            Value::Str(_) => 3,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Str(s) => write!(f, "{s}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Null => write!(f, "NULL"),
        }
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Float(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::str(v)
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::str(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

/// A tuple: one element of a multiset.
pub type Tuple = Vec<Value>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};

    fn hash_of(v: &Value) -> u64 {
        let mut h = DefaultHasher::new();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn numeric_cross_type_equality() {
        assert_eq!(Value::Int(3), Value::Float(3.0));
        assert_ne!(Value::Int(3), Value::Float(3.5));
        assert_eq!(hash_of(&Value::Int(3)), hash_of(&Value::Float(3.0)));
    }

    #[test]
    fn ordering_within_types() {
        assert!(Value::Int(1) < Value::Int(2));
        assert!(Value::str("a") < Value::str("b"));
        assert!(Value::Float(1.5) < Value::Int(2));
    }

    #[test]
    fn truthiness() {
        assert!(Value::Int(1).truthy());
        assert!(!Value::Int(0).truthy());
        assert!(!Value::Null.truthy());
        assert!(Value::str("x").truthy());
        assert!(!Value::str("").truthy());
    }

    #[test]
    fn conversions() {
        assert_eq!(Value::from(2i64).as_int(), Some(2));
        assert_eq!(Value::from(2.5).as_float(), Some(2.5));
        assert_eq!(Value::Int(2).as_float(), Some(2.0));
        assert_eq!(Value::from("hi").as_str(), Some("hi"));
        assert!(Value::Null.is_null());
    }
}
