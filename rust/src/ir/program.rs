//! Whole-program container: declarations + the statement body.
//!
//! A `Program` is the unit the pass pipeline (transform/) rewrites and the
//! execution engine (exec/) compiles. It owns the declarations of every
//! multiset (relation), accumulator array, result multiset and scalar
//! parameter the body refers to.

use std::collections::BTreeMap;

use super::schema::Schema;
use super::stmt::{Loop, Stmt};
use super::value::{DataType, Value};

/// Declaration of an accumulator array (`count`, `sum`, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayDecl {
    /// Number of subscripts. Parallelization adds a leading partition
    /// dimension (`count` → `count[k][...]`, the paper's `count_k`).
    pub dims: usize,
    /// Element type.
    pub dtype: DataType,
    /// Initial element value (usually 0).
    pub init: Value,
}

impl ArrayDecl {
    pub fn counter() -> Self {
        ArrayDecl {
            dims: 1,
            dtype: DataType::Int,
            init: Value::Int(0),
        }
    }

    pub fn accumulator(dtype: DataType) -> Self {
        ArrayDecl {
            dims: 1,
            dtype,
            init: match dtype {
                DataType::Float => Value::Float(0.0),
                _ => Value::Int(0),
            },
        }
    }
}

/// Slot-resolution metadata: stable integer ids for every named entity a
/// program declares. The vectorized execution tier (`exec::compile`)
/// resolves all string names to these slots once, at compile time, so the
/// per-row hot path performs no string comparison or allocation.
///
/// Slot order is deterministic (the `BTreeMap` iteration order of the
/// declarations), so two compilations of the same program agree on ids —
/// which is what lets `exec::parallel` workers share one compiled program.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SlotMap {
    /// Scalar variables, by declaration order; slot = index.
    pub scalars: Vec<String>,
    /// Accumulator arrays, by declaration order; slot = index.
    pub arrays: Vec<String>,
    /// Result multisets, by declaration order; slot = index.
    pub results: Vec<String>,
}

impl SlotMap {
    pub fn scalar_slot(&self, name: &str) -> Option<usize> {
        self.scalars.iter().position(|n| n == name)
    }

    pub fn array_slot(&self, name: &str) -> Option<usize> {
        self.arrays.iter().position(|n| n == name)
    }

    pub fn result_slot(&self, name: &str) -> Option<usize> {
        self.results.iter().position(|n| n == name)
    }
}

/// A complete program in the single intermediate representation.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub name: String,
    /// Input multisets, by name (`Access`, `Links`, `Grades`, ...).
    pub relations: BTreeMap<String, Schema>,
    /// Accumulator arrays, by name.
    pub arrays: BTreeMap<String, ArrayDecl>,
    /// Result multisets (`R`), by name.
    pub results: BTreeMap<String, Schema>,
    /// Scalar parameters (`N` = number of processors) and their defaults.
    pub params: BTreeMap<String, Value>,
    /// Scalar variables (`avg`), with initial values.
    pub scalars: BTreeMap<String, Value>,
    /// The statement body.
    pub body: Vec<Stmt>,
    /// Dot-namespaced decision tags (`opt.join_build_side`, ...) recorded
    /// by the cost-based optimizer (`crate::opt`) when it rewrote or
    /// annotated this program. Executors merge these into
    /// `ExecStats.idioms` so tests and dashboards can observe which
    /// optimizer decisions shaped a run.
    pub opt_tags: Vec<String>,
}

impl Program {
    pub fn new(name: &str) -> Self {
        Program {
            name: name.to_string(),
            ..Default::default()
        }
    }

    pub fn with_relation(mut self, name: &str, schema: Schema) -> Self {
        self.relations.insert(name.to_string(), schema);
        self
    }

    pub fn with_array(mut self, name: &str, decl: ArrayDecl) -> Self {
        self.arrays.insert(name.to_string(), decl);
        self
    }

    pub fn with_result(mut self, name: &str, schema: Schema) -> Self {
        self.results.insert(name.to_string(), schema);
        self
    }

    pub fn with_param(mut self, name: &str, v: Value) -> Self {
        self.params.insert(name.to_string(), v);
        self
    }

    pub fn with_scalar(mut self, name: &str, init: Value) -> Self {
        self.scalars.insert(name.to_string(), init);
        self
    }

    pub fn with_body(mut self, body: Vec<Stmt>) -> Self {
        self.body = body;
        self
    }

    /// Visit every statement in the program (pre-order, nested included).
    pub fn walk(&self, f: &mut impl FnMut(&Stmt)) {
        for s in &self.body {
            s.walk(f);
        }
    }

    /// All top-level loops (the units data-distribution reasons about).
    pub fn top_loops(&self) -> Vec<&Loop> {
        self.body
            .iter()
            .filter_map(|s| match s {
                Stmt::Loop(l) => Some(l),
                _ => None,
            })
            .collect()
    }

    /// Names of all relations read anywhere in the body.
    pub fn relations_read(&self) -> Vec<String> {
        let mut out = Vec::new();
        self.walk(&mut |s| {
            if let Stmt::Loop(l) = s {
                match &l.domain {
                    super::stmt::Domain::IndexSet(ix) => out.push(ix.relation.clone()),
                    super::stmt::Domain::ValuePartition { relation, .. }
                    | super::stmt::Domain::DistinctValues { relation, .. } => {
                        out.push(relation.clone())
                    }
                    _ => {}
                }
            }
        });
        out.sort();
        out.dedup();
        out
    }

    /// The first ordered/bounded emission contract (`ORDER BY`/`LIMIT`)
    /// in the body, if any — lowered SQL attaches at most one. Callers
    /// that materialize results outside the executors (the distributed
    /// coordinator's aggregate jobs) use this to honour the same
    /// contract on their externally-produced multiset.
    pub fn emit_bound(&self) -> Option<&super::stmt::EmitOrder> {
        fn find(body: &[Stmt]) -> Option<&super::stmt::EmitOrder> {
            for s in body {
                match s {
                    Stmt::Loop(l) => {
                        if let Some(e) = &l.emit {
                            return Some(e);
                        }
                        if let Some(e) = find(&l.body) {
                            return Some(e);
                        }
                    }
                    Stmt::If { then, els, .. } => {
                        if let Some(e) = find(then).or_else(|| find(els)) {
                            return Some(e);
                        }
                    }
                    _ => {}
                }
            }
            None
        }
        find(&self.body)
    }

    /// Slot-resolution metadata for this program's declarations.
    pub fn slot_map(&self) -> SlotMap {
        SlotMap {
            scalars: self.scalars.keys().cloned().collect(),
            arrays: self.arrays.keys().cloned().collect(),
            results: self.results.keys().cloned().collect(),
        }
    }

    /// Fresh variable name not colliding with params/scalars/loop vars.
    pub fn fresh_var(&self, base: &str) -> String {
        let mut used: std::collections::HashSet<String> = self
            .params
            .keys()
            .chain(self.scalars.keys())
            .cloned()
            .collect();
        self.walk(&mut |s| {
            if let Stmt::Loop(l) = s {
                used.insert(l.var.clone());
            }
        });
        if !used.contains(base) {
            return base.to_string();
        }
        for i in 1.. {
            let cand = format!("{base}{i}");
            if !used.contains(&cand) {
                return cand;
            }
        }
        unreachable!()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;
    use crate::ir::index_set::IndexSet;
    use crate::ir::stmt::{Loop, Stmt};

    fn url_count() -> Program {
        Program::new("url_count")
            .with_relation("Access", Schema::new(vec![("url", DataType::Str)]))
            .with_array("count", ArrayDecl::counter())
            .with_result("R", Schema::new(vec![("url", DataType::Str), ("n", DataType::Int)]))
            .with_body(vec![
                Stmt::Loop(Loop::forelem(
                    "i",
                    IndexSet::all("Access"),
                    vec![Stmt::increment("count", vec![Expr::field("i", "url")])],
                )),
                Stmt::Loop(Loop::forelem(
                    "i",
                    IndexSet::distinct_of("Access", "url"),
                    vec![Stmt::result_union(
                        "R",
                        vec![
                            Expr::field("i", "url"),
                            Expr::array("count", vec![Expr::field("i", "url")]),
                        ],
                    )],
                )),
            ])
    }

    #[test]
    fn relations_read_dedups() {
        assert_eq!(url_count().relations_read(), vec!["Access".to_string()]);
    }

    #[test]
    fn top_loops_counts_only_top_level() {
        assert_eq!(url_count().top_loops().len(), 2);
    }

    #[test]
    fn slot_map_is_deterministic_and_resolves() {
        let p = url_count().with_scalar("avg", crate::ir::Value::Float(0.0));
        let slots = p.slot_map();
        assert_eq!(slots, p.slot_map());
        assert_eq!(slots.array_slot("count"), Some(0));
        assert_eq!(slots.result_slot("R"), Some(0));
        assert_eq!(slots.scalar_slot("avg"), Some(0));
        assert_eq!(slots.scalar_slot("nope"), None);
    }

    #[test]
    fn fresh_var_avoids_loop_vars() {
        let p = url_count();
        assert_eq!(p.fresh_var("i"), "i1");
        assert_eq!(p.fresh_var("k"), "k");
    }
}
