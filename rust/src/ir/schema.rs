//! Relation schemas: named, typed tuple layouts.
//!
//! In the paper the tuple structure ("the schema of a database") is *under
//! compiler control* (§III-C1): the reformatting pass may drop dead fields
//! or dictionary-encode string fields, producing a *new* schema. Schemas
//! are therefore cheap immutable values the transformation passes can
//! rewrite freely.

use std::fmt;

use super::value::DataType;

/// Index of a field within a schema (stable across the compile).
pub type FieldId = usize;

/// One field: a name and a type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Field {
    pub name: String,
    pub dtype: DataType,
}

/// An ordered list of typed fields.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Schema {
    fields: Vec<Field>,
}

impl Schema {
    pub fn new(fields: Vec<(&str, DataType)>) -> Self {
        Schema {
            fields: fields
                .into_iter()
                .map(|(name, dtype)| Field {
                    name: name.to_string(),
                    dtype,
                })
                .collect(),
        }
    }

    pub fn from_fields(fields: Vec<Field>) -> Self {
        Schema { fields }
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    pub fn fields(&self) -> &[Field] {
        &self.fields
    }

    pub fn field(&self, id: FieldId) -> &Field {
        &self.fields[id]
    }

    /// Resolve a field name to its id.
    pub fn field_id(&self, name: &str) -> Option<FieldId> {
        self.fields.iter().position(|f| f.name == name)
    }

    /// Resolve a field name to its id, with the standard error message
    /// used across the execution engine.
    pub fn require_field(&self, name: &str) -> anyhow::Result<FieldId> {
        self.field_id(name)
            .ok_or_else(|| anyhow::anyhow!("no field `{name}`"))
    }

    pub fn dtype(&self, id: FieldId) -> DataType {
        self.fields[id].dtype
    }

    /// Schema with only the given fields kept, in the given order
    /// (dead-field elimination / projection).
    pub fn project(&self, keep: &[FieldId]) -> Schema {
        Schema {
            fields: keep.iter().map(|&i| self.fields[i].clone()).collect(),
        }
    }

    /// Schema with one field's type replaced (dictionary encoding turns a
    /// `Str` field into an `Int` key field).
    pub fn with_dtype(&self, id: FieldId, dtype: DataType) -> Schema {
        let mut s = self.clone();
        s.fields[id].dtype = dtype;
        s
    }

    /// Concatenation of two schemas (join output), prefixing duplicate
    /// names with the given labels.
    pub fn join(&self, other: &Schema, left_label: &str, right_label: &str) -> Schema {
        let mut fields = Vec::with_capacity(self.len() + other.len());
        for f in &self.fields {
            let dup = other.fields.iter().any(|g| g.name == f.name);
            fields.push(Field {
                name: if dup {
                    format!("{left_label}.{}", f.name)
                } else {
                    f.name.clone()
                },
                dtype: f.dtype,
            });
        }
        for f in &other.fields {
            let dup = self.fields.iter().any(|g| g.name == f.name);
            fields.push(Field {
                name: if dup {
                    format!("{right_label}.{}", f.name)
                } else {
                    f.name.clone()
                },
                dtype: f.dtype,
            });
        }
        Schema { fields }
    }
}

impl fmt::Display for Schema {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(")?;
        for (i, fd) in self.fields.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{}: {}", fd.name, fd.dtype)?;
        }
        write!(f, ")")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grades() -> Schema {
        Schema::new(vec![
            ("studentID", DataType::Int),
            ("grade", DataType::Float),
            ("weight", DataType::Float),
        ])
    }

    #[test]
    fn lookup() {
        let s = grades();
        assert_eq!(s.field_id("grade"), Some(1));
        assert_eq!(s.field_id("nope"), None);
        assert_eq!(s.dtype(0), DataType::Int);
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn require_field_errors_with_name() {
        let s = grades();
        assert_eq!(s.require_field("weight").unwrap(), 2);
        let e = s.require_field("nope").unwrap_err().to_string();
        assert!(e.contains("nope"), "{e}");
    }

    #[test]
    fn project_keeps_order() {
        let s = grades().project(&[2, 0]);
        assert_eq!(s.field(0).name, "weight");
        assert_eq!(s.field(1).name, "studentID");
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn dictionary_encoding_changes_dtype() {
        let s = Schema::new(vec![("url", DataType::Str)]);
        let e = s.with_dtype(0, DataType::Int);
        assert_eq!(e.dtype(0), DataType::Int);
        assert_eq!(e.field(0).name, "url");
    }

    #[test]
    fn join_prefixes_duplicates() {
        let a = Schema::new(vec![("id", DataType::Int), ("x", DataType::Int)]);
        let b = Schema::new(vec![("id", DataType::Int), ("y", DataType::Int)]);
        let j = a.join(&b, "A", "B");
        assert_eq!(j.field_id("A.id"), Some(0));
        assert_eq!(j.field_id("x"), Some(1));
        assert_eq!(j.field_id("B.id"), Some(2));
        assert_eq!(j.field_id("y"), Some(3));
    }
}
