//! Expression trees of the single intermediate representation.
//!
//! Expressions appear in loop bounds, index-set filters (`pA.field[expr]`),
//! accumulator subscripts (`count[A[i].url]`), result tuples and filter
//! conditions. They are deliberately simple — "simple loop control"
//! (§II) is what makes the re-targeted compiler transformations
//! applicable.

use std::fmt;

use super::value::Value;

/// Binary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
}

impl BinOp {
    pub fn is_comparison(self) -> bool {
        matches!(
            self,
            BinOp::Eq | BinOp::Ne | BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge
        )
    }
}

impl fmt::Display for BinOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Mod => "%",
            BinOp::Eq => "==",
            BinOp::Ne => "!=",
            BinOp::Lt => "<",
            BinOp::Le => "<=",
            BinOp::Gt => ">",
            BinOp::Ge => ">=",
            BinOp::And => "&&",
            BinOp::Or => "||",
        };
        write!(f, "{s}")
    }
}

/// Unary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnOp {
    Neg,
    Not,
}

/// An expression.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// Literal constant.
    Const(Value),
    /// A scalar/loop variable or program parameter (`l`, `k`, `N`, `avg`).
    Var(String),
    /// `A[i].field` — `var` is the tuple cursor (a forelem loop variable),
    /// `field` the accessed field name.
    Field { var: String, field: String },
    /// `count[k][A[i].url]` — accumulator array subscript.
    ArrayRef { array: String, indices: Vec<Expr> },
    /// Binary operation.
    Binary {
        op: BinOp,
        lhs: Box<Expr>,
        rhs: Box<Expr>,
    },
    /// Unary operation.
    Unary { op: UnOp, expr: Box<Expr> },
    /// `Σ_{v=1}^{parts} body` — the cross-partition reduction that closes a
    /// parallelized aggregation (§IV's `Σ_k count_k[...]`).
    SumOverParts {
        var: String,
        parts: Box<Expr>,
        body: Box<Expr>,
    },
}

impl Expr {
    pub fn int(v: i64) -> Expr {
        Expr::Const(Value::Int(v))
    }

    pub fn float(v: f64) -> Expr {
        Expr::Const(Value::Float(v))
    }

    pub fn str(v: &str) -> Expr {
        Expr::Const(Value::str(v))
    }

    pub fn var(name: &str) -> Expr {
        Expr::Var(name.to_string())
    }

    pub fn field(var: &str, field: &str) -> Expr {
        Expr::Field {
            var: var.to_string(),
            field: field.to_string(),
        }
    }

    pub fn array(array: &str, indices: Vec<Expr>) -> Expr {
        Expr::ArrayRef {
            array: array.to_string(),
            indices,
        }
    }

    pub fn bin(op: BinOp, lhs: Expr, rhs: Expr) -> Expr {
        Expr::Binary {
            op,
            lhs: Box::new(lhs),
            rhs: Box::new(rhs),
        }
    }

    pub fn eq(lhs: Expr, rhs: Expr) -> Expr {
        Expr::bin(BinOp::Eq, lhs, rhs)
    }

    pub fn add(lhs: Expr, rhs: Expr) -> Expr {
        Expr::bin(BinOp::Add, lhs, rhs)
    }

    pub fn mul(lhs: Expr, rhs: Expr) -> Expr {
        Expr::bin(BinOp::Mul, lhs, rhs)
    }

    /// Visit every sub-expression (pre-order).
    pub fn walk(&self, f: &mut impl FnMut(&Expr)) {
        f(self);
        match self {
            Expr::ArrayRef { indices, .. } => {
                for i in indices {
                    i.walk(f);
                }
            }
            Expr::Binary { lhs, rhs, .. } => {
                lhs.walk(f);
                rhs.walk(f);
            }
            Expr::Unary { expr, .. } => expr.walk(f),
            Expr::SumOverParts { parts, body, .. } => {
                parts.walk(f);
                body.walk(f);
            }
            Expr::Const(_) | Expr::Var(_) | Expr::Field { .. } => {}
        }
    }

    /// Mutate every sub-expression (post-order): used by substitution passes.
    pub fn walk_mut(&mut self, f: &mut impl FnMut(&mut Expr)) {
        match self {
            Expr::ArrayRef { indices, .. } => {
                for i in indices {
                    i.walk_mut(f);
                }
            }
            Expr::Binary { lhs, rhs, .. } => {
                lhs.walk_mut(f);
                rhs.walk_mut(f);
            }
            Expr::Unary { expr, .. } => expr.walk_mut(f),
            Expr::SumOverParts { parts, body, .. } => {
                parts.walk_mut(f);
                body.walk_mut(f);
            }
            Expr::Const(_) | Expr::Var(_) | Expr::Field { .. } => {}
        }
        f(self);
    }

    /// All loop-variable / scalar names this expression reads.
    pub fn used_vars(&self) -> Vec<String> {
        let mut out = Vec::new();
        self.walk(&mut |e| match e {
            Expr::Var(v) => out.push(v.clone()),
            Expr::Field { var, .. } => out.push(var.clone()),
            _ => {}
        });
        out
    }

    /// All accumulator arrays this expression reads.
    pub fn used_arrays(&self) -> Vec<String> {
        let mut out = Vec::new();
        self.walk(&mut |e| {
            if let Expr::ArrayRef { array, .. } = e {
                out.push(array.clone());
            }
        });
        out
    }

    /// Rename a variable throughout (alpha-renaming during fusion).
    pub fn rename_var(&mut self, from: &str, to: &str) {
        self.walk_mut(&mut |e| match e {
            Expr::Var(v) if v == from => *v = to.to_string(),
            Expr::Field { var, .. } if var == from => *var = to.to_string(),
            _ => {}
        });
    }

    /// True if the expression is a compile-time constant.
    pub fn is_const(&self) -> bool {
        matches!(self, Expr::Const(_))
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Const(Value::Str(s)) => write!(f, "{s:?}"),
            Expr::Const(v) => write!(f, "{v}"),
            Expr::Var(v) => write!(f, "{v}"),
            Expr::Field { var, field } => write!(f, "{var}.{field}"),
            Expr::ArrayRef { array, indices } => {
                write!(f, "{array}")?;
                for i in indices {
                    write!(f, "[{i}]")?;
                }
                Ok(())
            }
            Expr::Binary { op, lhs, rhs } => write!(f, "({lhs} {op} {rhs})"),
            Expr::Unary { op, expr } => match op {
                UnOp::Neg => write!(f, "(-{expr})"),
                UnOp::Not => write!(f, "(!{expr})"),
            },
            Expr::SumOverParts { var, parts, body } => {
                write!(f, "sum({var}=1..{parts}; {body})")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_roundtrip_style() {
        let e = Expr::add(
            Expr::mul(Expr::field("g", "grade"), Expr::field("g", "weight")),
            Expr::int(1),
        );
        assert_eq!(e.to_string(), "((g.grade * g.weight) + 1)");
    }

    #[test]
    fn used_vars_and_arrays() {
        let e = Expr::array("count", vec![Expr::var("k"), Expr::field("i", "url")]);
        let vars = e.used_vars();
        assert!(vars.contains(&"k".to_string()));
        assert!(vars.contains(&"i".to_string()));
        assert_eq!(e.used_arrays(), vec!["count".to_string()]);
    }

    #[test]
    fn rename_var_touches_fields() {
        let mut e = Expr::field("i", "url");
        e.rename_var("i", "j");
        assert_eq!(e, Expr::field("j", "url"));
    }

    #[test]
    fn sum_over_parts_display() {
        let e = Expr::SumOverParts {
            var: "k".into(),
            parts: Box::new(Expr::var("N")),
            body: Box::new(Expr::array("count", vec![Expr::var("k"), Expr::var("u")])),
        };
        assert_eq!(e.to_string(), "sum(k=1..N; count[k][u])");
    }
}
