//! The single intermediate representation (§II–III of the paper).
//!
//! Data is modelled as multisets of tuples; computation as `forelem`
//! loop nests over index sets. Everything downstream — SQL lowering,
//! MapReduce derivation, the transformation passes, parallelization and
//! code generation — operates on the types in this module.

pub mod expr;
pub mod index_set;
pub mod multiset;
pub mod pretty;
pub mod program;
pub mod schema;
pub mod stmt;
pub mod validate;
pub mod value;

pub use expr::{BinOp, Expr, UnOp};
pub use index_set::{IndexSet, Partition, Strategy};
pub use multiset::Multiset;
pub use program::{ArrayDecl, Program, SlotMap};
pub use schema::{Field, FieldId, Schema};
pub use stmt::{AccumOp, Domain, EmitOrder, Loop, LoopKind, Stmt, TopKStrategy};
pub use validate::validate;
pub use value::{DataType, Tuple, Value};
