//! Well-formedness checks over programs.
//!
//! Every pass in the pipeline must keep programs valid; `validate` is run
//! after each pass in debug builds (transform/pipeline.rs) and by tests.

use std::collections::HashSet;

use anyhow::{bail, Result};

use super::expr::Expr;
use super::program::Program;
use super::stmt::{Domain, Loop, LoopKind, Stmt};

/// Check a whole program. Returns the first problem found.
pub fn validate(p: &Program) -> Result<()> {
    let mut scope: HashSet<String> = p.params.keys().cloned().collect();
    scope.extend(p.scalars.keys().cloned());
    for s in &p.body {
        check_stmt(p, s, &mut scope)?;
    }
    Ok(())
}

fn check_stmt(p: &Program, s: &Stmt, scope: &mut HashSet<String>) -> Result<()> {
    match s {
        Stmt::Loop(l) => check_loop(p, l, scope),
        Stmt::Accum {
            array,
            indices,
            value,
            ..
        } => {
            let Some(decl) = p.arrays.get(array) else {
                bail!("accum into undeclared array `{array}`");
            };
            if indices.len() != decl.dims {
                bail!(
                    "array `{array}` declared with {} dims, used with {}",
                    decl.dims,
                    indices.len()
                );
            }
            for i in indices {
                check_expr(p, i, scope)?;
            }
            check_expr(p, value, scope)
        }
        Stmt::ResultUnion { result, tuple } => {
            let Some(schema) = p.results.get(result) else {
                bail!("union into undeclared result `{result}`");
            };
            if tuple.len() != schema.len() {
                bail!(
                    "result `{result}` has {} fields, tuple has {}",
                    schema.len(),
                    tuple.len()
                );
            }
            for e in tuple {
                check_expr(p, e, scope)?;
            }
            Ok(())
        }
        Stmt::Assign { var, value } => {
            check_expr(p, value, scope)?;
            scope.insert(var.clone());
            Ok(())
        }
        Stmt::If { cond, then, els } => {
            check_expr(p, cond, scope)?;
            for s in then {
                check_stmt(p, s, scope)?;
            }
            for s in els {
                check_stmt(p, s, scope)?;
            }
            Ok(())
        }
        Stmt::Print { args, .. } => {
            for a in args {
                check_expr(p, a, scope)?;
            }
            Ok(())
        }
    }
}

fn check_loop(p: &Program, l: &Loop, scope: &mut HashSet<String>) -> Result<()> {
    match &l.domain {
        Domain::IndexSet(ix) => {
            let Some(schema) = p.relations.get(&ix.relation) else {
                bail!("loop over undeclared relation `{}`", ix.relation);
            };
            if let Some((field, v)) = &ix.field_filter {
                if schema.field_id(field).is_none() {
                    bail!("filter on unknown field `{}.{}`", ix.relation, field);
                }
                check_expr(p, v, scope)?;
            }
            if let Some(d) = &ix.distinct {
                if schema.field_id(d).is_none() {
                    bail!("distinct on unknown field `{}.{}`", ix.relation, d);
                }
            }
            if ix.partition.is_some() && l.kind == LoopKind::Forall {
                bail!("a forall loop cannot itself iterate a partitioned index set");
            }
        }
        Domain::Range { lo, hi } => {
            check_expr(p, lo, scope)?;
            check_expr(p, hi, scope)?;
        }
        Domain::ValuePartition {
            relation,
            field,
            part,
            parts,
        } => {
            let Some(schema) = p.relations.get(relation) else {
                bail!("value partition over undeclared relation `{relation}`");
            };
            if schema.field_id(field).is_none() {
                bail!("value partition on unknown field `{relation}.{field}`");
            }
            check_expr(p, part, scope)?;
            check_expr(p, parts, scope)?;
        }
        Domain::DistinctValues { relation, field } => {
            let Some(schema) = p.relations.get(relation) else {
                bail!("distinct-values over undeclared relation `{relation}`");
            };
            if schema.field_id(field).is_none() {
                bail!("distinct-values on unknown field `{relation}.{field}`");
            }
        }
    }
    if let Some(e) = &l.emit {
        check_emit(l, e)?;
    }
    let added = scope.insert(l.var.clone());
    for s in &l.body {
        check_stmt(p, s, scope)?;
    }
    if added {
        scope.remove(&l.var);
    }
    Ok(())
}

/// An ordered/bounded emission must actually order or bound something,
/// and its sort key must be a valid position of every result tuple the
/// loop appends. (The schema width equals the tuple width — checked by
/// `check_stmt` — so the tuple check covers both.)
fn check_emit(l: &Loop, e: &super::stmt::EmitOrder) -> Result<()> {
    if e.key.is_none() && e.limit.is_none() {
        bail!("emit annotation on loop `{}` orders nothing and bounds nothing", l.var);
    }
    if let Some(f) = e.key {
        let mut err = None;
        for s in &l.body {
            s.walk(&mut |sub| {
                if err.is_some() {
                    return;
                }
                if let Stmt::ResultUnion { result, tuple } = sub {
                    if f >= tuple.len() {
                        err = Some(format!(
                            "emit sort key #{f} out of range for result `{result}` \
                             ({}-field tuple)",
                            tuple.len()
                        ));
                    }
                }
            });
        }
        if let Some(m) = err {
            bail!("{m}");
        }
    }
    Ok(())
}

fn check_expr(p: &Program, e: &Expr, scope: &HashSet<String>) -> Result<()> {
    let mut err = None;
    e.walk(&mut |sub| {
        if err.is_some() {
            return;
        }
        match sub {
            Expr::Var(v) => {
                if !scope.contains(v) && !p.params.contains_key(v) && !p.scalars.contains_key(v) {
                    // SumOverParts binds its own var; handled below by
                    // pushing it into a local scope — here we only flag
                    // genuinely free variables.
                    if !bound_by_sum(e, v) {
                        err = Some(format!("use of unbound variable `{v}`"));
                    }
                }
            }
            Expr::Field { var, .. } => {
                if !scope.contains(var) && !bound_by_sum(e, var) {
                    err = Some(format!("field access through unbound cursor `{var}`"));
                }
            }
            Expr::ArrayRef { array, indices } => {
                match p.arrays.get(array) {
                    None => err = Some(format!("read of undeclared array `{array}`")),
                    Some(d) if d.dims != indices.len() => {
                        err = Some(format!(
                            "array `{array}` declared with {} dims, read with {}",
                            d.dims,
                            indices.len()
                        ))
                    }
                    _ => {}
                }
            }
            _ => {}
        }
    });
    match err {
        Some(m) => bail!("{m}"),
        None => Ok(()),
    }
}

/// Is `v` bound by a `SumOverParts` node inside `e`?
fn bound_by_sum(e: &Expr, v: &str) -> bool {
    let mut found = false;
    e.walk(&mut |sub| {
        if let Expr::SumOverParts { var, .. } = sub {
            if var == v {
                found = true;
            }
        }
    });
    found
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::index_set::IndexSet;
    use crate::ir::program::ArrayDecl;
    use crate::ir::schema::Schema;
    use crate::ir::value::DataType;

    fn base() -> Program {
        Program::new("t")
            .with_relation("A", Schema::new(vec![("x", DataType::Int)]))
            .with_array("count", ArrayDecl::counter())
    }

    #[test]
    fn accepts_valid_program() {
        let p = base().with_body(vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("A"),
            vec![Stmt::increment("count", vec![Expr::field("i", "x")])],
        ))]);
        validate(&p).unwrap();
    }

    #[test]
    fn rejects_unknown_relation() {
        let p = base().with_body(vec![Stmt::Loop(Loop::forelem("i", IndexSet::all("B"), vec![]))]);
        assert!(validate(&p).unwrap_err().to_string().contains("undeclared relation"));
    }

    #[test]
    fn rejects_unknown_field_filter() {
        let p = base().with_body(vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::filtered("A", "nope", Expr::int(1)),
            vec![],
        ))]);
        assert!(validate(&p).unwrap_err().to_string().contains("unknown field"));
    }

    #[test]
    fn rejects_unbound_cursor() {
        let p = base().with_body(vec![Stmt::increment("count", vec![Expr::field("i", "x")])]);
        assert!(validate(&p).unwrap_err().to_string().contains("unbound cursor"));
    }

    #[test]
    fn rejects_dim_mismatch() {
        let p = base().with_body(vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("A"),
            vec![Stmt::increment(
                "count",
                vec![Expr::field("i", "x"), Expr::int(0)],
            )],
        ))]);
        assert!(validate(&p).unwrap_err().to_string().contains("dims"));
    }

    #[test]
    fn rejects_undeclared_result() {
        let p = base().with_body(vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("A"),
            vec![Stmt::result_union("R", vec![Expr::field("i", "x")])],
        ))]);
        assert!(validate(&p).unwrap_err().to_string().contains("undeclared result"));
    }

    #[test]
    fn emit_annotations_are_checked() {
        use crate::ir::stmt::EmitOrder;
        let result = || {
            base().with_result(
                "R",
                Schema::new(vec![("x", DataType::Int), ("n", DataType::Int)]),
            )
        };
        let emit_loop = |e: EmitOrder| {
            Stmt::Loop(
                Loop::forelem(
                    "i",
                    IndexSet::all("A"),
                    vec![Stmt::result_union(
                        "R",
                        vec![
                            Expr::field("i", "x"),
                            Expr::array("count", vec![Expr::field("i", "x")]),
                        ],
                    )],
                )
                .with_emit(e),
            )
        };
        // Valid top-k emission.
        validate(&result().with_body(vec![emit_loop(EmitOrder::top_k(1, true, 5))])).unwrap();
        // Sort key out of tuple range.
        let err = validate(&result().with_body(vec![emit_loop(EmitOrder::ordered(2, false))]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of range"), "{err}");
        // Annotation that neither orders nor bounds.
        let empty = EmitOrder {
            key: None,
            descending: false,
            limit: None,
            strategy: Default::default(),
        };
        let err = validate(&result().with_body(vec![emit_loop(empty)]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("orders nothing"), "{err}");
    }

    #[test]
    fn sum_over_parts_binds_its_var() {
        let p = base()
            .with_param("N", crate::ir::value::Value::Int(4))
            .with_result("R", Schema::new(vec![("n", DataType::Int)]))
            .with_body(vec![Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::all("A"),
                vec![Stmt::result_union(
                    "R",
                    vec![Expr::SumOverParts {
                        var: "k".into(),
                        parts: Box::new(Expr::var("N")),
                        body: Box::new(Expr::array("count", vec![Expr::var("k")])),
                    }],
                )],
            ))]);
        // `count` has 1 dim and is indexed [k] — consistent; `k` bound by sum.
        validate(&p).unwrap();
    }
}
