//! Expression evaluation: environments, value arithmetic, accumulator
//! array store.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::ir::{AccumOp, BinOp, Expr, Program, Tuple, UnOp, Value};
use crate::storage::Table;
use crate::util::FxHashMap;

/// A tuple cursor: the binding a `forelem` variable gets.
#[derive(Debug, Clone)]
pub struct Cursor {
    pub table: Arc<Table>,
    pub row: usize,
}

/// Evaluation environment: scalar bindings + tuple cursors (scope stack).
#[derive(Debug, Default)]
pub struct Env {
    vars: Vec<(String, Value)>,
    cursors: Vec<(String, Cursor)>,
}

impl Env {
    pub fn new() -> Self {
        Env::default()
    }

    pub fn push_var(&mut self, name: &str, v: Value) {
        self.vars.push((name.to_string(), v));
    }

    pub fn pop_var(&mut self) {
        self.vars.pop();
    }

    pub fn set_var(&mut self, name: &str, v: Value) {
        if let Some(slot) = self.vars.iter_mut().rev().find(|(n, _)| n == name) {
            slot.1 = v;
        } else {
            self.vars.push((name.to_string(), v));
        }
    }

    pub fn var(&self, name: &str) -> Option<&Value> {
        self.vars
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v)
    }

    pub fn push_cursor(&mut self, name: &str, c: Cursor) {
        self.cursors.push((name.to_string(), c));
    }

    pub fn pop_cursor(&mut self) {
        self.cursors.pop();
    }

    pub fn cursor(&self, name: &str) -> Option<&Cursor> {
        self.cursors
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .map(|(_, c)| c)
    }
}

/// Storage for accumulator arrays: associative maps from subscript tuples
/// to values. The recognized-idiom fast paths bypass this entirely.
#[derive(Debug, Default, Clone)]
pub struct ArrayStore {
    arrays: FxHashMap<String, FxHashMap<Tuple, Value>>,
}

impl ArrayStore {
    pub fn new() -> Self {
        ArrayStore::default()
    }

    pub fn accum(&mut self, array: &str, index: Tuple, op: AccumOp, v: Value, init: &Value) {
        let slot = self
            .arrays
            .entry(array.to_string())
            .or_default()
            .entry(index)
            .or_insert_with(|| init.clone());
        *slot = apply_accum(op, slot, &v);
    }

    pub fn read(&self, array: &str, index: &Tuple, init: &Value) -> Value {
        self.arrays
            .get(array)
            .and_then(|m| m.get(index))
            .cloned()
            .unwrap_or_else(|| init.clone())
    }

    pub fn entries(&self, array: &str) -> impl Iterator<Item = (&Tuple, &Value)> {
        self.arrays.get(array).into_iter().flat_map(|m| m.iter())
    }

    /// Merge another store into this one, combining same-key entries with
    /// `Add` semantics for numeric values (parallel-partial merge).
    pub fn merge_add(&mut self, other: ArrayStore) {
        for (name, entries) in other.arrays {
            let dst = self.arrays.entry(name).or_default();
            for (k, v) in entries {
                match dst.get_mut(&k) {
                    Some(slot) => *slot = apply_accum(AccumOp::Add, slot, &v),
                    None => {
                        dst.insert(k, v);
                    }
                }
            }
        }
    }
}

/// Combine an accumulator slot with an incoming value. Shared with the
/// vectorized tier (`vector.rs`) so merge semantics cannot drift.
pub(crate) fn apply_accum(op: AccumOp, old: &Value, new: &Value) -> Value {
    match op {
        AccumOp::Set => new.clone(),
        AccumOp::Add => value_binop(BinOp::Add, old, new).unwrap_or_else(|_| new.clone()),
        AccumOp::Max => {
            if new > old {
                new.clone()
            } else {
                old.clone()
            }
        }
        AccumOp::Min => {
            if new < old {
                new.clone()
            } else {
                old.clone()
            }
        }
    }
}

/// Render a `Print` statement: substitute `{}` placeholders left to
/// right, appending overflow values. Shared by the interpreter and the
/// vectorized tier so print-stream parity cannot drift.
pub(crate) fn format_print(format: &str, args: &[Value]) -> String {
    let mut text = format.to_string();
    for v in args {
        if let Some(pos) = text.find("{}") {
            text.replace_range(pos..pos + 2, &v.to_string());
        } else {
            text.push_str(&format!(" {v}"));
        }
    }
    text
}

/// Evaluate a binary operation on two values (Int/Float promotion).
pub fn value_binop(op: BinOp, l: &Value, r: &Value) -> Result<Value> {
    use BinOp::*;
    Ok(match op {
        Add | Sub | Mul | Div | Mod => match (l, r) {
            (Value::Int(a), Value::Int(b)) => match op {
                Add => Value::Int(a.wrapping_add(*b)),
                Sub => Value::Int(a.wrapping_sub(*b)),
                Mul => Value::Int(a.wrapping_mul(*b)),
                Div => {
                    if *b == 0 {
                        bail!("integer division by zero")
                    }
                    Value::Int(a / b)
                }
                Mod => {
                    if *b == 0 {
                        bail!("integer modulo by zero")
                    }
                    Value::Int(a % b)
                }
                _ => unreachable!(),
            },
            _ => {
                let (a, b) = (
                    l.as_float().context("non-numeric lhs")?,
                    r.as_float().context("non-numeric rhs")?,
                );
                Value::Float(match op {
                    Add => a + b,
                    Sub => a - b,
                    Mul => a * b,
                    Div => a / b,
                    Mod => a % b,
                    _ => unreachable!(),
                })
            }
        },
        Eq => Value::Bool(l == r),
        Ne => Value::Bool(l != r),
        Lt => Value::Bool(l < r),
        Le => Value::Bool(l <= r),
        Gt => Value::Bool(l > r),
        Ge => Value::Bool(l >= r),
        And => Value::Bool(l.truthy() && r.truthy()),
        Or => Value::Bool(l.truthy() || r.truthy()),
    })
}

/// Evaluate an expression.
pub fn eval(e: &Expr, env: &Env, arrays: &ArrayStore, program: &Program) -> Result<Value> {
    Ok(match e {
        Expr::Const(v) => v.clone(),
        Expr::Var(name) => env
            .var(name)
            .or_else(|| program.params.get(name))
            .or_else(|| program.scalars.get(name))
            .with_context(|| format!("unbound variable `{name}`"))?
            .clone(),
        Expr::Field { var, field } => {
            let c = env
                .cursor(var)
                .with_context(|| format!("unbound cursor `{var}`"))?;
            let fid = c
                .table
                .schema
                .field_id(field)
                .with_context(|| format!("no field `{field}`"))?;
            c.table.value(c.row, fid)
        }
        Expr::ArrayRef { array, indices } => {
            let decl = program
                .arrays
                .get(array)
                .with_context(|| format!("undeclared array `{array}`"))?;
            let index: Tuple = indices
                .iter()
                .map(|i| eval(i, env, arrays, program))
                .collect::<Result<_>>()?;
            arrays.read(array, &index, &decl.init)
        }
        Expr::Binary { op, lhs, rhs } => {
            // Short-circuit booleans.
            if *op == BinOp::And {
                let l = eval(lhs, env, arrays, program)?;
                if !l.truthy() {
                    return Ok(Value::Bool(false));
                }
                return Ok(Value::Bool(eval(rhs, env, arrays, program)?.truthy()));
            }
            if *op == BinOp::Or {
                let l = eval(lhs, env, arrays, program)?;
                if l.truthy() {
                    return Ok(Value::Bool(true));
                }
                return Ok(Value::Bool(eval(rhs, env, arrays, program)?.truthy()));
            }
            let l = eval(lhs, env, arrays, program)?;
            let r = eval(rhs, env, arrays, program)?;
            value_binop(*op, &l, &r)?
        }
        Expr::Unary { op, expr } => {
            let v = eval(expr, env, arrays, program)?;
            match op {
                UnOp::Neg => match v {
                    Value::Int(i) => Value::Int(-i),
                    Value::Float(f) => Value::Float(-f),
                    other => bail!("cannot negate {other}"),
                },
                UnOp::Not => Value::Bool(!v.truthy()),
            }
        }
        Expr::SumOverParts { var, parts, body } => {
            let n = eval(parts, env, arrays, program)?
                .as_int()
                .context("non-integer part count")?;
            let mut total = Value::Int(0);
            let mut local = Env::new();
            // Copy: SumOverParts bodies only reference arrays + the sum var
            // + enclosing cursors; build a child env referencing both.
            for k in 1..=n {
                local.set_var(var, Value::Int(k));
                let v = eval_with_overlay(body, env, &local, arrays, program)?;
                total = value_binop(BinOp::Add, &total, &v)?;
            }
            total
        }
    })
}

/// Evaluate with an overlay env consulted before the base env.
fn eval_with_overlay(
    e: &Expr,
    base: &Env,
    overlay: &Env,
    arrays: &ArrayStore,
    program: &Program,
) -> Result<Value> {
    // Cheap approach: temporarily push overlay vars onto a clone of base.
    // Overlays are tiny (the sum variable), so this stays off hot paths.
    match e {
        Expr::Var(name) => {
            if let Some(v) = overlay.var(name) {
                return Ok(v.clone());
            }
            eval(e, base, arrays, program)
        }
        Expr::ArrayRef { array, indices } => {
            let decl = program
                .arrays
                .get(array)
                .with_context(|| format!("undeclared array `{array}`"))?;
            let index: Tuple = indices
                .iter()
                .map(|i| eval_with_overlay(i, base, overlay, arrays, program))
                .collect::<Result<_>>()?;
            Ok(arrays.read(array, &index, &decl.init))
        }
        Expr::Binary { op, lhs, rhs } => {
            let l = eval_with_overlay(lhs, base, overlay, arrays, program)?;
            let r = eval_with_overlay(rhs, base, overlay, arrays, program)?;
            value_binop(*op, &l, &r)
        }
        other => eval(other, base, arrays, program),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Multiset, Schema};

    fn program() -> Program {
        Program::new("t")
            .with_param("N", Value::Int(4))
            .with_array("count", crate::ir::ArrayDecl::counter())
    }

    fn table() -> Arc<Table> {
        let schema = Schema::new(vec![("url", DataType::Str), ("n", DataType::Int)]);
        let m = Multiset::with_rows(
            schema,
            vec![vec![Value::str("/a"), Value::Int(7)]],
        );
        Arc::new(Table::from_multiset(&m).unwrap())
    }

    #[test]
    fn arithmetic_promotion() {
        assert_eq!(
            value_binop(BinOp::Add, &Value::Int(1), &Value::Float(0.5)).unwrap(),
            Value::Float(1.5)
        );
        assert_eq!(
            value_binop(BinOp::Mul, &Value::Int(3), &Value::Int(4)).unwrap(),
            Value::Int(12)
        );
        assert!(value_binop(BinOp::Div, &Value::Int(1), &Value::Int(0)).is_err());
    }

    #[test]
    fn field_access_via_cursor() {
        let p = program();
        let mut env = Env::new();
        env.push_cursor("i", Cursor { table: table(), row: 0 });
        let v = eval(&Expr::field("i", "n"), &env, &ArrayStore::new(), &p).unwrap();
        assert_eq!(v, Value::Int(7));
    }

    #[test]
    fn array_read_defaults_to_init() {
        let p = program();
        let v = eval(
            &Expr::array("count", vec![Expr::int(5)]),
            &Env::new(),
            &ArrayStore::new(),
            &p,
        )
        .unwrap();
        assert_eq!(v, Value::Int(0));
    }

    #[test]
    fn accum_and_read_back() {
        let p = program();
        let mut store = ArrayStore::new();
        let init = Value::Int(0);
        store.accum("count", vec![Value::str("/a")], AccumOp::Add, Value::Int(1), &init);
        store.accum("count", vec![Value::str("/a")], AccumOp::Add, Value::Int(1), &init);
        let v = eval(
            &Expr::array("count", vec![Expr::str("/a")]),
            &Env::new(),
            &store,
            &p,
        )
        .unwrap();
        assert_eq!(v, Value::Int(2));
    }

    #[test]
    fn sum_over_parts() {
        let p = program();
        let mut store = ArrayStore::new();
        let init = Value::Int(0);
        for k in 1..=4i64 {
            store.accum("count", vec![Value::Int(k)], AccumOp::Add, Value::Int(10 * k), &init);
        }
        let e = Expr::SumOverParts {
            var: "k".into(),
            parts: Box::new(Expr::var("N")),
            body: Box::new(Expr::array("count", vec![Expr::var("k")])),
        };
        let v = eval(&e, &Env::new(), &store, &p).unwrap();
        assert_eq!(v, Value::Int(100));
    }

    #[test]
    fn merge_add_combines_stores() {
        let init = Value::Int(0);
        let mut a = ArrayStore::new();
        a.accum("c", vec![Value::Int(1)], AccumOp::Add, Value::Int(2), &init);
        let mut b = ArrayStore::new();
        b.accum("c", vec![Value::Int(1)], AccumOp::Add, Value::Int(3), &init);
        b.accum("c", vec![Value::Int(2)], AccumOp::Add, Value::Int(5), &init);
        a.merge_add(b);
        assert_eq!(a.read("c", &vec![Value::Int(1)], &init), Value::Int(5));
        assert_eq!(a.read("c", &vec![Value::Int(2)], &init), Value::Int(5));
    }

    #[test]
    fn short_circuit_and() {
        let p = program();
        // (false && <unbound var>) must not error.
        let e = Expr::bin(BinOp::And, Expr::Const(Value::Bool(false)), Expr::var("nope"));
        assert_eq!(
            eval(&e, &Env::new(), &ArrayStore::new(), &p).unwrap(),
            Value::Bool(false)
        );
    }
}
