//! Runtime index structures for filtered index sets.
//!
//! "the compiler will determine iteration methods for these loops and
//! generate appropriate code. An iteration method may or may not involve
//! the use of an additional index structure" (§III-B). These structures
//! are generated at run time and are temporary, exactly as the paper
//! describes; the cache lets one index serve several forelem loops.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use crate::ir::Value;
use crate::storage::Table;

/// Hash index: field value → row ids (Figure 1 bottom).
#[derive(Debug)]
pub struct HashIndex {
    map: HashMap<Value, Vec<u32>>,
}

impl HashIndex {
    pub fn build(table: &Table, field: usize) -> Self {
        let mut map: HashMap<Value, Vec<u32>> = HashMap::new();
        for row in 0..table.len() {
            map.entry(table.value(row, field))
                .or_default()
                .push(row as u32);
        }
        HashIndex { map }
    }

    pub fn probe(&self, key: &Value) -> &[u32] {
        self.map.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn keys(&self) -> impl Iterator<Item = &Value> {
        self.map.keys()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Sorted (tree) index: ordered field value → row ids.
#[derive(Debug)]
pub struct TreeIndex {
    map: BTreeMap<Value, Vec<u32>>,
}

impl TreeIndex {
    pub fn build(table: &Table, field: usize) -> Self {
        let mut map: BTreeMap<Value, Vec<u32>> = BTreeMap::new();
        for row in 0..table.len() {
            map.entry(table.value(row, field))
                .or_default()
                .push(row as u32);
        }
        TreeIndex { map }
    }

    pub fn probe(&self, key: &Value) -> &[u32] {
        self.map.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Ordered iteration over (value, rows) — what distinct loops with
    /// ordering requirements use.
    pub fn iter(&self) -> impl Iterator<Item = (&Value, &Vec<u32>)> {
        self.map.iter()
    }

    pub fn range(
        &self,
        lo: &Value,
        hi: &Value,
    ) -> impl Iterator<Item = (&Value, &Vec<u32>)> {
        self.map.range(lo.clone()..=hi.clone())
    }
}

/// Distinct-value directory: value → first row (for `pA.distinct(f)`),
/// in first-occurrence order.
#[derive(Debug)]
pub struct DistinctIndex {
    pub firsts: Vec<u32>,
}

impl DistinctIndex {
    pub fn build(table: &Table, field: usize) -> Self {
        let mut seen = HashMap::new();
        let mut firsts = Vec::new();
        for row in 0..table.len() {
            let v = table.value(row, field);
            if seen.insert(v, ()).is_none() {
                firsts.push(row as u32);
            }
        }
        DistinctIndex { firsts }
    }
}

/// Per-execution cache: one index per (table-ptr, field, kind).
#[derive(Debug, Default)]
pub struct IndexCache {
    hash: HashMap<(usize, usize), Arc<HashIndex>>,
    tree: HashMap<(usize, usize), Arc<TreeIndex>>,
    distinct: HashMap<(usize, usize), Arc<DistinctIndex>>,
    pub builds: usize,
}

impl IndexCache {
    pub fn new() -> Self {
        IndexCache::default()
    }

    fn key(table: &Arc<Table>, field: usize) -> (usize, usize) {
        (Arc::as_ptr(table) as usize, field)
    }

    pub fn hash(&mut self, table: &Arc<Table>, field: usize) -> Arc<HashIndex> {
        let key = Self::key(table, field);
        if let Some(ix) = self.hash.get(&key) {
            return ix.clone();
        }
        self.builds += 1;
        let ix = Arc::new(HashIndex::build(table, field));
        self.hash.insert(key, ix.clone());
        ix
    }

    pub fn tree(&mut self, table: &Arc<Table>, field: usize) -> Arc<TreeIndex> {
        let key = Self::key(table, field);
        if let Some(ix) = self.tree.get(&key) {
            return ix.clone();
        }
        self.builds += 1;
        let ix = Arc::new(TreeIndex::build(table, field));
        self.tree.insert(key, ix.clone());
        ix
    }

    pub fn distinct(&mut self, table: &Arc<Table>, field: usize) -> Arc<DistinctIndex> {
        let key = Self::key(table, field);
        if let Some(ix) = self.distinct.get(&key) {
            return ix.clone();
        }
        self.builds += 1;
        let ix = Arc::new(DistinctIndex::build(table, field));
        self.distinct.insert(key, ix.clone());
        ix
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DataType, Multiset, Schema};

    fn table() -> Arc<Table> {
        let schema = Schema::new(vec![("k", DataType::Int)]);
        let m = Multiset::with_rows(
            schema,
            vec![
                vec![Value::Int(3)],
                vec![Value::Int(1)],
                vec![Value::Int(3)],
                vec![Value::Int(2)],
            ],
        );
        Arc::new(Table::from_multiset(&m).unwrap())
    }

    #[test]
    fn hash_probe_finds_all_rows() {
        let t = table();
        let ix = HashIndex::build(&t, 0);
        assert_eq!(ix.probe(&Value::Int(3)), &[0, 2]);
        assert_eq!(ix.probe(&Value::Int(9)), &[] as &[u32]);
        assert_eq!(ix.len(), 3);
    }

    #[test]
    fn tree_iterates_in_order() {
        let t = table();
        let ix = TreeIndex::build(&t, 0);
        let keys: Vec<i64> = ix.iter().map(|(v, _)| v.as_int().unwrap()).collect();
        assert_eq!(keys, vec![1, 2, 3]);
        let ranged: Vec<i64> = ix
            .range(&Value::Int(2), &Value::Int(3))
            .map(|(v, _)| v.as_int().unwrap())
            .collect();
        assert_eq!(ranged, vec![2, 3]);
    }

    #[test]
    fn distinct_keeps_first_occurrence_order() {
        let t = table();
        let ix = DistinctIndex::build(&t, 0);
        assert_eq!(ix.firsts, vec![0, 1, 3]);
    }

    #[test]
    fn cache_reuses_indexes() {
        let t = table();
        let mut cache = IndexCache::new();
        let a = cache.hash(&t, 0);
        let b = cache.hash(&t, 0);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.builds, 1);
        cache.tree(&t, 0);
        assert_eq!(cache.builds, 2);
    }
}
