//! The vectorized execution tier: batch execution of compiled programs.
//!
//! Sits between the recognized-idiom kernels (`plan.rs`) and the
//! reference interpreter (`local.rs`) in the dispatch order. Programs are
//! first lowered by `exec::compile` to slot-resolved register form; this
//! module drives `forelem` loops over the columnar storage in batches of
//! [`BATCH`] rows, with no string lookups or per-row name resolution on
//! the hot path. Single-statement aggregation bodies additionally fire
//! the fused batch kernels below — the same inner-loop primitives the
//! distributed coordinator's `process_chunk` and the idiom kernels'
//! native fallbacks use, so all three tiers share one code path for the
//! dense counting/summing loops.
//!
//! Equi-joins execute here too: a compiled [`JoinLoop`] builds a
//! [`JoinHashTable`] over the inner table once, then probes it from the
//! outer cursor in [`BATCH`]-row batches (selection vectors handle any
//! outer equality filter). Matched pairs run the slot-resolved body, or —
//! for the join + GROUP BY shapes — the fused per-match `vec.count` /
//! `vec.sum` kernels. N-way chains hash every joined table once and
//! probe level by level per match, pipelining the whole star/snowflake
//! nest without intermediate materialization. `"vec.hash_join"` is
//! pushed into [`ExecStats::idioms`] whenever the join kernel fires.
//!
//! Semantics contract: for every supported program the output is
//! `bag_eq`-identical to `local::run`, including scalar results, print
//! stream and float rounding (fold order is preserved; fused float sums
//! only fire from a zero accumulator, and join probes visit matches in
//! the interpreter's nested-loop order).
//!
//! The dense inner loops are *SIMD-shaped*: selection vectors are built
//! branchlessly and the integer count/sum kernels accumulate into
//! [`LANES`] interleaved per-lane partials folded at scan end (exact,
//! because wrapping integer addition is associative and commutative).
//! Float folds are never reshaped — reassociating them would change
//! rounding versus the interpreter. Kernels that fired the SIMD path tag
//! `"vec.simd"`; see `docs/ARCHITECTURE.md` § Kernel vectorization.

use std::collections::{BTreeMap, BinaryHeap};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::ir::{AccumOp, BinOp, Program, Tuple, UnOp, Value};
use crate::storage::{Column, CompressedInts, Dictionary, StorageCatalog, Table};
use crate::util::FxHashMap;

use super::compile::{
    compile_program, CStmt, CompiledProgram, EmitSpec, ExprProg, FastAgg, JoinFastAgg, JoinLoop,
    JoinSide, Op, ScanLoop,
};
use super::eval::{apply_accum, value_binop};
use super::index::DistinctIndex;
use super::local::{block_bounds, ExecStats, Output};

/// Rows per batch: large enough to amortize dispatch, small enough to
/// keep the touched column windows cache-resident.
pub const BATCH: usize = 1024;

/// Fixed lane width the SIMD-shaped kernels are written against: the
/// branchless selection builders and the striped integer accumulators
/// iterate `chunks_exact(LANES)` bodies so the autovectorizer sees a
/// constant trip count with no data-dependent branches. Eight 64-bit
/// lanes is one AVX-512 register / two AVX2 registers / four NEON
/// registers — wide enough to fill any current unit without spilling.
pub const LANES: usize = 8;

/// Widest dense-dictionary domain the striped kernels will allocate
/// per-lane accumulators for ([`LANES`] stripes of `width` slots each).
/// Past this the stripes stop fitting in L2 and the extra fold cost
/// outweighs the broken store-to-load dependence, so the aggregation
/// states fall back to a single scalar stripe.
pub const MAX_STRIPED_WIDTH: usize = 1 << 16;

/// Branchless equality selection: append `base + i` for every `i` with
/// `vals[i] == key`. The body writes the candidate index unconditionally
/// and advances the output cursor by the comparison result, so there is
/// no branch on data — the autovectorizer turns the `chunks_exact(LANES)`
/// loop into compare-to-mask + compress/store sequences. `sel` grows in
/// ascending order exactly like the branchy reference loop.
fn select_eq<T: Copy + PartialEq>(vals: &[T], key: T, base: usize, sel: &mut Vec<usize>) {
    let start = sel.len();
    // Reserve worst-case output; writes below stay in-bounds because the
    // cursor advances at most once per element processed.
    sel.resize(start + vals.len(), 0);
    let out = &mut sel[start..];
    let mut n = 0usize;
    let mut row = base;
    let mut chunks = vals.chunks_exact(LANES);
    for chunk in &mut chunks {
        for (i, &v) in chunk.iter().enumerate() {
            out[n] = row + i;
            n += (v == key) as usize;
        }
        row += LANES;
    }
    for (i, &v) in chunks.remainder().iter().enumerate() {
        out[n] = row + i;
        n += (v == key) as usize;
    }
    sel.truncate(start + n);
}

/// [`select_eq`] over flat `i64` columns (public for the bench harness).
pub fn select_eq_i64(vals: &[i64], key: i64, base: usize, sel: &mut Vec<usize>) {
    select_eq(vals, key, base, sel);
}

/// [`select_eq`] over dictionary-code columns (public for the bench
/// harness).
pub fn select_eq_u32(keys: &[u32], key: u32, base: usize, sel: &mut Vec<usize>) {
    select_eq(keys, key, base, sel);
}

/// Iterate `[lo, hi)` as `(start, end)` windows of at most [`BATCH`]
/// rows — the shared morsel granularity used by this module's scan and
/// join-probe drivers, `exec::parallel`'s morsel workers and the
/// coordinator's `process_chunk`.
pub fn morsel_ranges(lo: usize, hi: usize) -> impl Iterator<Item = (usize, usize)> {
    (lo..hi)
        .step_by(BATCH)
        .map(move |base| (base, (base + BATCH).min(hi)))
}

/// An equality filter resolved into its column's *physical* domain, once
/// per scan: string keys become dictionary codes (one
/// [`Dictionary::lookup`], so the per-row loops compare `u32` codes and
/// never strings), integer keys over flat columns compare raw `i64`
/// slices, and compressed columns are solved per run / arithmetically in
/// [`CompressedInts::find_eq_in`]. Only pairings the typed kernels cannot
/// express exactly (e.g. cross-type numeric keys, which `Value` equality
/// admits) fall back to the boxed comparison, so the match set is always
/// identical to the interpreter's.
pub(crate) enum EqFilter<'a> {
    /// Flat `i64` slice equality (autovectorization-friendly tight loop).
    Ints(&'a [i64], i64),
    /// Dictionary-code equality over the `u32` key column.
    Dict(&'a [u32], u32),
    /// Run-domain equality: whole-run emission for RLE, closed-form for
    /// enumerated ranges.
    Compressed(&'a CompressedInts, i64),
    /// Statically unsatisfiable (the filter string is absent from the
    /// column's dictionary): no row can match.
    Never,
    /// Boxed `Value` comparison — the reference semantics.
    Boxed(&'a Column, &'a Value),
}

impl<'a> EqFilter<'a> {
    pub(crate) fn new(col: &'a Column, key: &'a Value) -> EqFilter<'a> {
        match (col, key) {
            (Column::Ints(vals), Value::Int(k)) => EqFilter::Ints(vals, *k),
            (Column::DictStrs { keys, dict }, Value::Str(s)) => match dict.lookup(s) {
                Some(code) => EqFilter::Dict(keys, code),
                None => EqFilter::Never,
            },
            (Column::CompressedInts(c), Value::Int(k)) => EqFilter::Compressed(c, *k),
            _ => EqFilter::Boxed(col, key),
        }
    }

    /// Append the row ids in `[lo, hi)` whose column value matches onto
    /// `sel` (in ascending row order).
    pub(crate) fn select(&self, lo: usize, hi: usize, sel: &mut Vec<usize>) {
        match self {
            EqFilter::Ints(vals, k) => select_eq_i64(&vals[lo..hi], *k, lo, sel),
            EqFilter::Dict(keys, code) => select_eq_u32(&keys[lo..hi], *code, lo, sel),
            EqFilter::Compressed(c, k) => c.find_eq_in(*k, lo, hi, sel),
            EqFilter::Never => {}
            EqFilter::Boxed(col, key) => {
                for row in lo..hi {
                    if col.value(row) == **key {
                        sel.push(row);
                    }
                }
            }
        }
    }

    /// Per-row residual test, for the ordered-emission paths that must
    /// walk the global row sequence anyway. O(log runs) on compressed
    /// columns via the prefix-sum index.
    pub(crate) fn matches(&self, row: usize) -> bool {
        match self {
            EqFilter::Ints(vals, k) => vals[row] == *k,
            EqFilter::Dict(keys, code) => keys[row] == *code,
            EqFilter::Compressed(c, k) => c.get(row) == *k,
            EqFilter::Never => false,
            EqFilter::Boxed(col, key) => col.value(row) == **key,
        }
    }

    /// The idiom tag this filter pushes when it drives a scan, if it is
    /// one of the compressed-domain kernels.
    pub(crate) fn idiom(&self) -> Option<&'static str> {
        match self {
            EqFilter::Dict(..) | EqFilter::Never => Some("vec.dict_filter"),
            EqFilter::Compressed(..) => Some("vec.rle_filter"),
            _ => None,
        }
    }

    /// True when [`select`](Self::select) runs the branchless
    /// `chunks_exact(LANES)` builder (flat ints and dict codes) — the
    /// scan drivers tag `"vec.simd"` for these.
    pub(crate) fn simd(&self) -> bool {
        matches!(self, EqFilter::Ints(..) | EqFilter::Dict(..))
    }
}

/// Hash table over the build side of a compiled join: key value → row ids
/// in table order.
///
/// Probing uses the interpreter's `Value` equality (cross-type numeric
/// `Eq` and `Hash` agree, see `ir::value`), so the match set is identical
/// to the reference scan filter's; buckets preserve table order, so the
/// probe's (outer-major, inner-in-table-order) match sequence is exactly
/// the interpreter's nested-loop order. Built once per join execution and
/// shared read-only across workers by `exec::parallel` and the
/// coordinator's join jobs.
#[derive(Debug, Default)]
pub struct JoinHashTable {
    map: FxHashMap<Value, Vec<u32>>,
}

impl JoinHashTable {
    /// Build over `table.column(key_field)` in one pass.
    pub fn build(table: &Table, key_field: usize) -> JoinHashTable {
        let col = table.column(key_field);
        let mut map: FxHashMap<Value, Vec<u32>> = FxHashMap::default();
        for row in 0..table.len() {
            map.entry(col.value(row)).or_default().push(row as u32);
        }
        JoinHashTable { map }
    }

    /// Rows whose key column equals `key`, in table order.
    pub fn probe(&self, key: &Value) -> &[u32] {
        self.map.get(key).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Number of distinct keys.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when the build side held no rows.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// One buffered emission row: its sort key (if the emission orders), the
/// direction, its emission sequence number, and the row itself.
///
/// `Ord` is the *emission order*: key first (direction-adjusted), then
/// sequence — so `Less` means "emitted earlier" (better), a max-heap's
/// root is the worst retained row, and `into_sorted_vec` yields rows in
/// final emission order.
#[derive(Debug, Clone)]
struct TopKEntry {
    sort: Option<Value>,
    descending: bool,
    seq: u64,
    row: Tuple,
}

impl PartialEq for TopKEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for TopKEntry {}
impl PartialOrd for TopKEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TopKEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        let key = match (&self.sort, &other.sort) {
            (Some(a), Some(b)) => {
                let c = a.cmp(b);
                if self.descending {
                    c.reverse()
                } else {
                    c
                }
            }
            _ => std::cmp::Ordering::Equal,
        };
        key.then(self.seq.cmp(&other.seq))
    }
}

/// The fused top-k kernel behind the `vec.topk` idiom tag: a bounded-heap
/// accumulator for ordered/bounded emissions (`ORDER BY`/`LIMIT` lowered
/// into the IR's [`EmitOrder`](crate::ir::EmitOrder)).
///
/// In bounded mode the heap retains only the current `k` best rows —
/// O(n log k) time, O(k) memory over `n` emitted rows — and
/// [`finish`](TopK::finish) returns them in emission order. Tie-breaking
/// is by emission sequence, which makes the kept set and its order
/// *exactly* the first `k` rows of the reference interpreter's stable
/// sort: every tier agrees row-for-row, ties included. The morsel driver
/// runs one `TopK` per worker over disjoint chunks and k-way-merges them,
/// which preserves the same contract because a globally-top-k row is
/// top-k within its chunk.
///
/// # Examples
///
/// ```
/// use forelem::exec::TopK;
/// use forelem::ir::Value;
///
/// // ORDER BY #1 DESC LIMIT 2 over (url, count) rows.
/// let mut tk = TopK::bounded(Some(1), true, 2);
/// for (url, n) in [("/a", 3), ("/b", 9), ("/c", 5)] {
///     tk.push(vec![Value::str(url), Value::Int(n)]);
/// }
/// let rows = tk.finish();
/// assert_eq!(rows.len(), 2);
/// assert_eq!(rows[0][1], Value::Int(9));
/// assert_eq!(rows[1][1], Value::Int(5));
/// ```
#[derive(Debug)]
pub struct TopK {
    key: Option<usize>,
    descending: bool,
    limit: Option<usize>,
    /// Bounded-heap mode: evict the worst entry once `limit` is reached.
    heap: bool,
    entries: BinaryHeap<TopKEntry>,
    seq: u64,
}

impl TopK {
    /// Bounded-heap accumulator: keep the top `k` rows ordered by tuple
    /// position `key` (or the first `k` in emission order when `key` is
    /// `None` — a bare `LIMIT`).
    pub fn bounded(key: Option<usize>, descending: bool, k: usize) -> TopK {
        TopK {
            key,
            descending,
            limit: Some(k),
            heap: true,
            entries: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Materializing accumulator: buffer everything, sort at
    /// [`finish`](TopK::finish), truncate to `limit` if set — the
    /// `opt.topk_sort` strategy.
    pub fn sorting(key: Option<usize>, descending: bool, limit: Option<usize>) -> TopK {
        TopK {
            key,
            descending,
            limit,
            heap: false,
            entries: BinaryHeap::new(),
            seq: 0,
        }
    }

    fn from_spec(spec: &EmitSpec) -> TopK {
        if spec.heap {
            TopK::bounded(spec.key, spec.descending, spec.limit.expect("heap implies limit"))
        } else {
            TopK::sorting(spec.key, spec.descending, spec.limit)
        }
    }

    /// True when this accumulator runs the bounded-heap kernel.
    pub fn is_bounded(&self) -> bool {
        self.heap
    }

    /// Number of currently retained rows.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no rows are retained.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Append one emitted row (sequence assigned automatically, in call
    /// order).
    pub fn push(&mut self, row: Tuple) {
        let seq = self.seq;
        self.seq += 1;
        self.push_at(seq, row);
    }

    /// Append one emitted row with an explicit emission-sequence number —
    /// the parallel drivers pass the row's global iteration index so
    /// per-worker heaps merge into exactly the sequential order.
    pub fn push_at(&mut self, seq: u64, row: Tuple) {
        self.seq = self.seq.max(seq + 1);
        let entry = TopKEntry {
            sort: self.key.map(|f| row[f].clone()),
            descending: self.descending,
            seq,
            row,
        };
        self.push_entry(entry);
    }

    fn push_entry(&mut self, entry: TopKEntry) {
        if self.heap {
            let k = self.limit.expect("heap implies limit");
            if self.entries.len() < k {
                self.entries.push(entry);
            } else if let Some(worst) = self.entries.peek() {
                if entry < *worst {
                    self.entries.pop();
                    self.entries.push(entry);
                }
            }
        } else {
            self.entries.push(entry);
        }
    }

    /// Absorb another accumulator's retained rows (the `absorb`-style
    /// k-way merge of the morsel driver), preserving their sequence
    /// numbers. Both accumulators must order by the same key and
    /// direction — merging mismatched orderings would interleave
    /// entries under two different comparators.
    pub fn merge(&mut self, other: TopK) {
        debug_assert!(
            self.key == other.key && self.descending == other.descending,
            "merging top-k accumulators with different orderings"
        );
        for entry in other.entries.into_iter() {
            self.seq = self.seq.max(entry.seq + 1);
            self.push_entry(entry);
        }
    }

    /// The retained rows in final emission order (best first), truncated
    /// to `limit` — identical to stable-sorting every pushed row by the
    /// key and taking the prefix.
    pub fn finish(self) -> Vec<Tuple> {
        let mut entries = self.entries.into_sorted_vec();
        if let Some(k) = self.limit {
            entries.truncate(k);
        }
        entries.into_iter().map(|e| e.row).collect()
    }
}

/// Per-result-slot [`TopK`] accumulators for one emit loop in flight.
/// While installed on a [`VecState`], result appends are intercepted
/// into the matching accumulator instead of the result multiset.
#[derive(Debug)]
pub(crate) struct TopKSet {
    spec: EmitSpec,
    per_result: Vec<Option<TopK>>,
    /// When set, pushes use `(group << 16) | intra` as the sequence —
    /// the parallel drivers set the group to the row's global iteration
    /// index so worker-local heaps merge into sequential order.
    seq_group: Option<u64>,
    intra: u64,
}

impl TopKSet {
    pub(crate) fn new(spec: EmitSpec, n_results: usize) -> TopKSet {
        TopKSet {
            spec,
            per_result: (0..n_results).map(|_| None).collect(),
            seq_group: None,
            intra: 0,
        }
    }

    /// True when the bounded-heap kernel executes this emission.
    pub(crate) fn heap_mode(&self) -> bool {
        self.spec.heap
    }

    /// Set the global emission-sequence group for subsequent pushes
    /// (parallel drivers: one group per source row).
    pub(crate) fn set_seq_group(&mut self, group: u64) {
        self.seq_group = Some(group);
        self.intra = 0;
    }

    pub(crate) fn push(&mut self, result: usize, row: Tuple) {
        let spec = &self.spec;
        let tk = self.per_result[result].get_or_insert_with(|| TopK::from_spec(spec));
        match self.seq_group {
            Some(g) => {
                let seq = (g << 16) | self.intra.min(0xffff);
                self.intra += 1;
                tk.push_at(seq, row);
            }
            None => tk.push(row),
        }
    }

    pub(crate) fn merge(&mut self, other: TopKSet) {
        for (dst, src) in self.per_result.iter_mut().zip(other.per_result) {
            match (dst.as_mut(), src) {
                (Some(d), Some(s)) => d.merge(s),
                (None, Some(s)) => *dst = Some(s),
                _ => {}
            }
        }
    }

    /// Drain into `(result slot, rows in emission order)` pairs.
    pub(crate) fn finish(self) -> Vec<(usize, Vec<Tuple>)> {
        self.per_result
            .into_iter()
            .enumerate()
            .filter_map(|(slot, tk)| tk.map(|tk| (slot, tk.finish())))
            .collect()
    }
}

/// Execute a program on the vectorized tier if its shape is supported.
/// `Ok(None)` means "not this tier" — callers fall back to the
/// interpreter, preserving observable behaviour exactly.
pub fn try_run(p: &Program, catalog: &StorageCatalog) -> Result<Option<Output>> {
    match compile_program(p, catalog) {
        Some(cp) => {
            let mut out = run_compiled_program(&cp)?;
            // Direct callers (benches, tests) bypass `plan::run_compiled`;
            // merge the optimizer's decision tags here too (deduplicated).
            out.stats.note_opt_tags(&p.opt_tags);
            Ok(Some(out))
        }
        None => Ok(None),
    }
}

/// Execute an already-compiled program (shared by `exec::parallel`).
pub fn run_compiled_program(cp: &CompiledProgram) -> Result<Output> {
    let mut st = VecState::new(cp);
    st.exec_stmts(cp, &cp.body)?;
    Ok(st.finish(cp))
}

/// Execute an already-compiled program with the given parameter binding
/// overriding [`CompiledProgram::param_inits`] — the prepared-statement
/// execute path (`serve::Server`): compile once, run per binding.
pub fn run_compiled_program_with_params(cp: &CompiledProgram, params: Vec<Value>) -> Result<Output> {
    if params.len() != cp.param_names.len() {
        bail!(
            "binding has {} values but the program declares {} parameters",
            params.len(),
            cp.param_names.len()
        );
    }
    let mut st = VecState::new(cp);
    st.set_params(params);
    st.exec_stmts(cp, &cp.body)?;
    Ok(st.finish(cp))
}

/// Mutable execution state for one compiled-program run. Workers in
/// `exec::parallel` each own one and merge via [`VecState::absorb`].
pub struct VecState {
    pub(crate) scalars: Vec<Value>,
    /// Late-bound parameter values, `Op::LoadParam` slot order. Seeded
    /// from [`CompiledProgram::param_inits`]; prepared-statement
    /// executions override per run via [`VecState::set_params`].
    pub(crate) params: Vec<Value>,
    pub(crate) arrays: Vec<FxHashMap<Tuple, Value>>,
    cursors: Vec<CursorState>,
    pub(crate) results: Vec<crate::ir::Multiset>,
    pub(crate) prints: Vec<String>,
    pub(crate) stats: ExecStats,
    regs: Vec<Value>,
    /// Emit interception: while an ordered/bounded emit loop runs, its
    /// per-result [`TopK`] accumulators live here and result appends are
    /// routed into them instead of `results`. Not touched by `absorb`
    /// (never in flight across a worker merge).
    topk: Option<TopKSet>,
    /// Read-only accumulator override: when set, expression evaluation
    /// reads arrays from this shared store instead of `arrays`. The
    /// parallel emit fan-out hands every worker one `Arc` of the
    /// master's complete store — no per-worker copies. Writes (`Accum`,
    /// fused kernels) still target the private `arrays`; the emit
    /// eligibility analysis guarantees none happen while this is set.
    shared_arrays: Option<Arc<Vec<FxHashMap<Tuple, Value>>>>,
}

struct CursorState {
    table: Option<Arc<Table>>,
    row: usize,
}

impl VecState {
    pub fn new(cp: &CompiledProgram) -> Self {
        VecState {
            scalars: cp.scalar_inits.clone(),
            params: cp.param_inits.clone(),
            arrays: vec![FxHashMap::default(); cp.array_inits.len()],
            cursors: (0..cp.n_cursors)
                .map(|_| CursorState {
                    table: None,
                    row: 0,
                })
                .collect(),
            results: cp
                .result_schemas
                .iter()
                .map(|s| crate::ir::Multiset::new(s.clone()))
                .collect(),
            prints: Vec::new(),
            stats: ExecStats::default(),
            regs: vec![Value::Null; cp.n_regs],
            topk: None,
            shared_arrays: None,
        }
    }

    /// Override the parameter binding for this run (prepared statements).
    /// The caller must pass one value per [`CompiledProgram::param_names`]
    /// entry, in slot order.
    pub fn set_params(&mut self, params: Vec<Value>) {
        self.params = params;
    }

    /// Install a shared read-only accumulator store for expression reads
    /// (parallel emit workers; see the `shared_arrays` field docs).
    pub(crate) fn set_shared_arrays(&mut self, arrays: Arc<Vec<FxHashMap<Tuple, Value>>>) {
        self.shared_arrays = Some(arrays);
    }

    /// Install an emit-interception frame (parallel emit workers).
    pub(crate) fn begin_topk(&mut self, frame: TopKSet) {
        self.topk = Some(frame);
    }

    /// Remove and return the active emit-interception frame.
    pub(crate) fn take_topk(&mut self) -> Option<TopKSet> {
        self.topk.take()
    }

    /// Append a result row, honouring an active emit-interception frame.
    fn append_row(&mut self, result: usize, row: Tuple) {
        match self.topk.as_mut() {
            Some(tk) => tk.push(result, row),
            None => self.results[result].push(row),
        }
    }

    /// Merge a worker's state into this one: accumulator entries combine
    /// with `Add` (the privatized-slice merge of §IV), result rows append
    /// (bag semantics), stats sum.
    pub fn absorb(&mut self, other: VecState) {
        for (dst, src) in self.arrays.iter_mut().zip(other.arrays) {
            for (k, v) in src {
                match dst.get_mut(&k) {
                    Some(slot) => *slot = apply_accum(AccumOp::Add, slot, &v),
                    None => {
                        dst.insert(k, v);
                    }
                }
            }
        }
        for (dst, src) in self.results.iter_mut().zip(other.results) {
            for row in src.into_rows() {
                dst.push(row);
            }
        }
        self.prints.extend(other.prints);
        self.stats.rows_visited += other.stats.rows_visited;
        self.stats.index_builds += other.stats.index_builds;
        self.stats.kernel_calls += other.stats.kernel_calls;
        for idiom in other.stats.idioms {
            if !self.stats.idioms.contains(&idiom) {
                self.stats.idioms.push(idiom);
            }
        }
    }

    pub fn finish(self, cp: &CompiledProgram) -> Output {
        let mut stats = self.stats;
        stats.idioms.insert(0, "vectorized".into());
        let mut results = BTreeMap::new();
        for (name, m) in cp.slots.results.iter().zip(self.results) {
            results.insert(name.clone(), m);
        }
        let mut scalars = BTreeMap::new();
        for (i, name) in cp.slots.scalars.iter().enumerate() {
            scalars.insert(name.clone(), self.scalars[i].clone());
        }
        Output {
            results,
            scalars,
            prints: self.prints,
            stats,
        }
    }

    /// Evaluate one compiled expression in this state (also used by
    /// `exec::parallel` to evaluate `forall` bounds).
    pub(crate) fn eval_value(&mut self, cp: &CompiledProgram, prog: &ExprProg) -> Result<Value> {
        if self.regs.len() < prog.n_regs {
            self.regs.resize(prog.n_regs, Value::Null);
        }
        let arrays: &[FxHashMap<Tuple, Value>] = match &self.shared_arrays {
            Some(shared) => shared.as_slice(),
            None => &self.arrays,
        };
        eval_ops(
            &prog.ops,
            prog.out,
            &mut self.regs,
            &mut self.scalars,
            &self.params,
            &self.cursors,
            arrays,
            &cp.array_inits,
        )
    }

    pub(crate) fn exec_stmts(&mut self, cp: &CompiledProgram, stmts: &[CStmt]) -> Result<()> {
        for s in stmts {
            self.exec_stmt(cp, s)?;
        }
        Ok(())
    }

    fn exec_stmt(&mut self, cp: &CompiledProgram, s: &CStmt) -> Result<()> {
        match s {
            CStmt::Assign { slot, value } => {
                let v = self.eval_value(cp, value)?;
                self.scalars[*slot] = v;
                Ok(())
            }
            CStmt::Accum {
                array,
                idx,
                op,
                value,
            } => {
                let key: Tuple = idx
                    .iter()
                    .map(|e| self.eval_value(cp, e))
                    .collect::<Result<_>>()?;
                let v = self.eval_value(cp, value)?;
                let init = &cp.array_inits[*array];
                let slot = self.arrays[*array]
                    .entry(key)
                    .or_insert_with(|| init.clone());
                *slot = apply_accum(*op, slot, &v);
                Ok(())
            }
            CStmt::Result { result, tuple } => {
                let row: Tuple = tuple
                    .iter()
                    .map(|e| self.eval_value(cp, e))
                    .collect::<Result<_>>()?;
                self.append_row(*result, row);
                Ok(())
            }
            CStmt::If { cond, then, els } => {
                if self.eval_value(cp, cond)?.truthy() {
                    self.exec_stmts(cp, then)
                } else {
                    self.exec_stmts(cp, els)
                }
            }
            CStmt::Print { format, args } => {
                let values: Vec<Value> = args
                    .iter()
                    .map(|a| self.eval_value(cp, a))
                    .collect::<Result<_>>()?;
                self.prints.push(super::eval::format_print(format, &values));
                Ok(())
            }
            CStmt::Range {
                slot,
                lo,
                hi,
                body,
                ..
            } => {
                let lo = self
                    .eval_value(cp, lo)?
                    .as_int()
                    .context("range lo must be an int")?;
                let hi = self
                    .eval_value(cp, hi)?
                    .as_int()
                    .context("range hi must be an int")?;
                for k in lo..=hi {
                    self.scalars[*slot] = Value::Int(k);
                    self.exec_stmts(cp, body)?;
                }
                Ok(())
            }
            CStmt::Scan(sl) => self.exec_scan(cp, sl),
            CStmt::Join(jl) => self.exec_join(cp, jl),
        }
    }

    /// Run `f` with an emit-interception frame for `spec` installed, then
    /// re-emit the retained rows (sorted/bounded) through the normal
    /// append path — which routes into an enclosing frame if one is
    /// active, so nested emissions compose like the interpreter's.
    fn with_emit_frame(
        &mut self,
        cp: &CompiledProgram,
        spec: &EmitSpec,
        f: impl FnOnce(&mut Self) -> Result<()>,
    ) -> Result<()> {
        let prev = self.topk.take();
        self.topk = Some(TopKSet::new(spec.clone(), cp.result_schemas.len()));
        let r = f(self);
        let frame = self.topk.take().expect("emit frame still installed");
        self.topk = prev;
        r?;
        if frame.heap_mode() {
            self.note_idiom("vec.topk");
        }
        for (slot, rows) in frame.finish() {
            for row in rows {
                self.append_row(slot, row);
            }
        }
        Ok(())
    }

    /// Execute a compiled join: honour any emission contract, build the
    /// hash table over the inner side, then probe it from the outer
    /// cursor.
    fn exec_join(&mut self, cp: &CompiledProgram, jl: &JoinLoop) -> Result<()> {
        match jl.emit.clone() {
            Some(spec) => self.with_emit_frame(cp, &spec, |st| st.exec_join_domain(cp, jl)),
            None => self.exec_join_domain(cp, jl),
        }
    }

    fn exec_join_domain(&mut self, cp: &CompiledProgram, jl: &JoinLoop) -> Result<()> {
        let len = jl.outer.len();
        let (lo, hi) = match &jl.partition {
            Some((part, parts)) => {
                let k = self
                    .eval_value(cp, part)?
                    .as_int()
                    .context("partition id must be an int")?;
                let n = self
                    .eval_value(cp, parts)?
                    .as_int()
                    .context("partition count must be an int")?;
                if k < 1 || k > n {
                    bail!("partition {k} out of 1..={n}");
                }
                block_bounds(len, n as usize, k as usize - 1)
            }
            None => (0, len),
        };
        let build = JoinHashTable::build(&jl.build, jl.build_key);
        self.stats.index_builds += 1;
        // One hash table per deeper chain level, each built exactly once
        // for the whole nest — the pipelined N-way join never rebuilds or
        // materializes intermediates.
        let deeper: Vec<JoinHashTable> = jl
            .deeper
            .iter()
            .map(|lvl| JoinHashTable::build(&lvl.build, lvl.build_key))
            .collect();
        self.stats.index_builds += deeper.len();
        self.probe_join(cp, jl, &build, &deeper, lo, hi)
    }

    /// Probe rows `[lo, hi)` of the outer table against already-built
    /// hash tables (one for the first build side, one per deeper chain
    /// level). `exec::parallel` calls this directly with stolen row
    /// ranges, sharing the builds across the worker pool.
    pub(crate) fn probe_join(
        &mut self,
        cp: &CompiledProgram,
        jl: &JoinLoop,
        build: &JoinHashTable,
        deeper: &[JoinHashTable],
        lo: usize,
        hi: usize,
    ) -> Result<()> {
        self.note_idiom("vec.hash_join");
        if let Some(fast) = jl.fast {
            if lo < hi && self.join_fast_agg(jl, build, fast, lo, hi) {
                return Ok(());
            }
        }
        self.cursors[jl.outer_cursor].table = Some(jl.outer.clone());
        self.cursors[jl.build_cursor].table = Some(jl.build.clone());
        for lvl in &jl.deeper {
            self.cursors[lvl.cursor].table = Some(lvl.build.clone());
        }
        // Outer equality filter: the key is scope-constant, evaluated once.
        let filter = match &jl.outer_filter {
            Some((fid, prog)) => Some((*fid, self.eval_value(cp, prog)?)),
            None => None,
        };
        let efilt = filter
            .as_ref()
            .map(|(fid, key)| EqFilter::new(jl.outer.column(*fid), key));
        if let Some(tag) = efilt.as_ref().and_then(|f| f.idiom()) {
            self.note_idiom(tag);
        }
        if let Some(f) = &efilt {
            if f.simd() {
                self.note_idiom("vec.simd");
            }
        }
        let mut sel: Vec<usize> = Vec::with_capacity(BATCH);
        for (base, end) in morsel_ranges(lo, hi) {
            self.stats.rows_visited += (end - base) as u64;
            sel.clear();
            match &efilt {
                Some(f) => f.select(base, end, &mut sel),
                None => sel.extend(base..end),
            }
            for &row in &sel {
                self.cursors[jl.outer_cursor].row = row;
                let key = match jl.probe_field {
                    Some(f) => jl.outer.column(f).value(row),
                    None => self.eval_value(cp, &jl.probe_key)?,
                };
                for &irow in build.probe(&key) {
                    self.stats.rows_visited += 1;
                    self.cursors[jl.build_cursor].row = irow as usize;
                    if jl.deeper.is_empty() {
                        self.exec_stmts(cp, &jl.body)?;
                    } else {
                        self.probe_deeper(cp, jl, deeper, 0)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Probe chain level `depth` for the current match of the enclosing
    /// levels (all enclosing cursors are positioned), recursing until the
    /// innermost body runs once per full-chain match. Match order per
    /// level is table order, so the whole chain visits matches in exactly
    /// the interpreter's nested-loop order.
    fn probe_deeper(
        &mut self,
        cp: &CompiledProgram,
        jl: &JoinLoop,
        deeper: &[JoinHashTable],
        depth: usize,
    ) -> Result<()> {
        if depth == jl.deeper.len() {
            return self.exec_stmts(cp, &jl.body);
        }
        let lvl = &jl.deeper[depth];
        let key = self.eval_value(cp, &lvl.probe_key)?;
        for &row in deeper[depth].probe(&key) {
            self.stats.rows_visited += 1;
            self.cursors[lvl.cursor].row = row as usize;
            self.probe_deeper(cp, jl, deeper, depth + 1)?;
        }
        Ok(())
    }

    /// Fused per-match join aggregation: `count[key]++` / `sum[key] += v`
    /// over the matched pairs, driving the shared batch kernels where the
    /// key column is dictionary-encoded. Returns `false` (caller runs the
    /// generic per-pair body) when the target array already holds entries
    /// or the column pairing is unsupported.
    fn join_fast_agg(
        &mut self,
        jl: &JoinLoop,
        build: &JoinHashTable,
        fast: JoinFastAgg,
        lo: usize,
        hi: usize,
    ) -> bool {
        let Some(pf) = jl.probe_field else {
            return false;
        };
        let pcol = jl.outer.column(pf);
        // Matched build rows, counted so `rows_visited` reports probe
        // rows + matches exactly like the generic per-pair path.
        let mut matched: u64 = 0;
        // Row a column on `s` reads for the matched pair (orow, irow).
        let pick = |s: JoinSide, orow: usize, irow: usize| -> usize {
            match s {
                JoinSide::Outer => orow,
                JoinSide::Build => irow,
            }
        };
        match fast {
            JoinFastAgg::Count {
                array,
                key_side,
                key_field,
            } => {
                if !self.arrays[array].is_empty() {
                    return false;
                }
                let kcol = match key_side {
                    JoinSide::Outer => jl.outer.column(key_field),
                    JoinSide::Build => jl.build.column(key_field),
                };
                match (key_side, kcol) {
                    (JoinSide::Outer, Column::DictStrs { keys, dict }) => {
                        // Per outer row, all matches share the outer key:
                        // add the bucket length in one go.
                        let mut counts = vec![0i64; dict.len()];
                        for row in lo..hi {
                            let n = build.probe(&pcol.value(row)).len() as i64;
                            matched += n as u64;
                            if n != 0 {
                                counts[keys[row] as usize] += n;
                            }
                        }
                        let store = &mut self.arrays[array];
                        for (k, &n) in counts.iter().enumerate() {
                            if n != 0 {
                                let s = dict.decode(k as u32).expect("dict key in range").clone();
                                store.insert(vec![Value::Str(s)], Value::Int(n));
                            }
                        }
                    }
                    (JoinSide::Outer, Column::Ints(keys)) => {
                        let mut map: FxHashMap<i64, i64> = FxHashMap::default();
                        for row in lo..hi {
                            let n = build.probe(&pcol.value(row)).len() as i64;
                            matched += n as u64;
                            if n != 0 {
                                *map.entry(keys[row]).or_insert(0) += n;
                            }
                        }
                        let store = &mut self.arrays[array];
                        for (k, n) in map {
                            store.insert(vec![Value::Int(k)], Value::Int(n));
                        }
                    }
                    (JoinSide::Outer, Column::Strs(keys)) => {
                        let mut map: FxHashMap<Arc<str>, i64> = FxHashMap::default();
                        for row in lo..hi {
                            let n = build.probe(&pcol.value(row)).len() as i64;
                            matched += n as u64;
                            if n == 0 {
                                continue;
                            }
                            match map.get_mut(&keys[row]) {
                                Some(e) => *e += n,
                                None => {
                                    map.insert(keys[row].clone(), n);
                                }
                            }
                        }
                        let store = &mut self.arrays[array];
                        for (s, n) in map {
                            store.insert(vec![Value::Str(s)], Value::Int(n));
                        }
                    }
                    (JoinSide::Build, Column::DictStrs { keys, dict }) => {
                        // Gather matched build-row dict codes and drive the
                        // striped dense count kernel batch-wise.
                        let mut counts = StripedI64::new(dict.len());
                        let simd = counts.striped();
                        let mut batch: Vec<u32> = Vec::with_capacity(BATCH);
                        for row in lo..hi {
                            for &irow in build.probe(&pcol.value(row)) {
                                matched += 1;
                                batch.push(keys[irow as usize]);
                                if batch.len() == BATCH {
                                    counts.add_counts(&batch);
                                    batch.clear();
                                }
                            }
                        }
                        counts.add_counts(&batch);
                        let store = &mut self.arrays[array];
                        for (k, n) in counts.totals().into_iter().enumerate() {
                            if n != 0 {
                                let s = dict.decode(k as u32).expect("dict key in range").clone();
                                store.insert(vec![Value::Str(s)], Value::Int(n));
                            }
                        }
                        if simd {
                            self.note_idiom("vec.simd");
                        }
                    }
                    (JoinSide::Build, Column::Ints(keys)) => {
                        let mut map: FxHashMap<i64, i64> = FxHashMap::default();
                        for row in lo..hi {
                            for &irow in build.probe(&pcol.value(row)) {
                                matched += 1;
                                *map.entry(keys[irow as usize]).or_insert(0) += 1;
                            }
                        }
                        let store = &mut self.arrays[array];
                        for (k, n) in map {
                            store.insert(vec![Value::Int(k)], Value::Int(n));
                        }
                    }
                    (JoinSide::Build, Column::Strs(keys)) => {
                        let mut map: FxHashMap<Arc<str>, i64> = FxHashMap::default();
                        for row in lo..hi {
                            for &irow in build.probe(&pcol.value(row)) {
                                matched += 1;
                                let s = &keys[irow as usize];
                                match map.get_mut(s) {
                                    Some(e) => *e += 1,
                                    None => {
                                        map.insert(s.clone(), 1);
                                    }
                                }
                            }
                        }
                        let store = &mut self.arrays[array];
                        for (s, n) in map {
                            store.insert(vec![Value::Str(s)], Value::Int(n));
                        }
                    }
                    _ => return false,
                }
                self.stats.rows_visited += (hi - lo) as u64 + matched;
                self.note_idiom("vec.count");
                true
            }
            JoinFastAgg::Sum {
                array,
                key_side,
                key_field,
                val_side,
                val_field,
            } => {
                if !self.arrays[array].is_empty() {
                    return false;
                }
                let kcol = match key_side {
                    JoinSide::Outer => jl.outer.column(key_field),
                    JoinSide::Build => jl.build.column(key_field),
                };
                let vcol = match val_side {
                    JoinSide::Outer => jl.outer.column(val_field),
                    JoinSide::Build => jl.build.column(val_field),
                };
                match (kcol, vcol) {
                    (Column::DictStrs { keys, dict }, Column::Floats(vs)) => {
                        // Gather matched (code, value) pairs and drive the
                        // shared dense sum kernel batch-wise; pair order is
                        // probe order, so per-key fold order matches the
                        // interpreter exactly.
                        let mut sums = vec![0f64; dict.len()];
                        let mut seen = vec![false; dict.len()];
                        let mut kb: Vec<u32> = Vec::with_capacity(BATCH);
                        let mut vb: Vec<f64> = Vec::with_capacity(BATCH);
                        let mut flush = |kb: &mut Vec<u32>, vb: &mut Vec<f64>| {
                            sum_batch_u32(kb, vb, &mut sums);
                            for &k in kb.iter() {
                                seen[k as usize] = true;
                            }
                            kb.clear();
                            vb.clear();
                        };
                        for row in lo..hi {
                            for &irow in build.probe(&pcol.value(row)) {
                                matched += 1;
                                let irow = irow as usize;
                                kb.push(keys[pick(key_side, row, irow)]);
                                vb.push(vs[pick(val_side, row, irow)]);
                                if kb.len() == BATCH {
                                    flush(&mut kb, &mut vb);
                                }
                            }
                        }
                        flush(&mut kb, &mut vb);
                        let store = &mut self.arrays[array];
                        for (k, (&s, &was)) in sums.iter().zip(&seen).enumerate() {
                            if was {
                                let key =
                                    dict.decode(k as u32).expect("dict key in range").clone();
                                store.insert(vec![Value::Str(key)], Value::Float(s));
                            }
                        }
                    }
                    (Column::DictStrs { keys, dict }, Column::Ints(vs)) => {
                        // Gather matched (code, value) pairs and drive the
                        // striped integer sum kernel batch-wise (wrapping
                        // addition is associative, so striping is exact).
                        let mut sums = StripedI64::new(dict.len());
                        let simd = sums.striped();
                        let mut seen = vec![false; dict.len()];
                        let mut kb: Vec<u32> = Vec::with_capacity(BATCH);
                        let mut vb: Vec<i64> = Vec::with_capacity(BATCH);
                        let mut flush = |kb: &mut Vec<u32>, vb: &mut Vec<i64>| {
                            sums.add_sums(kb, vb);
                            for &k in kb.iter() {
                                seen[k as usize] = true;
                            }
                            kb.clear();
                            vb.clear();
                        };
                        for row in lo..hi {
                            for &irow in build.probe(&pcol.value(row)) {
                                matched += 1;
                                let irow = irow as usize;
                                kb.push(keys[pick(key_side, row, irow)]);
                                vb.push(vs[pick(val_side, row, irow)]);
                                if kb.len() == BATCH {
                                    flush(&mut kb, &mut vb);
                                }
                            }
                        }
                        flush(&mut kb, &mut vb);
                        let store = &mut self.arrays[array];
                        for (k, (s, &was)) in sums.totals().into_iter().zip(&seen).enumerate() {
                            if was {
                                let key =
                                    dict.decode(k as u32).expect("dict key in range").clone();
                                store.insert(vec![Value::Str(key)], Value::Int(s));
                            }
                        }
                        if simd {
                            self.note_idiom("vec.simd");
                        }
                    }
                    (Column::Ints(ks), Column::Floats(vs)) => {
                        let mut map: FxHashMap<i64, f64> = FxHashMap::default();
                        for row in lo..hi {
                            for &irow in build.probe(&pcol.value(row)) {
                                matched += 1;
                                let irow = irow as usize;
                                *map.entry(ks[pick(key_side, row, irow)]).or_insert(0.0) +=
                                    vs[pick(val_side, row, irow)];
                            }
                        }
                        let store = &mut self.arrays[array];
                        for (k, s) in map {
                            store.insert(vec![Value::Int(k)], Value::Float(s));
                        }
                    }
                    (Column::Ints(ks), Column::Ints(vs)) => {
                        let mut map: FxHashMap<i64, i64> = FxHashMap::default();
                        for row in lo..hi {
                            for &irow in build.probe(&pcol.value(row)) {
                                matched += 1;
                                let irow = irow as usize;
                                let e = map.entry(ks[pick(key_side, row, irow)]).or_insert(0);
                                *e = e.wrapping_add(vs[pick(val_side, row, irow)]);
                            }
                        }
                        let store = &mut self.arrays[array];
                        for (k, s) in map {
                            store.insert(vec![Value::Int(k)], Value::Int(s));
                        }
                    }
                    (Column::Strs(ss), Column::Floats(vs)) => {
                        let mut map: FxHashMap<Arc<str>, f64> = FxHashMap::default();
                        for row in lo..hi {
                            for &irow in build.probe(&pcol.value(row)) {
                                matched += 1;
                                let irow = irow as usize;
                                let s = &ss[pick(key_side, row, irow)];
                                let v = vs[pick(val_side, row, irow)];
                                match map.get_mut(s) {
                                    Some(e) => *e += v,
                                    None => {
                                        map.insert(s.clone(), v);
                                    }
                                }
                            }
                        }
                        let store = &mut self.arrays[array];
                        for (s, v) in map {
                            store.insert(vec![Value::Str(s)], Value::Float(v));
                        }
                    }
                    (Column::Strs(ss), Column::Ints(vs)) => {
                        let mut map: FxHashMap<Arc<str>, i64> = FxHashMap::default();
                        for row in lo..hi {
                            for &irow in build.probe(&pcol.value(row)) {
                                matched += 1;
                                let irow = irow as usize;
                                let s = &ss[pick(key_side, row, irow)];
                                let v = vs[pick(val_side, row, irow)];
                                match map.get_mut(s) {
                                    Some(e) => *e = e.wrapping_add(v),
                                    None => {
                                        map.insert(s.clone(), v);
                                    }
                                }
                            }
                        }
                        let store = &mut self.arrays[array];
                        for (s, v) in map {
                            store.insert(vec![Value::Str(s)], Value::Int(v));
                        }
                    }
                    _ => return false,
                }
                self.stats.rows_visited += (hi - lo) as u64 + matched;
                self.note_idiom("vec.sum");
                true
            }
        }
    }

    fn exec_scan(&mut self, cp: &CompiledProgram, sl: &ScanLoop) -> Result<()> {
        match sl.emit.clone() {
            Some(spec) => self.with_emit_frame(cp, &spec, |st| st.exec_scan_domain(cp, sl)),
            None => self.exec_scan_domain(cp, sl),
        }
    }

    fn exec_scan_domain(&mut self, cp: &CompiledProgram, sl: &ScanLoop) -> Result<()> {
        let len = sl.table.len();
        let (lo, hi) = match &sl.partition {
            Some((part, parts)) => {
                let k = self
                    .eval_value(cp, part)?
                    .as_int()
                    .context("partition id must be an int")?;
                let n = self
                    .eval_value(cp, parts)?
                    .as_int()
                    .context("partition count must be an int")?;
                if k < 1 || k > n {
                    bail!("partition {k} out of 1..={n}");
                }
                block_bounds(len, n as usize, k as usize - 1)
            }
            None => (0, len),
        };

        if let Some(field) = sl.distinct {
            let firsts = DistinctIndex::build(&sl.table, field).firsts;
            self.stats.index_builds += 1;
            if sl.partition.is_none() {
                return self.run_distinct_rows(cp, sl, &firsts);
            }
            self.cursors[sl.cursor].table = Some(sl.table.clone());
            for &row in &firsts {
                let row = row as usize;
                if row < lo || row >= hi {
                    continue;
                }
                self.stats.rows_visited += 1;
                self.cursors[sl.cursor].row = row;
                self.exec_stmts(cp, &sl.body)?;
            }
            return Ok(());
        }

        if let Some(fast) = sl.fast {
            if lo < hi && self.fast_agg(sl, fast, lo, hi) {
                self.stats.rows_visited += (hi - lo) as u64;
                return Ok(());
            }
        }

        // Filter keys are scope-constant: evaluate once, then scan.
        let filter = match &sl.filter {
            Some((fid, key_prog)) => Some((*fid, self.eval_value(cp, key_prog)?)),
            None => None,
        };
        self.scan_rows(cp, sl, filter.as_ref(), lo, hi)
    }

    /// Run a distinct-domain scan body over one slice of the
    /// distinct-firsts row list, in list order. Unbounded emission:
    /// result appends land directly in `results` (no top-k frame), so
    /// the rows come out in firsts order. Shared by the sequential
    /// distinct branch above (whole list) and `exec::parallel`'s
    /// unbounded emit fan-out, whose workers each run disjoint slices
    /// and concatenate the per-chunk runs in chunk order.
    pub(crate) fn run_distinct_rows(
        &mut self,
        cp: &CompiledProgram,
        sl: &ScanLoop,
        firsts: &[u32],
    ) -> Result<()> {
        self.cursors[sl.cursor].table = Some(sl.table.clone());
        for &row in firsts {
            self.stats.rows_visited += 1;
            self.cursors[sl.cursor].row = row as usize;
            self.exec_stmts(cp, &sl.body)?;
        }
        Ok(())
    }

    /// Run a compiled scan's body over rows `[lo, hi)` of its table, with
    /// an optional pre-evaluated equality-filter key (field id, key
    /// value). Shared by the sequential batch driver above and
    /// `exec::parallel`'s morsel workers, which evaluate the key once on
    /// the master state and fan the value out read-only.
    pub(crate) fn scan_rows(
        &mut self,
        cp: &CompiledProgram,
        sl: &ScanLoop,
        filter: Option<&(usize, Value)>,
        lo: usize,
        hi: usize,
    ) -> Result<()> {
        self.cursors[sl.cursor].table = Some(sl.table.clone());

        if let Some((fid, key)) = filter {
            // Equality-filtered scan: resolve the key into the column's
            // physical domain once, then build a selection vector per
            // batch and run the body over matches.
            let f = EqFilter::new(sl.table.column(*fid), key);
            if let Some(tag) = f.idiom() {
                self.note_idiom(tag);
            }
            if f.simd() {
                self.note_idiom("vec.simd");
            }
            let mut sel: Vec<usize> = Vec::with_capacity(BATCH);
            for (base, end) in morsel_ranges(lo, hi) {
                self.stats.rows_visited += (end - base) as u64;
                sel.clear();
                f.select(base, end, &mut sel);
                for &row in &sel {
                    self.stats.rows_visited += 1;
                    self.cursors[sl.cursor].row = row;
                    self.exec_stmts(cp, &sl.body)?;
                }
            }
            return Ok(());
        }

        for (base, end) in morsel_ranges(lo, hi) {
            for row in base..end {
                self.stats.rows_visited += 1;
                self.cursors[sl.cursor].row = row;
                self.exec_stmts(cp, &sl.body)?;
            }
        }
        Ok(())
    }

    /// Run an ordered/bounded emit scan's body over one morsel, pushing
    /// appended rows into the active [`TopKSet`] with each row's
    /// *global* iteration index as the emission-sequence group — so the
    /// per-worker heaps of `exec::parallel`'s top-k fan-out merge into
    /// exactly the sequential emission order, ties included. Requires a
    /// frame installed via [`VecState::begin_topk`]. Callers must pass
    /// `filter: None` with [`EmitChunk::Firsts`]: distinct iteration
    /// ignores the equality filter everywhere else (the interpreter's
    /// distinct branch takes precedence over the filter).
    pub(crate) fn emit_scan_chunk(
        &mut self,
        cp: &CompiledProgram,
        sl: &ScanLoop,
        filter: Option<&(usize, Value)>,
        chunk: EmitChunk<'_>,
    ) -> Result<()> {
        debug_assert!(self.topk.is_some(), "emit frame must be installed");
        self.cursors[sl.cursor].table = Some(sl.table.clone());
        let filt = filter.map(|(fid, key)| EqFilter::new(sl.table.column(*fid), key));
        if let Some(tag) = filt.as_ref().and_then(|f| f.idiom()) {
            self.note_idiom(tag);
        }
        let run_row = |st: &mut Self, global_idx: usize, row: usize| -> Result<()> {
            st.stats.rows_visited += 1;
            if let Some(f) = &filt {
                if !f.matches(row) {
                    return Ok(());
                }
            }
            if let Some(tk) = st.topk.as_mut() {
                tk.set_seq_group(global_idx as u64);
            }
            st.cursors[sl.cursor].row = row;
            st.exec_stmts(cp, &sl.body)
        };
        match chunk {
            EmitChunk::Rows { lo, hi } => {
                for row in lo..hi {
                    run_row(self, row, row)?;
                }
            }
            EmitChunk::Firsts { firsts, base } => {
                for (i, &row) in firsts.iter().enumerate() {
                    run_row(self, base + i, row as usize)?;
                }
            }
        }
        Ok(())
    }

    /// Fused whole-loop aggregation. Returns `false` (caller runs the
    /// generic per-row body) when the target array already holds entries
    /// — continuing an existing float fold batch-wise would change
    /// rounding — or when the column pairing is unsupported.
    fn fast_agg(&mut self, sl: &ScanLoop, fast: FastAgg, lo: usize, hi: usize) -> bool {
        if !self.arrays[fast.array()].is_empty() {
            return false;
        }
        let Some(mut st) = FastAggState::new(&sl.table, fast) else {
            return false;
        };
        st.update(lo, hi);
        let tag = st.idiom();
        let extra = st.extra_idiom();
        let simd = st.simd();
        st.finish(&mut self.arrays[fast.array()]);
        self.note_idiom(tag);
        if let Some(extra) = extra {
            self.note_idiom(extra);
        }
        if simd {
            self.note_idiom("vec.simd");
        }
        true
    }

    pub(crate) fn note_idiom(&mut self, tag: &str) {
        if !self.stats.idioms.iter().any(|i| i == tag) {
            self.stats.idioms.push(tag.to_string());
        }
    }
}

/// One morsel of an ordered/bounded emit scan (see
/// [`VecState::emit_scan_chunk`]).
pub(crate) enum EmitChunk<'a> {
    /// Plain table rows `[lo, hi)`; the global sequence is the row id.
    Rows { lo: usize, hi: usize },
    /// A slice of the distinct-firsts row list starting at position
    /// `base` of the whole list; the global sequence is the position.
    Firsts { firsts: &'a [u32], base: usize },
}

/// Incremental state for one fused [`FastAgg`]: disjoint row ranges are
/// folded in via [`FastAggState::update`] and materialized into an
/// accumulator-array store once at the end, driving the same shared batch
/// kernels as before. The sequential fast path above updates one
/// contiguous range; `exec::parallel`'s morsel workers update one range
/// per pulled chunk — the kernels fire per-morsel exactly as they do
/// sequentially — and the materialized per-worker arrays merge through
/// [`VecState::absorb`].
pub(crate) enum FastAggState<'a> {
    CountDense {
        keys: &'a [u32],
        dict: &'a Dictionary,
        counts: StripedI64,
    },
    CountInts {
        keys: &'a [i64],
        map: FxHashMap<i64, i64>,
    },
    CountStrs {
        keys: &'a [Arc<str>],
        map: FxHashMap<Arc<str>, i64>,
    },
    SumDenseFloat {
        keys: &'a [u32],
        vals: &'a [f64],
        dict: &'a Dictionary,
        sums: Vec<f64>,
        seen: Vec<bool>,
    },
    SumDenseInt {
        keys: &'a [u32],
        vals: &'a [i64],
        dict: &'a Dictionary,
        sums: StripedI64,
        seen: Vec<bool>,
    },
    SumIntFloat {
        keys: &'a [i64],
        vals: &'a [f64],
        map: FxHashMap<i64, f64>,
    },
    SumIntInt {
        keys: &'a [i64],
        vals: &'a [i64],
        map: FxHashMap<i64, i64>,
    },
    SumStrFloat {
        keys: &'a [Arc<str>],
        vals: &'a [f64],
        map: FxHashMap<Arc<str>, f64>,
    },
    SumStrInt {
        keys: &'a [Arc<str>],
        vals: &'a [i64],
        map: FxHashMap<Arc<str>, i64>,
    },
    /// Run-domain count over a compressed integer key column: one map
    /// update per run, adding the run length — never iterating rows.
    CountRle {
        col: &'a CompressedInts,
        map: FxHashMap<i64, i64>,
    },
    /// Run-domain float sum: one map probe per run of the key column;
    /// the value adds stay per-row in row order so float rounding is
    /// identical to the interpreter's fold.
    SumRleFloat {
        col: &'a CompressedInts,
        vals: &'a [f64],
        map: FxHashMap<i64, f64>,
    },
    /// Run-domain integer sum: the run's values are pre-folded (wrapping
    /// addition is associative) and added with one map probe per run.
    SumRleInt {
        col: &'a CompressedInts,
        vals: &'a [i64],
        map: FxHashMap<i64, i64>,
    },
}

impl<'a> FastAggState<'a> {
    /// Bind the fused aggregation's columns, or `None` when the column
    /// pairing is unsupported (callers fall back to the generic body).
    pub(crate) fn new(table: &'a Table, fast: FastAgg) -> Option<FastAggState<'a>> {
        match fast {
            FastAgg::Count { key_field, .. } => match table.column(key_field) {
                Column::DictStrs { keys, dict } => Some(FastAggState::CountDense {
                    keys,
                    dict,
                    counts: StripedI64::new(dict.len()),
                }),
                Column::Ints(keys) => Some(FastAggState::CountInts {
                    keys,
                    map: FxHashMap::default(),
                }),
                Column::Strs(keys) => Some(FastAggState::CountStrs {
                    keys,
                    map: FxHashMap::default(),
                }),
                Column::CompressedInts(col) => Some(FastAggState::CountRle {
                    col,
                    map: FxHashMap::default(),
                }),
                _ => None,
            },
            FastAgg::Sum {
                key_field,
                val_field,
                ..
            } => match (table.column(key_field), table.column(val_field)) {
                (Column::DictStrs { keys, dict }, Column::Floats(vals)) => {
                    Some(FastAggState::SumDenseFloat {
                        keys,
                        vals,
                        dict,
                        sums: vec![0f64; dict.len()],
                        seen: vec![false; dict.len()],
                    })
                }
                (Column::DictStrs { keys, dict }, Column::Ints(vals)) => {
                    Some(FastAggState::SumDenseInt {
                        keys,
                        vals,
                        dict,
                        sums: StripedI64::new(dict.len()),
                        seen: vec![false; dict.len()],
                    })
                }
                (Column::Ints(keys), Column::Floats(vals)) => Some(FastAggState::SumIntFloat {
                    keys,
                    vals,
                    map: FxHashMap::default(),
                }),
                (Column::Ints(keys), Column::Ints(vals)) => Some(FastAggState::SumIntInt {
                    keys,
                    vals,
                    map: FxHashMap::default(),
                }),
                (Column::Strs(keys), Column::Floats(vals)) => Some(FastAggState::SumStrFloat {
                    keys,
                    vals,
                    map: FxHashMap::default(),
                }),
                (Column::Strs(keys), Column::Ints(vals)) => Some(FastAggState::SumStrInt {
                    keys,
                    vals,
                    map: FxHashMap::default(),
                }),
                (Column::CompressedInts(col), Column::Floats(vals)) => {
                    Some(FastAggState::SumRleFloat {
                        col,
                        vals,
                        map: FxHashMap::default(),
                    })
                }
                (Column::CompressedInts(col), Column::Ints(vals)) => {
                    Some(FastAggState::SumRleInt {
                        col,
                        vals,
                        map: FxHashMap::default(),
                    })
                }
                _ => None,
            },
        }
    }

    /// Fold rows `[lo, hi)` of the bound columns into the accumulation.
    pub(crate) fn update(&mut self, lo: usize, hi: usize) {
        match self {
            FastAggState::CountDense { keys, counts, .. } => {
                counts.add_counts(&keys[lo..hi]);
            }
            FastAggState::CountInts { keys, map } => {
                for &k in &keys[lo..hi] {
                    *map.entry(k).or_insert(0) += 1;
                }
            }
            FastAggState::CountStrs { keys, map } => {
                for s in &keys[lo..hi] {
                    match map.get_mut(s) {
                        Some(n) => *n += 1,
                        None => {
                            map.insert(s.clone(), 1);
                        }
                    }
                }
            }
            FastAggState::SumDenseFloat {
                keys,
                vals,
                sums,
                seen,
                ..
            } => {
                sum_batch_u32(&keys[lo..hi], &vals[lo..hi], sums);
                for &k in &keys[lo..hi] {
                    seen[k as usize] = true;
                }
            }
            FastAggState::SumDenseInt {
                keys,
                vals,
                sums,
                seen,
                ..
            } => {
                sums.add_sums(&keys[lo..hi], &vals[lo..hi]);
                for &k in &keys[lo..hi] {
                    seen[k as usize] = true;
                }
            }
            FastAggState::SumIntFloat { keys, vals, map } => {
                for (&k, &v) in keys[lo..hi].iter().zip(&vals[lo..hi]) {
                    *map.entry(k).or_insert(0.0) += v;
                }
            }
            FastAggState::SumIntInt { keys, vals, map } => {
                for (&k, &v) in keys[lo..hi].iter().zip(&vals[lo..hi]) {
                    let e = map.entry(k).or_insert(0);
                    *e = e.wrapping_add(v);
                }
            }
            FastAggState::SumStrFloat { keys, vals, map } => {
                for (s, &v) in keys[lo..hi].iter().zip(&vals[lo..hi]) {
                    match map.get_mut(s) {
                        Some(e) => *e += v,
                        None => {
                            map.insert(s.clone(), v);
                        }
                    }
                }
            }
            FastAggState::SumStrInt { keys, vals, map } => {
                for (s, &v) in keys[lo..hi].iter().zip(&vals[lo..hi]) {
                    match map.get_mut(s) {
                        Some(e) => *e = e.wrapping_add(v),
                        None => {
                            map.insert(s.clone(), v);
                        }
                    }
                }
            }
            FastAggState::CountRle { col, map } => {
                for (k, rlo, rhi) in col.run_windows(lo, hi) {
                    *map.entry(k).or_insert(0) += (rhi - rlo) as i64;
                }
            }
            FastAggState::SumRleFloat { col, vals, map } => {
                for (k, rlo, rhi) in col.run_windows(lo, hi) {
                    let e = map.entry(k).or_insert(0.0);
                    for &v in &vals[rlo..rhi] {
                        *e += v;
                    }
                }
            }
            FastAggState::SumRleInt { col, vals, map } => {
                for (k, rlo, rhi) in col.run_windows(lo, hi) {
                    let run = sum_lanes_i64(&vals[rlo..rhi]);
                    let e = map.entry(k).or_insert(0);
                    *e = e.wrapping_add(run);
                }
            }
        }
    }

    /// Materialize into an (empty) accumulator-array store.
    pub(crate) fn finish(self, store: &mut FxHashMap<Tuple, Value>) {
        match self {
            FastAggState::CountDense { dict, counts, .. } => {
                for (k, n) in counts.totals().into_iter().enumerate() {
                    if n != 0 {
                        let s = dict.decode(k as u32).expect("dict key in range").clone();
                        store.insert(vec![Value::Str(s)], Value::Int(n));
                    }
                }
            }
            FastAggState::CountInts { map, .. } => {
                for (k, n) in map {
                    store.insert(vec![Value::Int(k)], Value::Int(n));
                }
            }
            FastAggState::CountStrs { map, .. } => {
                for (s, n) in map {
                    store.insert(vec![Value::Str(s)], Value::Int(n));
                }
            }
            FastAggState::SumDenseFloat {
                dict, sums, seen, ..
            } => {
                for (k, (&s, &was)) in sums.iter().zip(&seen).enumerate() {
                    if was {
                        let key = dict.decode(k as u32).expect("dict key in range").clone();
                        store.insert(vec![Value::Str(key)], Value::Float(s));
                    }
                }
            }
            FastAggState::SumDenseInt {
                dict, sums, seen, ..
            } => {
                for (k, (s, &was)) in sums.totals().into_iter().zip(&seen).enumerate() {
                    if was {
                        let key = dict.decode(k as u32).expect("dict key in range").clone();
                        store.insert(vec![Value::Str(key)], Value::Int(s));
                    }
                }
            }
            FastAggState::SumIntFloat { map, .. } => {
                for (k, v) in map {
                    store.insert(vec![Value::Int(k)], Value::Float(v));
                }
            }
            FastAggState::SumIntInt { map, .. } => {
                for (k, v) in map {
                    store.insert(vec![Value::Int(k)], Value::Int(v));
                }
            }
            FastAggState::SumStrFloat { map, .. } => {
                for (s, v) in map {
                    store.insert(vec![Value::Str(s)], Value::Float(v));
                }
            }
            FastAggState::SumStrInt { map, .. } => {
                for (s, v) in map {
                    store.insert(vec![Value::Str(s)], Value::Int(v));
                }
            }
            FastAggState::CountRle { map, .. } => {
                for (k, n) in map {
                    store.insert(vec![Value::Int(k)], Value::Int(n));
                }
            }
            FastAggState::SumRleFloat { map, .. } => {
                for (k, v) in map {
                    store.insert(vec![Value::Int(k)], Value::Float(v));
                }
            }
            FastAggState::SumRleInt { map, .. } => {
                for (k, v) in map {
                    store.insert(vec![Value::Int(k)], Value::Int(v));
                }
            }
        }
    }

    /// The idiom tag this state pushes when it fires.
    pub(crate) fn idiom(&self) -> &'static str {
        match self {
            FastAggState::CountDense { .. }
            | FastAggState::CountInts { .. }
            | FastAggState::CountStrs { .. }
            | FastAggState::CountRle { .. } => "vec.count",
            _ => "vec.sum",
        }
    }

    /// Additional tag for the run-domain states: kernels that fold whole
    /// RLE runs (count × run length, one map probe per run) also push
    /// `vec.rle_agg` so run-domain routing stays assertable.
    pub(crate) fn extra_idiom(&self) -> Option<&'static str> {
        match self {
            FastAggState::CountRle { .. }
            | FastAggState::SumRleFloat { .. }
            | FastAggState::SumRleInt { .. } => Some("vec.rle_agg"),
            _ => None,
        }
    }

    /// True when the state's update loop runs a SIMD-shaped kernel — the
    /// striped integer histograms or the RLE `LANES`-wide pre-fold — so
    /// callers can tag `"vec.simd"`. Float states never qualify: their
    /// folds keep the interpreter's row order.
    pub(crate) fn simd(&self) -> bool {
        match self {
            FastAggState::CountDense { counts, .. } => counts.striped(),
            FastAggState::SumDenseInt { sums, .. } => sums.striped(),
            FastAggState::SumRleInt { .. } => true,
            _ => false,
        }
    }
}

/// Evaluate a flat register program. `regs` is a reusable scratch buffer
/// of at least `n_regs` slots.
fn eval_ops(
    ops: &[Op],
    out: usize,
    regs: &mut Vec<Value>,
    scalars: &mut Vec<Value>,
    params: &[Value],
    cursors: &[CursorState],
    arrays: &[FxHashMap<Tuple, Value>],
    inits: &[Value],
) -> Result<Value> {
    let mut pc = 0;
    while pc < ops.len() {
        match &ops[pc] {
            Op::Const { dst, v } => regs[*dst] = v.clone(),
            Op::LoadScalar { dst, slot } => regs[*dst] = scalars[*slot].clone(),
            Op::LoadParam { dst, param } => regs[*dst] = params[*param].clone(),
            Op::LoadField { dst, cursor, field } => {
                let c = &cursors[*cursor];
                let t = c.table.as_ref().context("unbound cursor")?;
                regs[*dst] = t.value(c.row, *field);
            }
            Op::ReadArray { dst, array, idx } => {
                let key: Tuple = idx.iter().map(|&r| regs[r].clone()).collect();
                regs[*dst] = arrays[*array]
                    .get(&key)
                    .cloned()
                    .unwrap_or_else(|| inits[*array].clone());
            }
            Op::Binary { dst, op, lhs, rhs } => {
                let v = value_binop(*op, &regs[*lhs], &regs[*rhs])?;
                regs[*dst] = v;
            }
            Op::Unary { dst, op, src } => {
                let v = match op {
                    UnOp::Neg => match &regs[*src] {
                        Value::Int(i) => Value::Int(-i),
                        Value::Float(f) => Value::Float(-f),
                        other => bail!("cannot negate {other}"),
                    },
                    UnOp::Not => Value::Bool(!regs[*src].truthy()),
                };
                regs[*dst] = v;
            }
            Op::Truthy { dst, src } => {
                let b = regs[*src].truthy();
                regs[*dst] = Value::Bool(b);
            }
            Op::SkipIfTrue { src, n } => {
                if regs[*src].truthy() {
                    pc += n;
                }
            }
            Op::SkipIfFalse { src, n } => {
                if !regs[*src].truthy() {
                    pc += n;
                }
            }
            Op::Sum {
                dst,
                slot,
                parts,
                body,
            } => {
                let n = regs[*parts]
                    .as_int()
                    .context("non-integer part count")?;
                let mut total = Value::Int(0);
                for k in 1..=n {
                    scalars[*slot] = Value::Int(k);
                    let v = eval_ops(
                        &body.ops, body.out, regs, scalars, params, cursors, arrays, inits,
                    )?;
                    total = value_binop(BinOp::Add, &total, &v)?;
                }
                regs[*dst] = total;
            }
        }
        pc += 1;
    }
    Ok(regs[out].clone())
}

// ---------------------------------------------------------------------------
// Shared batch kernels: the dense inner loops used by (1) this tier's
// fused aggregations, (2) the idiom kernels' native fallbacks in plan.rs,
// and (3) the distributed coordinator's per-node `process_chunk`.
//
// Dense-width contract: every `acc[k as usize]` below indexes without a
// runtime bounds branch on the hot path in release builds only because
// the caller sizes `acc` to the key column's *dense domain* — a
// dictionary column's codes are `0..dict.len()` by construction, and the
// i64-keyed variants are only driven with accumulators pre-sized to the
// (validated, non-negative) key range. The `debug_assert!`s document and
// check that contract in debug/test builds.
// ---------------------------------------------------------------------------

/// `acc[k] += 1` over a batch of dictionary keys.
pub fn count_batch_u32(keys: &[u32], acc: &mut [i64]) {
    debug_assert!(
        keys.iter().all(|&k| (k as usize) < acc.len()),
        "dense-width contract: every dict code must fit the accumulator"
    );
    for &k in keys {
        acc[k as usize] += 1;
    }
}

/// `acc[k] += 1` over a batch of integer keys.
pub fn count_batch_i64(keys: &[i64], acc: &mut [i64]) {
    debug_assert!(
        keys.iter().all(|&k| 0 <= k && (k as usize) < acc.len()),
        "dense-width contract: every key must be in [0, acc.len())"
    );
    for &k in keys {
        acc[k as usize] += 1;
    }
}

/// f64-accumulator variant (the coordinator's wire format).
pub fn count_batch_u32_f64(keys: &[u32], acc: &mut [f64]) {
    debug_assert!(
        keys.iter().all(|&k| (k as usize) < acc.len()),
        "dense-width contract: every dict code must fit the accumulator"
    );
    for &k in keys {
        acc[k as usize] += 1.0;
    }
}

/// f64-accumulator variant (the coordinator's wire format).
pub fn count_batch_i64_f64(keys: &[i64], acc: &mut [f64]) {
    debug_assert!(
        keys.iter().all(|&k| 0 <= k && (k as usize) < acc.len()),
        "dense-width contract: every key must be in [0, acc.len())"
    );
    for &k in keys {
        acc[k as usize] += 1.0;
    }
}

/// `acc[k] += v` over aligned key/value batches (dictionary keys).
pub fn sum_batch_u32(keys: &[u32], vals: &[f64], acc: &mut [f64]) {
    debug_assert!(
        keys.iter().all(|&k| (k as usize) < acc.len()),
        "dense-width contract: every dict code must fit the accumulator"
    );
    for (&k, &v) in keys.iter().zip(vals) {
        acc[k as usize] += v;
    }
}

/// `acc[k] += v` over aligned key/value batches (integer keys).
pub fn sum_batch_i64(keys: &[i64], vals: &[f64], acc: &mut [f64]) {
    debug_assert!(
        keys.iter().all(|&k| 0 <= k && (k as usize) < acc.len()),
        "dense-width contract: every key must be in [0, acc.len())"
    );
    for (&k, &v) in keys.iter().zip(vals) {
        acc[k as usize] += v;
    }
}

/// `acc[k] = acc[k].wrapping_add(v)` over aligned key/value batches —
/// the scalar single-stripe fallback the integer-sum states use when the
/// dictionary is too wide for striping (see [`MAX_STRIPED_WIDTH`]).
pub fn sum_batch_u32_i64(keys: &[u32], vals: &[i64], acc: &mut [i64]) {
    debug_assert!(
        keys.iter().all(|&k| (k as usize) < acc.len()),
        "dense-width contract: every dict code must fit the accumulator"
    );
    for (&k, &v) in keys.iter().zip(vals) {
        acc[k as usize] = acc[k as usize].wrapping_add(v);
    }
}

// ---------------------------------------------------------------------------
// SIMD-shaped striped kernels (`vec.simd`): fixed-trip-count
// `chunks_exact(LANES)` bodies over LANES independent per-lane partials.
// Lane `l`'s partial for dense slot `k` lives at `stripes[l * width + k]`,
// so a chunk's LANES updates hit LANES disjoint histograms — repeated
// keys never serialize on one store-to-load chain, and the autovectorizer
// sees a branch-free constant-width body. Only *integer* accumulators are
// striped: wrapping `i64` addition is associative and commutative, so the
// end-of-scan stripe fold is bit-exact with the scalar loop. Float folds
// are never striped — they keep the interpreter's row order (see the
// module doc's semantics contract).
// ---------------------------------------------------------------------------

/// Striped `acc[k] += 1`: fold rows into `LANES` interleaved count
/// histograms. `stripes.len()` must be `LANES * width`.
pub fn count_batch_u32_striped(keys: &[u32], width: usize, stripes: &mut [i64]) {
    debug_assert_eq!(stripes.len(), LANES * width);
    debug_assert!(
        keys.iter().all(|&k| (k as usize) < width),
        "dense-width contract: every dict code must fit the accumulator"
    );
    let mut chunks = keys.chunks_exact(LANES);
    for chunk in &mut chunks {
        for (l, &k) in chunk.iter().enumerate() {
            stripes[l * width + k as usize] += 1;
        }
    }
    for &k in chunks.remainder() {
        stripes[k as usize] += 1;
    }
}

/// Striped `acc[k] += v` over aligned key/value batches (wrapping `i64`
/// sums). `stripes.len()` must be `LANES * width`.
pub fn sum_batch_u32_i64_striped(keys: &[u32], vals: &[i64], width: usize, stripes: &mut [i64]) {
    debug_assert_eq!(stripes.len(), LANES * width);
    debug_assert_eq!(keys.len(), vals.len());
    debug_assert!(
        keys.iter().all(|&k| (k as usize) < width),
        "dense-width contract: every dict code must fit the accumulator"
    );
    let mut kc = keys.chunks_exact(LANES);
    let mut vc = vals.chunks_exact(LANES);
    for (ks, vs) in (&mut kc).zip(&mut vc) {
        for (l, (&k, &v)) in ks.iter().zip(vs).enumerate() {
            let slot = &mut stripes[l * width + k as usize];
            *slot = slot.wrapping_add(v);
        }
    }
    for (&k, &v) in kc.remainder().iter().zip(vc.remainder()) {
        let slot = &mut stripes[k as usize];
        *slot = slot.wrapping_add(v);
    }
}

/// Fold `LANES` (or one) interleaved stripes back into a single dense
/// `width`-slot vector. Accepts the single-stripe layout too, so callers
/// can finish either path through one code shape.
pub fn fold_lanes_i64(width: usize, stripes: &[i64]) -> Vec<i64> {
    if width == 0 {
        return Vec::new();
    }
    let mut out = vec![0i64; width];
    for stripe in stripes.chunks_exact(width) {
        for (o, &s) in out.iter_mut().zip(stripe) {
            *o = o.wrapping_add(s);
        }
    }
    out
}

/// Fixed-width pre-fold of a flat `i64` slice (wrapping addition): the
/// RLE run-aggregation kernel sums each run's values through `LANES`
/// partials folded at the end — exact for integers, and the shape the
/// autovectorizer turns into vertical adds plus one horizontal reduce.
pub fn sum_lanes_i64(vals: &[i64]) -> i64 {
    let mut parts = [0i64; LANES];
    let mut chunks = vals.chunks_exact(LANES);
    for chunk in &mut chunks {
        for (p, &v) in parts.iter_mut().zip(chunk) {
            *p = p.wrapping_add(v);
        }
    }
    let mut total = parts.iter().fold(0i64, |a, &p| a.wrapping_add(p));
    for &v in chunks.remainder() {
        total = total.wrapping_add(v);
    }
    total
}

/// LANES-striped dense `i64` accumulator shared by the fused count and
/// integer-sum states: allocates `LANES` stripes for dictionary widths up
/// to [`MAX_STRIPED_WIDTH`] (the `vec.simd` path) and a single scalar
/// stripe beyond that.
pub(crate) struct StripedI64 {
    width: usize,
    data: Vec<i64>,
}

impl StripedI64 {
    pub(crate) fn new(width: usize) -> StripedI64 {
        let striped = width <= MAX_STRIPED_WIDTH;
        let stripes = if striped { LANES } else { 1 };
        StripedI64 {
            width,
            data: vec![0i64; width * stripes],
        }
    }

    /// True when per-lane stripes were allocated (the `vec.simd` path).
    pub(crate) fn striped(&self) -> bool {
        self.data.len() > self.width
    }

    pub(crate) fn add_counts(&mut self, keys: &[u32]) {
        if self.striped() {
            count_batch_u32_striped(keys, self.width, &mut self.data);
        } else {
            count_batch_u32(keys, &mut self.data);
        }
    }

    pub(crate) fn add_sums(&mut self, keys: &[u32], vals: &[i64]) {
        if self.striped() {
            sum_batch_u32_i64_striped(keys, vals, self.width, &mut self.data);
        } else {
            sum_batch_u32_i64(keys, vals, &mut self.data);
        }
    }

    /// Fold the stripes into one dense `width`-slot total vector.
    pub(crate) fn totals(&self) -> Vec<i64> {
        fold_lanes_i64(self.width, &self.data)
    }
}

/// Associative count over a batch of plain strings: hashes the `Arc<str>`
/// contents without constructing a `Value` per row.
pub fn count_batch_strs(keys: &[Arc<str>], acc: &mut FxHashMap<Arc<str>, f64>) {
    for s in keys {
        match acc.get_mut(s) {
            Some(n) => *n += 1.0,
            None => {
                acc.insert(s.clone(), 1.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::local;
    use crate::ir::{ArrayDecl, DataType, Expr, IndexSet, Loop, Multiset, Schema, Stmt};
    use crate::sql::compile_sql;
    use crate::workload::{access_log, AccessLogSpec};

    fn catalog(rows: usize, dict: bool) -> StorageCatalog {
        let m = access_log(&AccessLogSpec {
            rows,
            urls: 64,
            skew: 1.1,
            seed: 7,
        });
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        if dict {
            let mut t = (**c.get("access").unwrap()).clone();
            t.dict_encode_field(0).unwrap();
            c.replace("access", t);
        }
        c
    }

    fn assert_matches_interpreter(p: &Program, c: &StorageCatalog) {
        let reference = local::run(p, c).unwrap();
        let out = try_run(p, c).unwrap().expect("vectorized tier must fire");
        assert!(
            out.result()
                .map(|m| m.bag_eq(reference.result().unwrap()))
                .unwrap_or(reference.result().is_none()),
            "vectorized diverged from interpreter"
        );
        assert_eq!(out.scalars, reference.scalars);
        assert_eq!(out.prints, reference.prints);
        assert!(out.stats.idioms.contains(&"vectorized".to_string()));
    }

    #[test]
    fn group_count_matches_interpreter_strings_and_dict() {
        for dict in [false, true] {
            let c = catalog(3000, dict);
            let p = compile_sql(
                "SELECT url, COUNT(url) FROM access GROUP BY url",
                &c.schemas(),
            )
            .unwrap();
            assert_matches_interpreter(&p, &c);
            let out = try_run(&p, &c).unwrap().unwrap();
            assert!(
                out.stats.idioms.contains(&"vec.count".to_string()),
                "{:?}",
                out.stats.idioms
            );
        }
    }

    #[test]
    fn projection_and_filter_match_interpreter() {
        let c = catalog(1000, false);
        for q in [
            "SELECT url FROM access",
            "SELECT url FROM access WHERE url = 'http://example.org/site0/page0.html'",
            "SELECT url FROM access WHERE url = '/nope'",
        ] {
            let p = compile_sql(q, &c.schemas()).unwrap();
            assert_matches_interpreter(&p, &c);
        }
    }

    #[test]
    fn group_sum_floats_match_interpreter_exactly() {
        let schema = Schema::new(vec![("k", DataType::Str), ("x", DataType::Float)]);
        let mut m = Multiset::new(schema);
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..500 {
            m.push(vec![
                Value::str(format!("k{}", rng.below(10))),
                Value::Float((rng.f64() - 0.5) * 10.0),
            ]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("t", &m).unwrap();
        let p = compile_sql("SELECT k, SUM(x) FROM t GROUP BY k", &c.schemas()).unwrap();
        // Exact equality (not approximate): fold order must match.
        let reference = local::run(&p, &c).unwrap();
        let out = try_run(&p, &c).unwrap().unwrap();
        assert!(out.result().unwrap().bag_eq(reference.result().unwrap()));
    }

    #[test]
    fn weighted_average_scalars_and_prints_match() {
        let mut c = StorageCatalog::new();
        let grades = Multiset::with_rows(
            Schema::new(vec![
                ("studentID", DataType::Int),
                ("grade", DataType::Float),
                ("weight", DataType::Float),
            ]),
            vec![
                vec![Value::Int(25), Value::Float(8.0), Value::Float(0.5)],
                vec![Value::Int(30), Value::Float(6.0), Value::Float(1.0)],
                vec![Value::Int(25), Value::Float(6.0), Value::Float(0.5)],
            ],
        );
        c.insert_multiset("Grades", &grades).unwrap();
        let mut p = Program::new("avg")
            .with_relation("Grades", c.schemas()["Grades"].clone())
            .with_scalar("avg", Value::Float(0.0));
        p.body = vec![
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::filtered("Grades", "studentID", Expr::int(25)),
                vec![Stmt::assign(
                    "avg",
                    Expr::add(
                        Expr::var("avg"),
                        Expr::mul(Expr::field("i", "grade"), Expr::field("i", "weight")),
                    ),
                )],
            )),
            Stmt::Print {
                format: "Average grade: {}".into(),
                args: vec![Expr::var("avg")],
            },
        ];
        assert_matches_interpreter(&p, &c);
        let out = try_run(&p, &c).unwrap().unwrap();
        assert_eq!(out.scalars["avg"], Value::Float(7.0));
        assert_eq!(out.prints, vec!["Average grade: 7".to_string()]);
    }

    #[test]
    fn partitioned_forall_matches_interpreter() {
        let c = catalog(900, false);
        let mut p = Program::new("part")
            .with_relation("access", c.schemas()["access"].clone())
            .with_array("count", ArrayDecl::counter())
            .with_param("N", Value::Int(3))
            .with_result(
                "R",
                Schema::new(vec![("url", DataType::Str), ("n", DataType::Int)]),
            );
        p.body = vec![
            Stmt::Loop(Loop::forall_range(
                "k",
                Expr::int(1),
                Expr::var("N"),
                vec![Stmt::Loop(Loop::forelem(
                    "i",
                    IndexSet::all("access").with_partition(Expr::var("k"), Expr::var("N")),
                    vec![Stmt::increment("count", vec![Expr::field("i", "url")])],
                ))],
            )),
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::distinct_of("access", "url"),
                vec![Stmt::result_union(
                    "R",
                    vec![
                        Expr::field("i", "url"),
                        Expr::array("count", vec![Expr::field("i", "url")]),
                    ],
                )],
            )),
        ];
        assert_matches_interpreter(&p, &c);
    }

    #[test]
    fn empty_table_and_empty_range_are_fine() {
        let mut c = StorageCatalog::new();
        let m = Multiset::new(Schema::new(vec![("url", DataType::Str)]));
        c.insert_multiset("access", &m).unwrap();
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &c.schemas(),
        )
        .unwrap();
        assert_matches_interpreter(&p, &c);

        // Range with hi < lo runs zero iterations.
        let mut p2 = Program::new("empty")
            .with_relation("access", c.schemas()["access"].clone())
            .with_scalar("x", Value::Int(0));
        p2.body = vec![Stmt::Loop(Loop::for_range(
            "k",
            Expr::int(5),
            Expr::int(4),
            vec![Stmt::assign("x", Expr::var("k"))],
        ))];
        assert_matches_interpreter(&p2, &c);
    }

    #[test]
    fn unsupported_shapes_return_none() {
        // Value partitions stay on the interpreter tier.
        let c = catalog(100, false);
        let mut p = Program::new("vpart")
            .with_relation("access", c.schemas()["access"].clone())
            .with_array("count", ArrayDecl::counter());
        p.body = vec![Stmt::Loop(crate::ir::Loop {
            kind: crate::ir::LoopKind::For,
            var: "l".into(),
            domain: crate::ir::Domain::ValuePartition {
                relation: "access".into(),
                field: "url".into(),
                part: Expr::int(1),
                parts: Expr::int(2),
            },
            body: vec![],
            emit: None,
        })];
        assert!(try_run(&p, &c).unwrap().is_none());
    }

    fn join_catalog(arows: usize, brows: usize, dict: bool) -> StorageCatalog {
        let mut rng = crate::util::Rng::new(13);
        let mut a = Multiset::new(Schema::new(vec![
            ("b_id", DataType::Int),
            ("g", DataType::Str),
        ]));
        for _ in 0..arows {
            a.push(vec![
                Value::Int(rng.range(0, brows.max(1) as i64 * 2)),
                Value::str(format!("g{}", rng.below(7))),
            ]);
        }
        let mut b = Multiset::new(Schema::new(vec![
            ("id", DataType::Int),
            ("tag", DataType::Str),
            ("v", DataType::Float),
        ]));
        for i in 0..brows {
            b.push(vec![
                Value::Int(i as i64),
                Value::str(format!("t{}", rng.below(5))),
                Value::Float((rng.f64() - 0.5) * 4.0),
            ]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("A", &a).unwrap();
        c.insert_multiset("B", &b).unwrap();
        if dict {
            let mut t = (**c.get("A").unwrap()).clone();
            t.dict_encode_field(1).unwrap();
            c.replace("A", t);
        }
        c
    }

    #[test]
    fn hash_join_matches_interpreter_and_tags_idiom() {
        let c = join_catalog(500, 40, false);
        let p = compile_sql(
            "SELECT A.g, B.tag FROM A JOIN B ON A.b_id = B.id",
            &c.schemas(),
        )
        .unwrap();
        assert_matches_interpreter(&p, &c);
        let out = try_run(&p, &c).unwrap().unwrap();
        assert!(
            out.stats.idioms.contains(&"vec.hash_join".to_string()),
            "{:?}",
            out.stats.idioms
        );
    }

    #[test]
    fn join_group_by_count_fuses_and_matches() {
        for dict in [false, true] {
            let c = join_catalog(800, 60, dict);
            let p = compile_sql(
                "SELECT g, COUNT(g) FROM A JOIN B ON A.b_id = B.id GROUP BY g",
                &c.schemas(),
            )
            .unwrap();
            assert_matches_interpreter(&p, &c);
            let out = try_run(&p, &c).unwrap().unwrap();
            assert!(
                out.stats.idioms.contains(&"vec.hash_join".to_string())
                    && out.stats.idioms.contains(&"vec.count".to_string()),
                "dict={dict}: {:?}",
                out.stats.idioms
            );
        }
    }

    #[test]
    fn join_group_by_float_sum_matches_exactly() {
        // Group key on the probe side, summed value on the build side;
        // exact equality — per-key fold order must match the interpreter.
        let c = join_catalog(600, 50, false);
        let p = compile_sql(
            "SELECT g, SUM(v) FROM A JOIN B ON A.b_id = B.id GROUP BY g",
            &c.schemas(),
        )
        .unwrap();
        let reference = local::run(&p, &c).unwrap();
        let out = try_run(&p, &c).unwrap().unwrap();
        assert!(out.result().unwrap().bag_eq(reference.result().unwrap()));
        assert!(out.stats.idioms.contains(&"vec.sum".to_string()));
    }

    #[test]
    fn join_group_by_build_side_key_matches() {
        let c = join_catalog(400, 30, false);
        let p = compile_sql(
            "SELECT tag, COUNT(tag) FROM A JOIN B ON A.b_id = B.id GROUP BY tag",
            &c.schemas(),
        )
        .unwrap();
        assert_matches_interpreter(&p, &c);
    }

    #[test]
    fn join_with_residual_guard_matches() {
        let c = join_catalog(300, 25, false);
        let p = compile_sql(
            "SELECT A.g FROM A JOIN B ON A.b_id = B.id WHERE B.v > 0.0",
            &c.schemas(),
        )
        .unwrap();
        assert_matches_interpreter(&p, &c);
    }

    #[test]
    fn join_with_empty_sides_is_fine() {
        for (arows, brows) in [(0, 20), (20, 0), (0, 0)] {
            let c = join_catalog(arows, brows, false);
            let p = compile_sql(
                "SELECT A.g, B.tag FROM A JOIN B ON A.b_id = B.id",
                &c.schemas(),
            )
            .unwrap();
            assert_matches_interpreter(&p, &c);
        }
    }

    #[test]
    fn join_hash_table_buckets_preserve_table_order() {
        let m = Multiset::with_rows(
            Schema::new(vec![("id", DataType::Int)]),
            vec![
                vec![Value::Int(7)],
                vec![Value::Int(3)],
                vec![Value::Int(7)],
                vec![Value::Int(7)],
            ],
        );
        let t = crate::storage::Table::from_multiset(&m).unwrap();
        let ht = JoinHashTable::build(&t, 0);
        assert_eq!(ht.len(), 2);
        assert!(!ht.is_empty());
        assert_eq!(ht.probe(&Value::Int(7)), &[0, 2, 3]);
        assert_eq!(ht.probe(&Value::Int(3)), &[1]);
        assert_eq!(ht.probe(&Value::Int(99)), &[] as &[u32]);
        // Cross-type numeric probe matches the interpreter's Value eq.
        assert_eq!(ht.probe(&Value::Float(3.0)), &[1]);
    }

    #[test]
    fn topk_bounded_heap_equals_stable_sort_prefix() {
        // Random rows, random k: TopK::bounded must retain exactly the
        // stable-sort prefix — same rows, same order, ties included.
        let mut rng = crate::util::Rng::new(42);
        for _ in 0..50 {
            let n = 1 + rng.below(200) as usize;
            let k = rng.below(20) as usize;
            let desc = rng.below(2) == 1;
            let rows: Vec<Tuple> = (0..n)
                .map(|i| vec![Value::Int(i as i64), Value::Int(rng.range(0, 8))])
                .collect();
            let mut heap = TopK::bounded(Some(1), desc, k);
            let mut sort = TopK::sorting(Some(1), desc, Some(k));
            for row in &rows {
                heap.push(row.clone());
                sort.push(row.clone());
            }
            let mut want = rows.clone();
            want.sort_by(|a, b| {
                let ord = a[1].cmp(&b[1]);
                if desc {
                    ord.reverse()
                } else {
                    ord
                }
            });
            want.truncate(k);
            assert_eq!(heap.finish(), want, "desc={desc} k={k} n={n}");
            assert_eq!(sort.finish(), want, "sorting variant, desc={desc} k={k} n={n}");
        }
    }

    #[test]
    fn topk_merge_equals_single_accumulator() {
        // Chunked per-worker heaps merged k-way must equal one heap fed
        // sequentially — the parallel emit fan-out's correctness core.
        let mut rng = crate::util::Rng::new(7);
        let rows: Vec<Tuple> = (0..300)
            .map(|i| vec![Value::Int(i), Value::Int(rng.range(0, 10))])
            .collect();
        let mut single = TopK::bounded(Some(1), true, 12);
        for (i, row) in rows.iter().enumerate() {
            single.push_at(i as u64, row.clone());
        }
        let mut merged = TopK::bounded(Some(1), true, 12);
        for (ci, part) in rows.chunks(64).enumerate() {
            let mut w = TopK::bounded(Some(1), true, 12);
            for (j, row) in part.iter().enumerate() {
                w.push_at((ci * 64 + j) as u64, row.clone());
            }
            merged.merge(w);
        }
        assert_eq!(merged.finish(), single.finish());
    }

    #[test]
    fn topk_group_by_matches_interpreter_rows_exactly() {
        // Ties included: 64 urls over 3000 rows guarantees tied counts
        // are common; the emitted prefix must be row-identical to the
        // interpreter's stable sort.
        for dict in [false, true] {
            let c = catalog(3000, dict);
            for q in [
                "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n DESC LIMIT 9",
                "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n ASC LIMIT 4",
                "SELECT url, COUNT(url) FROM access GROUP BY url ORDER BY url ASC",
                "SELECT url FROM access LIMIT 17",
                "SELECT url FROM access ORDER BY url DESC LIMIT 3",
            ] {
                let p = compile_sql(q, &c.schemas()).unwrap();
                let reference = local::run(&p, &c).unwrap();
                let out = try_run(&p, &c).unwrap().expect("vectorized tier fires");
                assert_eq!(
                    out.result().unwrap().rows(),
                    reference.result().unwrap().rows(),
                    "dict={dict} `{q}`: emission must match the interpreter row-for-row"
                );
            }
            // The bounded forms fire the vec.topk kernel.
            let p = compile_sql(
                "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n DESC LIMIT 9",
                &c.schemas(),
            )
            .unwrap();
            let out = try_run(&p, &c).unwrap().unwrap();
            assert!(
                out.stats.idioms.contains(&"vec.topk".to_string()),
                "dict={dict}: {:?}",
                out.stats.idioms
            );
        }
    }

    #[test]
    fn topk_ordered_join_matches_interpreter_rows_exactly() {
        let c = join_catalog(400, 30, false);
        for q in [
            "SELECT A.g, B.v FROM A JOIN B ON A.b_id = B.id ORDER BY v DESC LIMIT 6",
            "SELECT A.g, B.tag FROM A JOIN B ON A.b_id = B.id LIMIT 11",
        ] {
            let p = compile_sql(q, &c.schemas()).unwrap();
            let reference = local::run(&p, &c).unwrap();
            let out = try_run(&p, &c).unwrap().expect("vectorized join fires");
            assert_eq!(
                out.result().unwrap().rows(),
                reference.result().unwrap().rows(),
                "`{q}`"
            );
            assert!(out.stats.idioms.contains(&"vec.hash_join".to_string()));
            assert!(out.stats.idioms.contains(&"vec.topk".to_string()));
        }
    }

    #[test]
    fn topk_limit_zero_and_oversized_k_are_fine() {
        let c = catalog(500, false);
        for q in [
            "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n DESC LIMIT 0",
            // k far above the group count: everything, sorted.
            "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n DESC LIMIT 500",
        ] {
            let p = compile_sql(q, &c.schemas()).unwrap();
            let reference = local::run(&p, &c).unwrap();
            let out = try_run(&p, &c).unwrap().unwrap();
            assert_eq!(
                out.result().unwrap().rows(),
                reference.result().unwrap().rows(),
                "`{q}`"
            );
        }
        let p = compile_sql(
            "SELECT url, COUNT(url) AS n FROM access GROUP BY url ORDER BY n DESC LIMIT 0",
            &c.schemas(),
        )
        .unwrap();
        assert_eq!(try_run(&p, &c).unwrap().unwrap().result().unwrap().len(), 0);
    }

    #[test]
    fn morsel_ranges_cover_exactly_once() {
        for (lo, hi) in [(0, 0), (0, 1), (0, BATCH), (3, BATCH + 5), (7, 3 * BATCH)] {
            let windows: Vec<(usize, usize)> = morsel_ranges(lo, hi).collect();
            let mut expect = lo;
            for &(s, e) in &windows {
                assert_eq!(s, expect, "[{lo},{hi})");
                assert!(e > s && e - s <= BATCH, "[{lo},{hi})");
                expect = e;
            }
            assert_eq!(expect, if lo < hi { hi } else { lo }, "[{lo},{hi})");
        }
    }

    #[test]
    fn batch_kernels_agree_with_scalar_loops() {
        let keys_u32: Vec<u32> = (0..5000u32).map(|i| i % 37).collect();
        let keys_i64: Vec<i64> = keys_u32.iter().map(|&k| k as i64).collect();
        let vals: Vec<f64> = (0..5000).map(|i| (i % 11) as f64 * 0.25).collect();

        let mut a = vec![0i64; 37];
        count_batch_u32(&keys_u32, &mut a);
        let mut b = vec![0i64; 37];
        count_batch_i64(&keys_i64, &mut b);
        assert_eq!(a, b);
        assert_eq!(a.iter().sum::<i64>(), 5000);

        let mut f1 = vec![0f64; 37];
        count_batch_u32_f64(&keys_u32, &mut f1);
        let mut f2 = vec![0f64; 37];
        count_batch_i64_f64(&keys_i64, &mut f2);
        assert_eq!(f1, f2);

        let mut s1 = vec![0f64; 37];
        sum_batch_u32(&keys_u32, &vals, &mut s1);
        let mut s2 = vec![0f64; 37];
        sum_batch_i64(&keys_i64, &vals, &mut s2);
        assert_eq!(s1, s2);

        let strs: Vec<Arc<str>> = ["/a", "/b", "/a"].iter().map(|s| Arc::from(*s)).collect();
        let mut m: FxHashMap<Arc<str>, f64> = FxHashMap::default();
        count_batch_strs(&strs, &mut m);
        assert_eq!(m[&Arc::<str>::from("/a")], 2.0);
        assert_eq!(m[&Arc::<str>::from("/b")], 1.0);
    }

    /// The dense-width contract the `debug_assert!`s in the batch kernels
    /// document: every code a dictionary column stores decodes, i.e. the
    /// widest code fits a `dict.len()`-slot accumulator.
    #[test]
    fn widest_dict_code_fits_the_dense_accumulator() {
        let c = catalog(2000, true);
        let t = c.get("access").unwrap();
        let Column::DictStrs { keys, dict } = t.column(0) else {
            panic!("url column must be dict-encoded");
        };
        let widest = keys.iter().copied().max().unwrap() as usize;
        assert!(
            widest < dict.len(),
            "widest code {widest} must index a len-{} accumulator",
            dict.len()
        );
        // And the kernels accept exactly that width.
        let mut acc = vec![0i64; dict.len()];
        count_batch_u32(keys, &mut acc);
        assert_eq!(acc.iter().sum::<i64>(), t.len() as i64);
        let mut striped = StripedI64::new(dict.len());
        striped.add_counts(keys);
        assert_eq!(striped.totals(), acc);
    }

    /// The striped kernels and the LANES pre-fold are bit-exact with the
    /// scalar loops (wrapping integer addition is associative), across
    /// remainder lengths around LANES boundaries.
    #[test]
    fn striped_kernels_fold_to_the_scalar_totals() {
        for n in [0, 1, LANES - 1, LANES, 3 * LANES + 2, 5000] {
            let keys: Vec<u32> = (0..n as u32).map(|i| i % 37).collect();
            let vals: Vec<i64> = (0..n as i64).map(|i| (i % 11) - 5).collect();
            let width = 37;

            let mut scalar_counts = vec![0i64; width];
            count_batch_u32(&keys, &mut scalar_counts);
            let mut striped = vec![0i64; LANES * width];
            count_batch_u32_striped(&keys, width, &mut striped);
            assert_eq!(fold_lanes_i64(width, &striped), scalar_counts, "n={n}");

            let mut scalar_sums = vec![0i64; width];
            sum_batch_u32_i64(&keys, &vals, &mut scalar_sums);
            let mut striped = vec![0i64; LANES * width];
            sum_batch_u32_i64_striped(&keys, &vals, width, &mut striped);
            assert_eq!(fold_lanes_i64(width, &striped), scalar_sums, "n={n}");

            let seq = vals.iter().fold(0i64, |a, &v| a.wrapping_add(v));
            assert_eq!(sum_lanes_i64(&vals), seq, "n={n}");
        }
        assert_eq!(fold_lanes_i64(0, &[]), Vec::<i64>::new());
        // Past the striping width cap the accumulator stays scalar.
        assert!(!StripedI64::new(MAX_STRIPED_WIDTH + 1).striped());
        assert!(StripedI64::new(64).striped());
    }

    /// The branchless selection builder appends exactly the branchy
    /// reference's rows, in order, across remainder lengths — including
    /// when appending to a non-empty selection vector.
    #[test]
    fn branchless_select_matches_reference_across_remainders() {
        for n in [0, 1, LANES - 1, LANES, 2 * LANES + 3, 1000] {
            let vals: Vec<i64> = (0..n as i64).map(|i| i % 7).collect();
            let reference: Vec<usize> = (0..n).filter(|&i| vals[i] == 3).map(|i| 100 + i).collect();
            let mut sel = vec![42usize];
            select_eq_i64(&vals, 3, 100, &mut sel);
            assert_eq!(sel[0], 42, "n={n}: existing entries must survive");
            assert_eq!(&sel[1..], &reference[..], "n={n}");

            let codes: Vec<u32> = vals.iter().map(|&v| v as u32).collect();
            let mut sel = Vec::new();
            select_eq_u32(&codes, 3, 100, &mut sel);
            assert_eq!(sel, reference, "n={n}");
        }
    }
}
