//! The reference interpreter: executes any valid program sequentially.
//!
//! This is the semantic oracle for everything else — the recognized-idiom
//! compiled plans (plan.rs), the parallel executor and the distributed
//! coordinator must all produce `bag_eq` results with this interpreter.
//! (The paper generates C code from the IR; our analogue is plan.rs. The
//! interpreter is the specification both are checked against.)

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::ir::{
    Domain, Expr, Loop, LoopKind, Multiset, Program, Stmt, Strategy, Tuple, Value,
};
use crate::storage::{StorageCatalog, Table};

use super::eval::{eval, ArrayStore, Cursor, Env};
use super::index::IndexCache;

/// Execution statistics (observability + test assertions).
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    /// Tuples visited by index-set iteration.
    pub rows_visited: u64,
    /// Index structures built.
    pub index_builds: usize,
    /// Which compiled idioms fired (empty for the pure interpreter).
    pub idioms: Vec<String>,
    /// Calls into the XLA kernel runtime.
    pub kernel_calls: usize,
}

impl ExecStats {
    /// Merge a program's optimizer decision tags (`Program::opt_tags`,
    /// dot-namespaced `opt.*`) into the idiom list, deduplicating —
    /// several dispatch layers (`run_compiled`, `vector::try_run`,
    /// `run_parallel`) may each merge on the way out.
    pub fn note_opt_tags(&mut self, tags: &[String]) {
        for t in tags {
            if !self.idioms.contains(t) {
                self.idioms.push(t.clone());
            }
        }
    }
}

/// The outcome of executing a program.
#[derive(Debug, Default)]
pub struct Output {
    pub results: BTreeMap<String, Multiset>,
    pub scalars: BTreeMap<String, Value>,
    pub prints: Vec<String>,
    pub stats: ExecStats,
}

impl Output {
    /// The (single) result multiset `R`, when present.
    pub fn result(&self) -> Option<&Multiset> {
        self.results.get("R").or_else(|| self.results.values().next())
    }
}

/// Execute a program sequentially against a storage catalog.
pub fn run(program: &Program, catalog: &StorageCatalog) -> Result<Output> {
    let mut interp = Interp::new(program, catalog);
    interp.run_body(&program.body)?;
    Ok(interp.finish())
}

pub(crate) struct Interp<'a> {
    program: &'a Program,
    catalog: &'a StorageCatalog,
    pub arrays: ArrayStore,
    pub(crate) env: Env,
    pub(crate) results: BTreeMap<String, Multiset>,
    cache: IndexCache,
    pub(crate) prints: Vec<String>,
    pub stats: ExecStats,
}

impl<'a> Interp<'a> {
    pub fn new(program: &'a Program, catalog: &'a StorageCatalog) -> Self {
        let mut results = BTreeMap::new();
        for (name, schema) in &program.results {
            results.insert(name.clone(), Multiset::new(schema.clone()));
        }
        let mut env = Env::new();
        for (name, init) in &program.scalars {
            env.set_var(name, init.clone());
        }
        Interp {
            program,
            catalog,
            arrays: ArrayStore::new(),
            env,
            results,
            cache: IndexCache::new(),
            prints: Vec::new(),
            stats: ExecStats::default(),
        }
    }

    pub fn finish(mut self) -> Output {
        self.stats.index_builds = self.cache.builds;
        let mut scalars = BTreeMap::new();
        for name in self.program.scalars.keys() {
            if let Some(v) = self.env.var(name) {
                scalars.insert(name.clone(), v.clone());
            }
        }
        Output {
            results: self.results,
            scalars,
            prints: self.prints,
            stats: self.stats,
        }
    }

    pub fn run_body(&mut self, body: &[Stmt]) -> Result<()> {
        for s in body {
            self.exec_stmt(s)?;
        }
        Ok(())
    }

    fn exec_stmt(&mut self, s: &Stmt) -> Result<()> {
        match s {
            Stmt::Loop(l) => self.exec_loop(l),
            Stmt::Accum {
                array,
                indices,
                op,
                value,
            } => {
                let decl = self
                    .program
                    .arrays
                    .get(array)
                    .with_context(|| format!("undeclared array `{array}`"))?;
                let index: Tuple = indices
                    .iter()
                    .map(|i| eval(i, &self.env, &self.arrays, self.program))
                    .collect::<Result<_>>()?;
                let v = eval(value, &self.env, &self.arrays, self.program)?;
                self.arrays.accum(array, index, *op, v, &decl.init.clone());
                Ok(())
            }
            Stmt::ResultUnion { result, tuple } => {
                let row: Tuple = tuple
                    .iter()
                    .map(|e| eval(e, &self.env, &self.arrays, self.program))
                    .collect::<Result<_>>()?;
                self.results
                    .get_mut(result)
                    .with_context(|| format!("undeclared result `{result}`"))?
                    .push(row);
                Ok(())
            }
            Stmt::Assign { var, value } => {
                let v = eval(value, &self.env, &self.arrays, self.program)?;
                self.env.set_var(var, v);
                Ok(())
            }
            Stmt::If { cond, then, els } => {
                let c = eval(cond, &self.env, &self.arrays, self.program)?;
                if c.truthy() {
                    self.run_body(then)
                } else {
                    self.run_body(els)
                }
            }
            Stmt::Print { format, args } => {
                let values: Vec<Value> = args
                    .iter()
                    .map(|a| eval(a, &self.env, &self.arrays, self.program))
                    .collect::<Result<_>>()?;
                self.prints.push(super::eval::format_print(format, &values));
                Ok(())
            }
        }
    }

    fn exec_loop(&mut self, l: &Loop) -> Result<()> {
        // Ordered/bounded emission (the IR form of ORDER BY/LIMIT): run
        // the loop normally, then stable-sort + truncate the rows it
        // appended to each result. This is the reference semantics the
        // vectorized `vec.topk` bounded-heap kernel and the parallel
        // k-way merge must reproduce exactly, ties included.
        let Some(emit) = &l.emit else {
            return self.exec_loop_domain(l);
        };
        let marks: Vec<(String, usize)> = self
            .results
            .iter()
            .map(|(name, m)| (name.clone(), m.len()))
            .collect();
        self.exec_loop_domain(l)?;
        for (name, mark) in marks {
            let rows = self.results.get_mut(&name).expect("result still declared");
            let mut tail = rows.rows_mut().split_off(mark);
            emit.apply_rows(&mut tail);
            rows.rows_mut().extend(tail);
        }
        Ok(())
    }

    fn exec_loop_domain(&mut self, l: &Loop) -> Result<()> {
        match &l.domain {
            Domain::IndexSet(ix) => {
                let table = self.catalog.get(&ix.relation)?.clone();

                // Partitioned index set: restrict to the k-th contiguous
                // block (direct data partitioning, §III-A1).
                let (lo, hi) = match &ix.partition {
                    Some(p) => {
                        let k = eval(&p.part, &self.env, &self.arrays, self.program)?
                            .as_int()
                            .context("partition id must be an int")?;
                        let n = eval(&p.parts, &self.env, &self.arrays, self.program)?
                            .as_int()
                            .context("partition count must be an int")?;
                        if k < 1 || k > n {
                            bail!("partition {k} out of 1..={n}");
                        }
                        block_bounds(table.len(), n as usize, k as usize - 1)
                    }
                    None => (0, table.len()),
                };

                if let Some(dfield) = &ix.distinct {
                    // Iterate one representative row per distinct value.
                    let fid = table
                        .schema
                        .field_id(dfield)
                        .with_context(|| format!("no field `{dfield}`"))?;
                    let dix = self.cache.distinct(&table, fid);
                    for &row in dix.firsts.iter() {
                        let row = row as usize;
                        if row < lo || row >= hi {
                            continue;
                        }
                        self.iter_row(l, &table, row)?;
                    }
                    return Ok(());
                }

                if let Some((field, value_expr)) = &ix.field_filter {
                    let fid = table
                        .schema
                        .field_id(field)
                        .with_context(|| format!("no field `{field}`"))?;
                    let key = eval(value_expr, &self.env, &self.arrays, self.program)?;
                    match ix.strategy {
                        Strategy::Hash => {
                            let hix = self.cache.hash(&table, fid);
                            for &row in hix.probe(&key) {
                                let row = row as usize;
                                if row < lo || row >= hi {
                                    continue;
                                }
                                self.iter_row(l, &table, row)?;
                            }
                        }
                        Strategy::Tree => {
                            let tix = self.cache.tree(&table, fid);
                            for &row in tix.probe(&key) {
                                let row = row as usize;
                                if row < lo || row >= hi {
                                    continue;
                                }
                                self.iter_row(l, &table, row)?;
                            }
                        }
                        Strategy::Scan | Strategy::Unspecified => {
                            for row in lo..hi {
                                self.stats.rows_visited += 1;
                                if table.value(row, fid) == key {
                                    self.iter_row(l, &table, row)?;
                                }
                            }
                        }
                    }
                    return Ok(());
                }

                // Plain full (or partition-restricted) iteration.
                for row in lo..hi {
                    self.iter_row(l, &table, row)?;
                }
                Ok(())
            }
            Domain::Range { lo, hi } => {
                let lo = eval(lo, &self.env, &self.arrays, self.program)?
                    .as_int()
                    .context("range lo must be an int")?;
                let hi = eval(hi, &self.env, &self.arrays, self.program)?
                    .as_int()
                    .context("range hi must be an int")?;
                for k in lo..=hi {
                    self.env.push_var(&l.var, Value::Int(k));
                    let r = self.run_body(&l.body);
                    self.env.pop_var();
                    r?;
                }
                Ok(())
            }
            Domain::ValuePartition {
                relation,
                field,
                part,
                parts,
            } => {
                let table = self.catalog.get(relation)?.clone();
                let fid = table
                    .schema
                    .field_id(field)
                    .with_context(|| format!("no field `{field}`"))?;
                let k = eval(part, &self.env, &self.arrays, self.program)?
                    .as_int()
                    .context("partition id must be an int")?;
                let n = eval(parts, &self.env, &self.arrays, self.program)?
                    .as_int()
                    .context("partition count must be an int")?;
                if k < 1 || k > n {
                    bail!("value partition {k} out of 1..={n}");
                }
                let values = partition_values(&mut self.cache, &table, fid, n as usize);
                for v in values[k as usize - 1].clone() {
                    self.env.push_var(&l.var, v);
                    let r = self.run_body(&l.body);
                    self.env.pop_var();
                    r?;
                }
                Ok(())
            }
            Domain::DistinctValues { relation, field } => {
                let table = self.catalog.get(relation)?.clone();
                let fid = table
                    .schema
                    .field_id(field)
                    .with_context(|| format!("no field `{field}`"))?;
                let dix = self.cache.distinct(&table, fid);
                for &row in dix.firsts.iter() {
                    let v = table.value(row as usize, fid);
                    self.env.push_var(&l.var, v);
                    let r = self.run_body(&l.body);
                    self.env.pop_var();
                    r?;
                }
                Ok(())
            }
        }
    }

    fn iter_row(&mut self, l: &Loop, table: &Arc<Table>, row: usize) -> Result<()> {
        self.stats.rows_visited += 1;
        self.env.push_cursor(
            &l.var,
            Cursor {
                table: table.clone(),
                row,
            },
        );
        let r = self.run_body(&l.body);
        self.env.pop_cursor();
        r
    }
}

/// Contiguous block bounds for direct partitioning: block `k` of `n` over
/// `len` rows, with remainders spread over the leading blocks.
pub fn block_bounds(len: usize, n: usize, k: usize) -> (usize, usize) {
    let base = len / n;
    let rem = len % n;
    let lo = k * base + k.min(rem);
    let size = base + usize::from(k < rem);
    (lo, (lo + size).min(len))
}

/// The sorted-value-range partitioning of `relation.field` into `n`
/// segments (indirect partitioning's `X = X_1 ∪ ... ∪ X_N`).
pub fn partition_values(
    cache: &mut IndexCache,
    table: &Arc<Table>,
    field: usize,
    n: usize,
) -> Vec<Vec<Value>> {
    let tix = cache.tree(table, field);
    let sorted: Vec<Value> = tix.iter().map(|(v, _)| v.clone()).collect();
    let mut parts = Vec::with_capacity(n);
    for k in 0..n {
        let (lo, hi) = block_bounds(sorted.len(), n, k);
        parts.push(sorted[lo..hi].to_vec());
    }
    parts
}

/// Fraction of the loop kinds that the interpreter treats specially:
/// `forall` runs sequentially here — parallel execution is the
/// coordinator's job. Kept as a function so tests can assert the intent.
pub fn forall_is_sequential_here(kind: LoopKind) -> bool {
    kind == LoopKind::Forall
}

#[allow(unused_imports)]
use Expr as _ExprUnused;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArrayDecl, DataType, IndexSet, Schema};
    use crate::sql::compile_sql;

    fn access_catalog() -> StorageCatalog {
        let schema = Schema::new(vec![("url", DataType::Str)]);
        let mut m = Multiset::new(schema);
        for u in ["/a", "/b", "/a", "/c", "/a", "/b"] {
            m.push(vec![Value::str(u)]);
        }
        let mut c = StorageCatalog::new();
        c.insert_multiset("access", &m).unwrap();
        c
    }

    #[test]
    fn url_count_end_to_end() {
        let catalog = access_catalog();
        let p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &catalog.schemas(),
        )
        .unwrap();
        let out = run(&p, &catalog).unwrap();
        let r = out.result().unwrap();
        assert_eq!(r.len(), 3);
        let expected = Multiset::with_rows(
            r.schema.clone(),
            vec![
                vec![Value::str("/a"), Value::Int(3)],
                vec![Value::str("/b"), Value::Int(2)],
                vec![Value::str("/c"), Value::Int(1)],
            ],
        );
        assert!(r.bag_eq(&expected), "{r:?}");
    }

    #[test]
    fn top_k_emission_is_the_stable_sort_prefix() {
        use crate::ir::EmitOrder;
        let catalog = access_catalog();
        let mut p = compile_sql(
            "SELECT url, COUNT(url) FROM access GROUP BY url",
            &catalog.schemas(),
        )
        .unwrap();
        // Annotate the emit loop: ORDER BY count DESC LIMIT 2.
        let Stmt::Loop(emit) = &mut p.body[1] else {
            panic!("expected emit loop")
        };
        emit.emit = Some(EmitOrder::top_k(1, true, 2));
        let out = run(&p, &catalog).unwrap();
        let r = out.result().unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.rows()[0], vec![Value::str("/a"), Value::Int(3)]);
        assert_eq!(r.rows()[1], vec![Value::str("/b"), Value::Int(2)]);
    }

    #[test]
    fn bare_limit_keeps_the_first_rows_in_emission_order() {
        use crate::ir::EmitOrder;
        let catalog = access_catalog();
        let mut p = compile_sql("SELECT url FROM access", &catalog.schemas()).unwrap();
        let Stmt::Loop(scan) = &mut p.body[0] else {
            panic!("expected scan loop")
        };
        scan.emit = Some(EmitOrder::first_k(3));
        let out = run(&p, &catalog).unwrap();
        let r = out.result().unwrap();
        assert_eq!(
            r.rows(),
            &[
                vec![Value::str("/a")],
                vec![Value::str("/b")],
                vec![Value::str("/a")],
            ]
        );
    }

    #[test]
    fn join_all_strategies_agree() {
        let mut c = StorageCatalog::new();
        let a = Multiset::with_rows(
            Schema::new(vec![("b_id", DataType::Int), ("field", DataType::Str)]),
            vec![
                vec![Value::Int(1), Value::str("a1")],
                vec![Value::Int(2), Value::str("a2")],
                vec![Value::Int(1), Value::str("a3")],
                vec![Value::Int(9), Value::str("a4")], // no partner
            ],
        );
        let b = Multiset::with_rows(
            Schema::new(vec![("id", DataType::Int), ("field", DataType::Str)]),
            vec![
                vec![Value::Int(1), Value::str("b1")],
                vec![Value::Int(2), Value::str("b2")],
                vec![Value::Int(1), Value::str("b3")],
            ],
        );
        c.insert_multiset("A", &a).unwrap();
        c.insert_multiset("B", &b).unwrap();

        let base = compile_sql(
            "SELECT A.field, B.field FROM A JOIN B ON A.b_id = B.id",
            &c.schemas(),
        )
        .unwrap();
        let reference = run(&base, &c).unwrap();
        assert_eq!(reference.result().unwrap().len(), 5); // (a1,b1)(a1,b3)(a2,b2)(a3,b1)(a3,b3)

        for strat in [Strategy::Scan, Strategy::Hash, Strategy::Tree] {
            let mut p = base.clone();
            // Set the inner loop's strategy.
            if let Stmt::Loop(outer) = &mut p.body[0] {
                if let Stmt::Loop(inner) = &mut outer.body[0] {
                    inner.index_set_mut().unwrap().strategy = strat;
                }
            }
            let out = run(&p, &c).unwrap();
            assert!(
                out.result().unwrap().bag_eq(reference.result().unwrap()),
                "strategy {strat} diverged"
            );
        }
    }

    #[test]
    fn hash_strategy_builds_one_index_and_visits_fewer_rows() {
        let mut c = StorageCatalog::new();
        let b = {
            let mut m = Multiset::new(Schema::new(vec![("id", DataType::Int)]));
            for i in 0..100 {
                m.push(vec![Value::Int(i)]);
            }
            m
        };
        c.insert_multiset("A", &b).unwrap();
        c.insert_multiset("B", &b).unwrap();
        // Self-join style probe: for each A row, find B rows with same id.
        let mut p = Program::new("t")
            .with_relation("A", c.schemas()["A"].clone())
            .with_relation("B", c.schemas()["B"].clone())
            .with_result("R", Schema::new(vec![("x", DataType::Int)]));
        p.body = vec![Stmt::Loop(Loop::forelem(
            "i",
            IndexSet::all("A"),
            vec![Stmt::Loop(Loop::forelem(
                "j",
                IndexSet::filtered("B", "id", Expr::field("i", "id"))
                    .with_strategy(Strategy::Hash),
                vec![Stmt::result_union("R", vec![Expr::field("j", "id")])],
            ))],
        ))];
        let out = run(&p, &c).unwrap();
        assert_eq!(out.result().unwrap().len(), 100);
        assert_eq!(out.stats.index_builds, 1);
        // Scan would visit 100*100 B-rows; hash visits 100 + 100.
        assert!(out.stats.rows_visited <= 300, "{}", out.stats.rows_visited);
    }

    #[test]
    fn partitioned_loop_covers_every_row_exactly_once() {
        let catalog = access_catalog();
        // forall k=1..3 { forelem i ∈ p_k access { count[i.url]++ } } then emit.
        let mut p = Program::new("part")
            .with_relation("access", catalog.schemas()["access"].clone())
            .with_array("count", ArrayDecl::counter())
            .with_param("N", Value::Int(3))
            .with_result(
                "R",
                Schema::new(vec![("url", DataType::Str), ("n", DataType::Int)]),
            );
        p.body = vec![
            Stmt::Loop(Loop::forall_range(
                "k",
                Expr::int(1),
                Expr::var("N"),
                vec![Stmt::Loop(Loop::forelem(
                    "i",
                    IndexSet::all("access").with_partition(Expr::var("k"), Expr::var("N")),
                    vec![Stmt::increment("count", vec![Expr::field("i", "url")])],
                ))],
            )),
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::distinct_of("access", "url"),
                vec![Stmt::result_union(
                    "R",
                    vec![
                        Expr::field("i", "url"),
                        Expr::array("count", vec![Expr::field("i", "url")]),
                    ],
                )],
            )),
        ];
        let out = run(&p, &catalog).unwrap();
        let r = out.result().unwrap();
        let expected = Multiset::with_rows(
            r.schema.clone(),
            vec![
                vec![Value::str("/a"), Value::Int(3)],
                vec![Value::str("/b"), Value::Int(2)],
                vec![Value::str("/c"), Value::Int(1)],
            ],
        );
        assert!(r.bag_eq(&expected));
    }

    #[test]
    fn value_partition_covers_all_values() {
        let catalog = access_catalog();
        // forall k=1..2 { for l ∈ X_k { forelem i ∈ paccess.url[l] { count[i.url]++ } } }
        let mut p = Program::new("vpart")
            .with_relation("access", catalog.schemas()["access"].clone())
            .with_array("count", ArrayDecl::counter())
            .with_param("N", Value::Int(2))
            .with_result(
                "R",
                Schema::new(vec![("url", DataType::Str), ("n", DataType::Int)]),
            );
        p.body = vec![
            Stmt::Loop(Loop::forall_range(
                "k",
                Expr::int(1),
                Expr::var("N"),
                vec![Stmt::Loop(Loop {
                    kind: LoopKind::For,
                    var: "l".into(),
                    domain: Domain::ValuePartition {
                        relation: "access".into(),
                        field: "url".into(),
                        part: Expr::var("k"),
                        parts: Expr::var("N"),
                    },
                    emit: None,
                    body: vec![Stmt::Loop(Loop::forelem(
                        "i",
                        IndexSet::filtered("access", "url", Expr::var("l"))
                            .with_strategy(Strategy::Hash),
                        vec![Stmt::increment("count", vec![Expr::field("i", "url")])],
                    ))],
                })],
            )),
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::distinct_of("access", "url"),
                vec![Stmt::result_union(
                    "R",
                    vec![
                        Expr::field("i", "url"),
                        Expr::array("count", vec![Expr::field("i", "url")]),
                    ],
                )],
            )),
        ];
        let out = run(&p, &catalog).unwrap();
        let r = out.result().unwrap();
        let expected = Multiset::with_rows(
            r.schema.clone(),
            vec![
                vec![Value::str("/a"), Value::Int(3)],
                vec![Value::str("/b"), Value::Int(2)],
                vec![Value::str("/c"), Value::Int(1)],
            ],
        );
        assert!(r.bag_eq(&expected), "{r:?}");
    }

    #[test]
    fn weighted_average_vertical_integration() {
        // §III-B merged loop: avg += grade*weight over one student.
        let mut c = StorageCatalog::new();
        let grades = Multiset::with_rows(
            Schema::new(vec![
                ("studentID", DataType::Int),
                ("grade", DataType::Float),
                ("weight", DataType::Float),
            ]),
            vec![
                vec![Value::Int(25), Value::Float(8.0), Value::Float(0.5)],
                vec![Value::Int(30), Value::Float(6.0), Value::Float(1.0)],
                vec![Value::Int(25), Value::Float(6.0), Value::Float(0.5)],
            ],
        );
        c.insert_multiset("Grades", &grades).unwrap();
        let mut p = Program::new("avg")
            .with_relation("Grades", c.schemas()["Grades"].clone())
            .with_scalar("avg", Value::Float(0.0));
        p.body = vec![
            Stmt::Loop(Loop::forelem(
                "i",
                IndexSet::filtered("Grades", "studentID", Expr::int(25)),
                vec![Stmt::assign(
                    "avg",
                    Expr::add(
                        Expr::var("avg"),
                        Expr::mul(Expr::field("i", "grade"), Expr::field("i", "weight")),
                    ),
                )],
            )),
            Stmt::Print {
                format: "Average grade: {}".into(),
                args: vec![Expr::var("avg")],
            },
        ];
        let out = run(&p, &c).unwrap();
        assert_eq!(out.scalars["avg"], Value::Float(7.0));
        assert_eq!(out.prints, vec!["Average grade: 7".to_string()]);
    }

    #[test]
    fn block_bounds_partition_exactly() {
        for (len, n) in [(10, 3), (7, 7), (5, 8), (0, 4), (100, 1)] {
            let mut covered = 0;
            let mut prev_hi = 0;
            for k in 0..n {
                let (lo, hi) = block_bounds(len, n, k);
                assert_eq!(lo, prev_hi);
                prev_hi = hi;
                covered += hi - lo;
            }
            assert_eq!(covered, len, "len={len} n={n}");
            assert_eq!(prev_hi, len);
        }
    }
}
