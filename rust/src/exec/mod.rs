//! The execution engine: evaluates transformed IR against storage.
//!
//! Three executor tiers, dispatched in order by [`plan::run_compiled`]:
//!
//! 1. [`plan`]    — recognized whole-program idioms executed by native
//!    loops or the XLA kernel runtime (the analogue of the paper's
//!    generated C code);
//! 2. [`vector`]  — the vectorized batch executor: programs lowered by
//!    [`compile`] to slot-resolved register form and driven over column
//!    batches (no per-row name resolution); equi-joins run here as
//!    build+probe hash joins (`"vec.hash_join"`), and ordered/bounded
//!    emissions (`ORDER BY`/`LIMIT` lowered into the IR) as the fused
//!    bounded-heap top-k kernel (`"vec.topk"`, O(n log k));
//! 3. [`local`]   — the sequential reference interpreter (semantic
//!    oracle); every other tier must produce `bag_eq` results with it.
//!
//! Support modules:
//!
//! * [`eval`]    — expression evaluation, environments, accumulator store;
//! * [`compile`] — the one-pass IR → register-program compiler (including
//!   the `scan_parallel_safe`/`join_parallel_safe` effect analyses);
//! * [`index`]   — temporary runtime index structures (hash/tree/distinct);
//! * [`parallel`] — shared-memory morsel-driven execution: `forall`
//!   loops, eligible `forelem` scans and compiled hash joins fan out
//!   over a worker pool pulling chunks through the `sched::Policy`
//!   machinery (GSS by default, chunk-affinity on by default), reusing
//!   the compiled programs across workers.

pub mod compile;
pub mod eval;
pub mod index;
pub mod local;
pub mod parallel;
pub mod plan;
pub mod vector;

pub use compile::{compile_program, CompiledProgram};
pub use eval::{ArrayStore, Cursor, Env};
pub use index::{DistinctIndex, HashIndex, IndexCache, TreeIndex};
pub use local::{block_bounds, partition_values, run, ExecStats, Output};
pub use parallel::{
    run_parallel, run_parallel_compiled_with_opts, run_parallel_with_opts, run_parallel_with_policy,
};
pub use plan::{recognize, run_compiled, Idiom};
pub use vector::{
    count_batch_u32_striped, fold_lanes_i64, morsel_ranges, run_compiled_program, select_eq_i64,
    select_eq_u32, sum_batch_u32_i64, sum_batch_u32_i64_striped, sum_lanes_i64,
    try_run as run_vectorized, JoinHashTable, TopK, BATCH, LANES, MAX_STRIPED_WIDTH,
};
