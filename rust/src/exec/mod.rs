//! The execution engine: evaluates transformed IR against storage.
//!
//! * [`eval`]  — expression evaluation, environments, accumulator store;
//! * [`index`] — temporary runtime index structures (hash/tree/distinct);
//! * [`local`] — the sequential reference interpreter (semantic oracle);
//! * [`plan`]  — compiled plans: recognized idioms executed by native
//!   loops or the XLA kernel runtime (the analogue of the paper's
//!   generated C code).

pub mod eval;
pub mod index;
pub mod local;
pub mod parallel;
pub mod plan;

pub use eval::{ArrayStore, Cursor, Env};
pub use index::{DistinctIndex, HashIndex, IndexCache, TreeIndex};
pub use local::{block_bounds, partition_values, run, ExecStats, Output};
pub use parallel::run_parallel;
pub use plan::{recognize, run_compiled, Idiom};
